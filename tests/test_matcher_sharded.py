"""Distributed matcher: run in a subprocess with 8 fake CPU devices so the
main pytest process keeps jax at 1 device."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import graphs, pso
    from repro.core.matcher import IMMSchedMatcher

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    key = jax.random.PRNGKey(0)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 8, 0.35)
    g = graphs.embed_query_in_target(kt, q, 16)

    cfg = pso.PSOConfig(num_particles=24, epochs=5, inner_steps=10)
    matcher = IMMSchedMatcher(cfg, mesh=mesh, axis_names=("data", "model"))
    res = matcher.match(q, g, key=jax.random.PRNGKey(7))
    assert res.found, f"sharded matcher failed, f*={res.f_star}"
    M = np.asarray(res.mapping, dtype=np.int64)
    assert (M.sum(1) == 1).all() and (M.sum(0) <= 1).all()
    covered = M @ g.adj.astype(np.int64) @ M.T
    assert (covered >= q.adj).all()
    # 8 shards x 24 particles x 5 epochs of candidate mappings came back
    assert res.all_feasible.shape[0] == 5 * 24 * 8
    print("SHARDED-MATCHER-OK", res.feasible_count)

    # distributed revalidation (the tiered pipeline's cheap stage):
    # replicated fallback (B=1 < devices) and problem-axis sharding (B=8)
    import jax.numpy as jnp
    from repro.core import pso as psolib
    from repro.core.graphs import as_device_graphs, topological_relabel
    from repro.core.matcher import build_distributed_revalidate_batch
    qr, _ = topological_relabel(q)
    Q, G, mask = as_device_graphs(qr, g)
    carry = tuple(jnp.asarray(c) for c in res.carry)
    for B in (1, 8):
        rfn = build_distributed_revalidate_batch(
            (8, 16), mesh, cfg, ("data", "model"), B)
        cb = tuple(jnp.stack([c] * B) for c in carry)
        outs = rfn(jnp.stack([Q] * B), jnp.stack([G] * B),
                   jnp.stack([mask] * B), cb)
        ok = np.asarray(outs["ok"])
        assert ok.shape == (B,)
        assert len(set(ok.tolist())) == 1   # identical problems agree
        ref = psolib.revalidate_batch(Q[None], G[None], mask[None],
                                      cfg, tuple(c[None] for c in carry))
        assert ok[0] == bool(np.asarray(ref["ok"])[0])
        if ok[0]:
            np.testing.assert_array_equal(
                np.asarray(outs["mapping"])[0],
                np.asarray(ref["mapping"])[0])
    print("SHARDED-REVALIDATE-OK")
""")


@pytest.mark.slow
def test_sharded_matcher_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED-MATCHER-OK" in out.stdout, out.stderr[-4000:]
    assert "SHARDED-REVALIDATE-OK" in out.stdout, out.stderr[-4000:]
