"""Compute kernels: Pallas TPU implementations + pure-jnp oracles behind
one pluggable backend registry.

Core code selects a suite via :func:`get_backend` /
:func:`for_config` (precedence: explicit arg > ``PSOConfig.backend`` >
``REPRO_KERNEL_BACKEND`` env var > platform default) and calls kernel
entry points on it — see ``kernels/backend.py`` for how to register a
new kernel or a custom suite.
"""
from repro.kernels.backend import (ENV_VAR, KERNEL_NAMES, KernelBackend,
                                   for_config, get_backend,
                                   register_backend, registered_backends,
                                   resolve_backend_name)

__all__ = [
    "ENV_VAR",
    "KERNEL_NAMES",
    "KernelBackend",
    "for_config",
    "get_backend",
    "register_backend",
    "registered_backends",
    "resolve_backend_name",
]
