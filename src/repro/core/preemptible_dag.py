"""Preemptible-DAG construction: DAG-to-Pipeline + Layer Concatenate-and-Split.

Following the paper (§3.1), the query graph handed to the matcher is built
from the live multi-DNN workload in three steps:

  1. **DAG-to-Pipeline** (ReMap): the layer DAG of each task is levelled into
     pipeline stages by longest-path depth; the scheduler only matches a
     *window* of the next few stages (the preemptible frontier), which keeps
     the query size bounded and is what makes interruption cheap — tiles
     beyond the window haven't been committed to engines yet.
  2. **Layer Concatenate** (IsoSched): cheap bandwidth-bound layers
     (norm/activation/elementwise) are fused into their producer tile so the
     query contains only engine-occupying vertices.
  3. **Layer Split** (IsoSched): a layer whose work exceeds one engine's
     tile capacity is split into ⌈work/capacity⌉ parallel tile vertices
     (they inherit the layer's in/out edges; no edges between siblings).

The output is a ``graphs.Graph`` whose vertices are *tiles* with compute
types + MAC weights, plus bookkeeping mapping tiles back to (task, layer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import graphs
from repro.workloads.layers import LayerKind, LayerSpec, WorkloadGraph

# Layer kinds fused into their producer by Layer-Concatenate.
_FUSABLE = {LayerKind.NORM, LayerKind.ACT, LayerKind.ELEMENTWISE}

_KIND_TO_TYPE = {
    LayerKind.CONV: graphs.TYPE_MAC,
    LayerKind.MATMUL: graphs.TYPE_MAC,
    LayerKind.ATTN: graphs.TYPE_MAC,
    LayerKind.MOE: graphs.TYPE_MAC,
    LayerKind.POOL: graphs.TYPE_REDUCE,
    LayerKind.REDUCE: graphs.TYPE_REDUCE,
    LayerKind.NORM: graphs.TYPE_VECTOR,
    LayerKind.ACT: graphs.TYPE_VECTOR,
    LayerKind.ELEMENTWISE: graphs.TYPE_VECTOR,
    LayerKind.EMBED: graphs.TYPE_MAC,
    LayerKind.SSM: graphs.TYPE_MAC,
}


@dataclasses.dataclass
class Tile:
    task_id: int
    layer_idx: int
    split_idx: int
    kind: LayerKind
    macs: float              # work in MACs
    bytes_moved: float       # activation traffic this tile emits
    stage: int               # pipeline stage (DAG-to-Pipeline level)


@dataclasses.dataclass
class PreemptibleDAG:
    graph: graphs.Graph
    tiles: List[Tile]
    # index ranges per task for victim accounting
    task_tiles: Dict[int, List[int]]

    @property
    def n(self) -> int:
        return self.graph.n


def _pipeline_stages(wg: WorkloadGraph) -> np.ndarray:
    """Longest-path level per layer (DAG-to-Pipeline)."""
    n = len(wg.layers)
    adj = wg.adjacency()
    order = graphs._topo_order(adj)
    level = np.zeros(n, dtype=np.int64)
    for v in order:
        preds = np.where(adj[:, v])[0]
        if len(preds):
            level[v] = level[preds].max() + 1
    return level


def _concatenate(wg: WorkloadGraph):
    """Layer-Concatenate: fuse fusable layers into their (single) producer.

    Returns (keep_list, contracted adjacency over kept layers). A fusable
    layer with multiple producers is kept (fusion would duplicate work).
    """
    n = len(wg.layers)
    adj = wg.adjacency().astype(bool)
    parent = np.arange(n)
    for v in range(n):
        preds = np.where(adj[:, v])[0]
        if wg.layers[v].kind in _FUSABLE and len(preds) == 1:
            parent[v] = preds[0]

    def find(v):
        while parent[v] != v:
            parent[v] = parent[parent[v]]
            v = parent[v]
        return v

    roots = sorted({find(v) for v in range(n)})
    root_idx = {r: i for i, r in enumerate(roots)}
    k = len(roots)
    cadj = np.zeros((k, k), dtype=np.uint8)
    extra_macs = np.zeros(k)
    extra_bytes = np.zeros(k)
    for v in range(n):
        r = find(v)
        if v != r:
            extra_macs[root_idx[r]] += wg.layers[v].macs
            extra_bytes[root_idx[r]] += wg.layers[v].bytes_moved
    for u in range(n):
        for v in np.where(adj[u])[0]:
            ru, rv = find(u), find(int(v))
            if ru != rv:
                cadj[root_idx[ru], root_idx[rv]] = 1
    return roots, cadj, extra_macs, extra_bytes


def build_preemptible_dag(
        tasks: Sequence[Tuple[int, WorkloadGraph, int]],
        tile_capacity_macs: float,
        window_stages: int = 4,
        max_split: int = 8) -> PreemptibleDAG:
    """Build the query DAG for the matcher.

    tasks: sequence of (task_id, workload graph, progress_stage) — only
    stages in [progress, progress + window) contribute tiles.
    tile_capacity_macs: one engine-tile's MAC budget (Layer-Split threshold).
    """
    all_tiles: List[Tile] = []
    edges: List[Tuple[int, int]] = []
    task_tiles: Dict[int, List[int]] = {}

    for task_id, wg, progress in tasks:
        roots, cadj, extra_macs, extra_bytes = _concatenate(wg)
        levels_full = _pipeline_stages(wg)
        levels = levels_full[roots]
        # compress levels to consecutive stage ids
        uniq = np.unique(levels)
        stage_of = {int(l): i for i, l in enumerate(uniq)}
        lo, hi = progress, progress + window_stages

        layer_to_tiles: Dict[int, List[int]] = {}
        for li, root in enumerate(roots):
            st = stage_of[int(levels[li])]
            if not (lo <= st < hi):
                continue
            spec = wg.layers[root]
            macs = spec.macs + extra_macs[li]
            nbytes = spec.bytes_moved + extra_bytes[li]
            nsplit = int(np.clip(np.ceil(macs / tile_capacity_macs),
                                 1, max_split))
            ids = []
            for s in range(nsplit):
                tid = len(all_tiles)
                all_tiles.append(Tile(task_id=task_id, layer_idx=root,
                                      split_idx=s, kind=spec.kind,
                                      macs=macs / nsplit,
                                      bytes_moved=nbytes / nsplit,
                                      stage=st))
                ids.append(tid)
                task_tiles.setdefault(task_id, []).append(tid)
            # split siblings form a reduction/broadcast *chain* (partials
            # accumulate hop-by-hop over the NoC) — an all-to-all sibling
            # pattern would demand in/out-degree = split factor, which no
            # degree-4 engine mesh can embed
            for a, b in zip(ids[:-1], ids[1:]):
                edges.append((a, b))
            layer_to_tiles[li] = ids

        for u in range(len(roots)):
            for v in np.where(cadj[u])[0]:
                if u in layer_to_tiles and int(v) in layer_to_tiles:
                    # single bridge: end of the producer chain feeds the
                    # head of the consumer chain (degree ≤ 3 everywhere)
                    edges.append((layer_to_tiles[u][-1],
                                  layer_to_tiles[int(v)][0]))

    n = len(all_tiles)
    adj = np.zeros((n, n), dtype=np.uint8)
    for a, b in edges:
        adj[a, b] = 1
    adj = _cap_degrees(adj, cap=3)
    types = np.array([_KIND_TO_TYPE[t.kind] for t in all_tiles],
                     dtype=np.int32) if n else np.zeros((0,), np.int32)
    weights = np.array([t.macs for t in all_tiles], dtype=np.float32) \
        if n else np.zeros((0,), np.float32)
    g = graphs.Graph.build(adj, types=types, weights=weights)
    assert g.is_dag()
    return PreemptibleDAG(graph=g, tiles=all_tiles, task_tiles=task_tiles)


def _cap_degrees(adj: np.ndarray, cap: int = 3) -> np.ndarray:
    """Reroute excess fan-in/fan-out through NoC multicast/reduction chains.

    Engine meshes have degree ≤ 4, so a tile with 5+ producers (NASNet-style
    concat) or consumers (cell fan-out) can never embed directly. Real TSS
    hardware forwards such traffic hop-by-hop; we model it by rewriting

        fan-out u → {s₁..s_k}:  excess (u → s_j) becomes (s_{j-1} → s_j)
        fan-in  {p₁..p_k} → v:  excess (p_i → v) becomes (p_i → p_{i+1})

    with neighbours ordered topologically (earlier → later ⇒ stays a DAG)
    so precedence is preserved and the forwarding vertex already carries
    the payload.
    """
    adj = adj.copy()
    n = adj.shape[0]
    order = graphs._topo_order(adj)
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    for _ in range(4):               # few passes reach a fixpoint
        changed = False
        for u in range(n):
            succs = sorted(np.where(adj[u])[0], key=lambda v: rank[v])
            while len(succs) > cap:
                v = succs.pop()      # latest consumer forwards from prior
                adj[u, v] = 0
                adj[succs[-1], v] = 1
                changed = True
        for v in range(n):
            preds = sorted(np.where(adj[:, v])[0], key=lambda u: rank[u])
            while len(preds) > cap:
                p = preds.pop(0)     # earliest producer chains forward
                adj[p, v] = 0
                adj[p, preds[0]] = 1
                changed = True
        if not changed:
            break
    return adj


def pad_problem(Q: np.ndarray, G: np.ndarray, mask: np.ndarray,
                n_bucket: int, m_bucket: int):
    """Bucket (Q, G, mask) to fixed sizes without changing semantics.

    Dummy query tiles are isolated and may only map to dedicated dummy PEs
    (one per dummy tile, also isolated), so every real matching extends to a
    padded matching and vice versa. Extra target slots beyond that are
    unreachable (all-zero mask columns).
    """
    n, m = mask.shape
    nd = n_bucket - n                     # dummy tiles
    assert nd >= 0
    m_needed = m + nd
    assert m_bucket >= m_needed, (m_bucket, m_needed)
    Qp = np.zeros((n_bucket, n_bucket), dtype=Q.dtype)
    Qp[:n, :n] = Q
    Gp = np.zeros((m_bucket, m_bucket), dtype=G.dtype)
    Gp[:m, :m] = G
    maskp = np.zeros((n_bucket, m_bucket), dtype=mask.dtype)
    maskp[:n, :m] = mask
    for d in range(nd):
        maskp[n + d, m + d] = 1           # dummy tile d ↔ dummy PE d only
    return Qp, Gp, maskp


def unpad_mapping(M: np.ndarray, n: int, m: int) -> np.ndarray:
    return M[:n, :m]
