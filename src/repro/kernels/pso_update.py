"""Pallas TPU kernel: fused PSO velocity/position/mask/row-normalize step.

Paper Algorithm 1 lines 8–11 touch five (n, m) matrices per particle per
inner step. Unfused, each op is a separate HBM round-trip (the step is
purely elementwise + a row reduction, i.e. VPU/memory-bound). This kernel
fuses the whole update so every matrix is read once and written once —
the TPU analogue of the paper's "arbiters and selectors added to existing
PEs to enable different [element-wise] operations" on one pass through the
array.

Division-free normalization: rows are rescaled by a computed reciprocal
(one divide per row of a (TILE_N, 1) vector, amortized over m lanes),
mirroring the paper's reconfigurable-reciprocal multiplier.

Tiling: grid = (B, n/TILE_N). Blocks are (TILE_N, m) so a full row lives in
one block and the row-sum is local. Per-particle PSO randoms r ∈ R³ ride in
SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

TILE_N = 128
EPS = 1e-9


def _pso_update_kernel(r_ref, s_ref, v_ref, sl_ref, ss_ref, sb_ref, mask_ref,
                       s_out_ref, v_out_ref, *, omega, c1, c2, c3, v_max):
    s = s_ref[0].astype(jnp.float32)          # (TILE_N, m)
    v = v_ref[0].astype(jnp.float32)
    s_local = sl_ref[0].astype(jnp.float32)
    s_star = ss_ref[...].astype(jnp.float32)  # shared across particles
    s_bar = sb_ref[...].astype(jnp.float32)
    maskf = mask_ref[...].astype(jnp.float32)

    r0 = r_ref[0, 0]
    r1 = r_ref[0, 1]
    r2 = r_ref[0, 2]

    v_new = (omega * v
             + c1 * r0 * (s_local - s)
             + c2 * r1 * (s_star - s)
             + c3 * r2 * (s_bar - s))
    v_new = jnp.clip(v_new, -v_max, v_max)
    s_new = jnp.maximum(s + v_new, 0.0) * maskf

    row_sum = jnp.sum(s_new, axis=1, keepdims=True)            # (TILE_N, 1)
    inv = 1.0 / jnp.maximum(row_sum, EPS)                      # reciprocal
    mask_rows = jnp.sum(maskf, axis=1, keepdims=True)
    uniform = maskf * (1.0 / jnp.maximum(mask_rows, 1.0))
    s_new = jnp.where(row_sum > EPS, s_new * inv, uniform)

    s_out_ref[0] = s_new.astype(s_out_ref.dtype)
    v_out_ref[0] = v_new.astype(v_out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("omega", "c1", "c2", "c3", "v_max", "interpret"))
def pso_update_pallas(S, V, S_local, S_star, S_bar, mask, r,
                      omega: float, c1: float, c2: float, c3: float,
                      v_max: float = 1.0, interpret: bool = False):
    """Batched fused PSO step.

    S, V, S_local: (B, n, m) f32 per-particle state.
    S_star, S_bar, mask: (n, m) shared.
    r: (B, 8) f32 per-particle randoms (slots 0..2 used; padded for SMEM
       lane alignment).
    Returns (S_new, V_new).
    """
    B, n, m = S.shape
    n_tiles = pl.cdiv(n, TILE_N)
    kernel = functools.partial(_pso_update_kernel, omega=omega, c1=c1, c2=c2,
                               c3=c3, v_max=v_max)
    blk3 = lambda b, i: (b, i, 0)
    shared = lambda b, i: (i, 0)
    s_new, v_new = pl.pallas_call(
        kernel,
        grid=(B, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 8), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),               # r
            pl.BlockSpec((1, TILE_N, m), blk3),                  # S
            pl.BlockSpec((1, TILE_N, m), blk3),                  # V
            pl.BlockSpec((1, TILE_N, m), blk3),                  # S_local
            pl.BlockSpec((TILE_N, m), shared),                   # S*
            pl.BlockSpec((TILE_N, m), shared),                   # S̄
            pl.BlockSpec((TILE_N, m), shared),                   # mask
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_N, m), blk3),
            pl.BlockSpec((1, TILE_N, m), blk3),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n, m), jnp.float32),
            jax.ShapeDtypeStruct((B, n, m), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r, S, V, S_local, S_star, S_bar, mask)
    return s_new, v_new
