"""Evaluation metrics (paper §4.1.4): Speedup, LBT, Energy efficiency."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.accel.platform import Platform
from repro.sched.simulator import SimConfig, SimResult, Simulator
from repro.sched.schedulers import get_scheduler
from repro.sched.tasks import Scenario, make_scenario


def run_all(scenario: Scenario, platform: Platform,
            schedulers: Sequence[str],
            matcher_mode: str = "analytic") -> Dict[str, SimResult]:
    out = {}
    for name in schedulers:
        cfg = SimConfig(platform=platform, matcher_mode=matcher_mode)
        out[name] = Simulator(cfg, get_scheduler(name)).run(scenario)
    return out


def speedup_table(results: Dict[str, SimResult],
                  ours: str = "immsched") -> Dict[str, float]:
    """Speedup of ``ours`` vs each baseline: ratio of mean total task
    latency (scheduling + queueing + execution), following IsoSched."""
    base = results[ours].avg_total_latency
    return {name: r.avg_total_latency / max(base, 1e-12)
            for name, r in results.items() if name != ours}


def energy_efficiency(results: Dict[str, SimResult],
                      ours: str = "immsched") -> Dict[str, float]:
    """Improvement in per-task work energy (exec + scheduling) of ``ours``
    vs each baseline — throughput per joule, following the paper."""
    mine = results[ours].work_energy_per_task
    return {name: r.work_energy_per_task / max(mine, 1e-18)
            for name, r in results.items() if name != ours}


def matcher_service_stats(results: Dict[str, SimResult]
                          ) -> Dict[str, Dict[str, float]]:
    """Online matcher-service counters per scheduler: compile-cache and
    warm-start hit rates, per-tier pipeline counters, and epochs saved by
    early exit. Schedulers without any matching state (LTS baselines)
    report an empty dict; IsoSched reports its host memo counters."""
    return {name: dict(r.matcher_stats) for name, r in results.items()
            if r.matcher_stats}


def pipeline_tier_rates(result: SimResult) -> Dict[str, float]:
    """Per-tier serve rates of the tiered matcher pipeline for one run.

    Combines the service's real counters (``tier{0,1,2}_hits``, from
    ``matcher_mode="real"`` launches) with the scheduler's analytic tier
    decisions (``sched_tier{0,1,2}_decisions``, charged in every mode) so
    the decision mix is inspectable regardless of matcher mode."""
    ms = result.matcher_stats
    out: Dict[str, float] = {}
    sched_total = sum(ms.get(f"sched_tier{i}_decisions", 0)
                      for i in range(3))
    for i in range(3):
        out[f"tier{i}_hits"] = ms.get(f"tier{i}_hits", 0)
        d = ms.get(f"sched_tier{i}_decisions", 0)
        out[f"sched_tier{i}_decisions"] = d
        out[f"sched_tier{i}_rate"] = d / max(sched_total, 1)
    calls = ms.get("calls", 0)
    out["revalidated_rate"] = ms.get("revalidated_rate", 0.0)
    out["calls"] = calls
    # fused pre-prune accounting: real sweeps observed by the service and
    # the analytic latency the scheduler charged Tier-2 decisions for it
    out["avg_prune_sweeps"] = ms.get("avg_prune_sweeps", 0.0)
    out["sched_prune_launches"] = ms.get("sched_prune_launches", 0)
    out["sched_prune_wall_s"] = ms.get("sched_prune_wall_s", 0.0)
    # Tier-1 calibration: observed rebase outcomes feeding the predictor
    out["sched_tier1_calib_hits"] = ms.get("sched_tier1_calib_hits", 0)
    out["sched_tier1_calib_trials"] = ms.get("sched_tier1_calib_trials", 0)
    return out


def warm_restart_stats(result: SimResult) -> Dict[str, float]:
    """Warm-restart persistence counters for one run.

    Groups the restart-path observables: how many scheduler-process
    kill/restart events the run saw, what a restore brought back
    (carries / similarity entries / predictor posteriors), and the AOT
    executable-cache counters — ``jit_traces`` is the headline: a warm
    restart that re-traced nothing keeps it at 0 for the restarted
    process. All keys default to 0 for schedulers without a service."""
    ms = result.matcher_stats
    keys = ("restart_count", "restart_restored_carries",
            "restart_restored_sim_entries",
            "restart_restored_posterior_buckets",
            "restart_restored_state_sigs", "restart_snapshots_saved",
            "restart_boot_restores",
            "jit_traces", "aot_cache_hits", "aot_cache_misses",
            "aot_exports", "aot_export_failures", "aot_call_fallbacks",
            "snapshot_saves", "snapshot_restores",
            "snapshot_stale_skipped")
    return {k: ms.get(k, 0) for k in keys}


def frontend_stats(result: SimResult) -> Dict[str, float]:
    """Async front-end counters for one run (``core.service``'s
    ``AsyncServiceFrontEnd``): admission-control outcomes (admitted vs
    shed vs forced drains under the block policy), drain trigger
    reasons (deadline-slack crossing / batch class full / manual flush),
    and queue-depth / waiting-time observables. All keys default to 0
    for runs that never attach a front end."""
    ms = result.matcher_stats
    keys = ("fe_submitted", "fe_admitted", "fe_shed", "fe_forced_drains",
            "fe_drains", "fe_drain_deadline", "fe_drain_batch_full",
            "fe_drain_flush", "fe_queue_peak", "fe_wait_s")
    return {k: ms.get(k, 0) for k in keys}


def transfer_stats(result: SimResult) -> Dict[str, float]:
    """Host-sync census of one run's matcher service (the
    device-resident drain pipeline): drain rounds, blocking device→host
    fetches with their payload bytes and blocked wall time, launches
    that donated their carry buffers, and device-carry-pool activity.
    ``host_syncs_per_drain`` is the pipeline's budget observable — ~1 on
    all-warm drain traffic. Keys default to 0 for analytic runs that
    never touch a live service."""
    ms = result.matcher_stats
    keys = ("drains", "host_syncs", "host_syncs_per_drain",
            "host_bytes_transferred", "host_sync_wall_s",
            "donated_launches", "pool_puts", "pool_gathers",
            "pool_live_rows")
    return {k: ms.get(k, 0) for k in keys}


def latency_bound_throughput(scheduler_name: str, platform: Platform,
                             complexity: str, *,
                             hit_target: float = 0.95,
                             horizon: float = 1.0,
                             lo: float = 1.0, hi: float = 4096.0,
                             iters: int = 9, seed: int = 0) -> float:
    """Max Poisson arrival rate (QPS) sustaining ≥ ``hit_target`` urgent
    deadline hit-rate — binary search over λ (paper: LBT = 1/λ*)."""

    def ok(rate: float) -> bool:
        sc = make_scenario(complexity, rate_hz=rate, horizon=horizon,
                           seed=seed)
        if not sc.tasks:
            return True
        cfg = SimConfig(platform=platform, matcher_mode="analytic")
        res = Simulator(cfg, get_scheduler(scheduler_name)).run(sc)
        finished_frac = res.finished / max(res.total, 1)
        return (res.urgent_hit_rate >= hit_target
                and finished_frac >= hit_target)

    if not ok(lo):
        # even the lowest probed rate misses the target: the sustainable
        # rate is below the search bracket, not AT its lower edge —
        # returning `lo` here would report an unsustainable rate as LBT
        return 0.0
    for _ in range(iters):
        mid = (lo * hi) ** 0.5          # geometric bisection
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return lo
