"""Mixture-of-Experts FFN: top-k routing with capacity-based scatter
dispatch.

Design note (TPU roofline): the classic GShard one-hot dispatch/combine
einsums cost O(T·E·C·d) MACs — for 160 experts that's ~27× the expert
FLOPs and would bury the roofline in dispatch work. We instead compute
each token's position in its expert buffer with a cumsum over the one-hot
assignment matrix (integer VPU work, no MACs) and use scatter-add/gather
(data movement only). HLO FLOPs then stay ≈ the true expert FLOPs, and
``MODEL_FLOPS/HLO_FLOPs`` in the roofline table stays honest.

Expert-parallelism: the expert buffers (E, C, d) are sharded E→"model"
(see runtime.sharding); GSPMD turns the scatter/gather into all-to-all
exchanges on that axis — the standard EP pattern.

Covers: DeepSeek-V2 (160 routed top-6 + 2 shared experts) and Arctic
(128 routed top-2 + parallel dense residual FFN).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common, ffn
from repro.models.common import dense_init


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor) + 1
    return max(8, ((c + 7) // 8) * 8)          # lane-align


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "experts": {
            "gate": dense_init(ks[1], (m.num_experts, d, m.expert_d_ff),
                               dtype, in_axis=1),
            "up": dense_init(ks[2], (m.num_experts, d, m.expert_d_ff),
                             dtype, in_axis=1),
            "down": dense_init(ks[3], (m.num_experts, m.expert_d_ff, d),
                               dtype, in_axis=1),
        },
    }
    if m.shared_experts:
        p["shared"] = ffn.init_mlp(ks[4], d, m.expert_d_ff
                                   * m.shared_experts, dtype)
    if m.dense_residual_d_ff:
        p["dense_residual"] = ffn.init_mlp(ks[5], d, m.dense_residual_d_ff,
                                           dtype)
    return p


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.runtime.mesh_ctx import constrain
    m = cfg.moe
    cd = common.dt(cfg.compute_dtype)
    B, S, d = x.shape
    T = B * S
    xf = constrain(x.reshape(T, d), "batch", None)

    # --- routing (f32 router, the production default) ---
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    logits = constrain(logits, "batch", None)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)          # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- capacity positions via one-hot cumsum (integer work, no MACs) ---
    C = _capacity(T, m)
    e_flat = top_e.reshape(-1)                            # (T·k,)
    onehot = jax.nn.one_hot(e_flat, m.num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)                # (T·k, E)
    pos_flat = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos_flat < C                                   # overflow drops
    p_clip = jnp.clip(pos_flat, 0, C - 1)

    # --- dispatch: scatter tokens into (E, C, d) buffers. Pinning the
    # token side to the batch axes and the buffers to the expert(tensor)
    # axis makes GSPMD lower the scatter/gather as the standard EP
    # all-to-all instead of replicating the dispatch (§Perf) ---
    x_rep = jnp.repeat(xf, m.top_k, axis=0).astype(cd)    # (T·k, d)
    x_rep = constrain(x_rep * keep[:, None].astype(cd), "batch", None)
    buf = jnp.zeros((m.num_experts, C, d), cd)
    buf = buf.at[e_flat, p_clip].add(x_rep)
    buf = constrain(buf, "tensor", None, None)

    # --- expert SwiGLU (batched over experts; MXU work == model FLOPs) ---
    ex = params["experts"]
    h = jnp.einsum("ecd,edf->ecf", buf, ex["gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, ex["up"].astype(cd))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                   ex["down"].astype(cd))
    y = constrain(y, "tensor", None, None)

    # --- combine: gather + weighted sum over the token's k experts ---
    y_tok = constrain(y[e_flat, p_clip], "batch", None)   # (T·k, d)
    w = (top_p.reshape(-1).astype(cd) * keep.astype(cd))[:, None]
    out = (y_tok * w).reshape(T, m.top_k, d).sum(axis=1)
    out = out.reshape(B, S, d).astype(x.dtype)

    if m.shared_experts:
        out = out + ffn.mlp(params["shared"], x, cd)
    if m.dense_residual_d_ff:
        out = out + ffn.mlp(params["dense_residual"], x, cd)
    return out


def router_aux_loss(params: dict, cfg: ModelConfig, x: jax.Array):
    """Load-balancing auxiliary loss (Switch-style): E[f_e · p_e] · E."""
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32),
                    axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return jnp.sum(frac * mean_p) * m.num_experts
