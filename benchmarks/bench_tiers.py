"""Tiered-pipeline benchmark: mixed bursts, fragmentation churn, per-tier
latency accounting.

Three experiments, one per acceptance claim of the tiered decision
pipeline (revalidate → similarity-rebase → swarm):

  1. **Mixed warm burst** — E easy (fast-pathing) + H hard (full-epoch)
     problems in one shape bucket, all warm. Compares the tiered
     ``match_many`` drain against (a) E+H sequential warm ``match`` calls
     and (b) the PR-2 *uniform* batch path (``tiered=False``: one swarm
     launch over the whole burst, where a serial device pays the hard
     members' epochs at full batch width). Acceptance: pipeline wall ≤
     sequential AND < uniform, found flags identical everywhere.
  2. **Fragmentation churn** — one workload matched against a drifting
     free-engine set (one engine swaps per step, PREMA-style preemption
     churn). Every drift is an exact-content warm MISS, so the
     content-keyed baseline (``similarity=False``) re-swarms each step
     while Tier-1 rebases serve the tiered service at revalidation cost.
     Acceptance: tiered revalidated-rate > content-keyed baseline's.
  3. **Simulator accounting** — `make_mixed_burst_scenario` through the
     event simulator with the real matcher, dumping the per-tier counters
     surfaced in ``SimResult.matcher_stats`` (and IsoSched's host-memo
     counters for the warm-traffic baseline comparison).

Emits ``BENCH_tiers.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_tiers
           [--easy E] [--hard H] [--repeats N] [--churn-steps T]
           [--smoke] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import numpy as np

from repro.accel import EDGE
from repro.accel.target_graph import (free_engine_graph,
                                      free_engine_signature)
from repro.core import graphs, preemptible_dag, pso
from repro.core.service import MatcherService
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.metrics import pipeline_tier_rates
from repro.sched.tasks import make_mixed_burst_scenario
from repro.workloads import get_workload


def _planted(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _fastpath_problems(svc: MatcherService, want: int, seed0: int = 100):
    """Planted problems whose stored carry re-validates on repeat (the
    warm 'easy' traffic class); mirrors bench_batch's servable filter."""
    probs, keys, wks = [], [], []
    s = seed0
    while len(probs) < want and s < seed0 + 40 * want:
        q, g = _planted(s, 6, 12)
        key = jax.random.PRNGKey(s)
        wk = f"easy/{s}"
        r = svc.match(q, g, key=key, workload_key=wk)
        if r.found:
            r2 = svc.match(q, g, key=jax.random.PRNGKey(s + 999),
                           workload_key=wk)
            if r2.tier == 0:
                probs.append((q, g))
                keys.append(key)
                wks.append(wk)
        s += 1
    assert len(probs) == want, "not enough fast-pathing planted problems"
    return probs, keys, wks


def bench_mixed_burst(cfg: pso.PSOConfig, easy: int, hard: int,
                      repeats: int):
    svc = MatcherService(cfg, batch_classes=(1, 2, 4, max(8, easy + hard)))
    svc_u = MatcherService(cfg, tiered=False,
                           batch_classes=(1, 2, 4, max(8, easy + hard)))
    eprobs, ekeys, ewks = _fastpath_problems(svc, easy)
    # hard member: infeasible in the same (8, 16) bucket → full epochs
    hq, hg = graphs.line_graph(6), graphs.line_graph(4)
    probs = eprobs + [(hq, hg)] * hard
    keys = ekeys + [jax.random.PRNGKey(900 + i) for i in range(hard)]
    wks = ewks + [f"hard/{i}" for i in range(hard)]

    # warm both services on every problem + compile their batch paths
    for svc_x in (svc, svc_u):
        for i, (q, g) in enumerate(probs):
            svc_x.match(q, g, key=keys[i], workload_key=wks[i])
        svc_x.match_many(probs, keys=keys, workload_keys=wks)

    seq_lat, pipe_lat, uni_lat = [], [], []
    seq_flags = pipe_flags = uni_flags = None
    tiers = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        rs = [svc.match(q, g, key=keys[i], workload_key=wks[i])
              for i, (q, g) in enumerate(probs)]
        seq_lat.append(time.perf_counter() - t0)
        seq_flags = [r.found for r in rs]

        t0 = time.perf_counter()
        rp = svc.match_many(probs, keys=keys, workload_keys=wks)
        pipe_lat.append(time.perf_counter() - t0)
        pipe_flags = [r.found for r in rp]
        tiers = [r.tier for r in rp]

        t0 = time.perf_counter()
        ru = svc_u.match_many(probs, keys=keys, workload_keys=wks)
        uni_lat.append(time.perf_counter() - t0)
        uni_flags = [r.found for r in ru]

    assert seq_flags == pipe_flags == uni_flags, \
        (seq_flags, pipe_flags, uni_flags)
    seq_med = statistics.median(seq_lat)
    pipe_med = statistics.median(pipe_lat)
    uni_med = statistics.median(uni_lat)
    return {
        "easy": easy,
        "hard": hard,
        "sequential_median_s": seq_med,
        "pipeline_median_s": pipe_med,
        "uniform_batch_median_s": uni_med,
        "pipeline_over_sequential": pipe_med / max(seq_med, 1e-12),
        "pipeline_over_uniform": pipe_med / max(uni_med, 1e-12),
        "per_problem_tier": tiers,
        "per_problem_found": pipe_flags,
        "tier0_served": sum(1 for t in tiers if t == 0),
        "tier2_served": sum(1 for t in tiers if t == 2),
        "stats": svc.stats_dict(),
        "pass": pipe_med <= seq_med and pipe_med < uni_med,
    }


def bench_fragmentation(cfg: pso.PSOConfig, steps: int, seed: int = 42):
    wl = get_workload("mobilenetv2")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=4)
    q = pd.graph

    rng = np.random.default_rng(seed)
    busy = set(rng.choice(EDGE.engines, 6, replace=False).tolist())
    states = []
    for step in range(steps):
        if step:
            busy.remove(next(iter(busy)))   # one victim resumes ...
            pool = [e for e in range(EDGE.engines) if e not in busy]
            busy.add(int(rng.choice(pool)))  # ... another gets preempted
        free = np.array([e not in busy for e in range(EDGE.engines)])
        states.append((free_engine_graph(EDGE, free),
                       free_engine_signature(free)))

    out = {"query_tiles": int(q.n), "steps": steps}
    for label, sim in (("tiered", True), ("content_keyed", False)):
        svc = MatcherService(cfg, similarity=sim)
        tiers = []
        for i, (tgt, sig) in enumerate(states):
            r = svc.match(q, tgt, key=jax.random.PRNGKey(i),
                          workload_key=(wl.name, sig))
            tiers.append(r.tier)
        s = svc.stats_dict()
        out[label] = {
            "revalidated_rate": s["revalidated_rate"],
            "tier1_hits": s["tier1_hits"],
            "tier2_swarms": s["tier2_checked"],
            "exact_warm_hits": s["warm_hits"],
            "per_step_tier": tiers,
        }
    out["pass"] = (out["tiered"]["revalidated_rate"]
                   > out["content_keyed"]["revalidated_rate"])
    return out


def bench_simulator(cfg: pso.PSOConfig, smoke: bool):
    sc = make_mixed_burst_scenario(
        "simple", "simple" if smoke else "middle",
        rate_hz=30, horizon=0.2 if smoke else 0.4,
        burst_size=4 if smoke else 6, hard_frac=0.25, burst_frac=0.8,
        churn_rate_hz=10, seed=7)
    out = {"scenario": sc.name, "tasks": len(sc.tasks)}
    sim_cfg = SimConfig(platform=EDGE, matcher_mode="real", pso_cfg=cfg,
                        window_stages=2)
    r = Simulator(sim_cfg, get_scheduler("immsched")).run(sc)
    out["immsched"] = {
        "finished": r.finished, "total": r.total,
        "avg_sched_time_s": r.avg_sched_time,
        "tier_rates": pipeline_tier_rates(r),
        "matcher_stats": {k: v for k, v in r.matcher_stats.items()
                          if not k.endswith("wall_s")},
    }
    ri = Simulator(SimConfig(platform=EDGE, matcher_mode="analytic"),
                   get_scheduler("isosched")).run(sc)
    out["isosched"] = {
        "finished": ri.finished,
        "avg_sched_time_s": ri.avg_sched_time,
        "memo_stats": dict(ri.matcher_stats),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--easy", type=int, default=6)
    ap.add_argument("--hard", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=12)
    ap.add_argument("--churn-steps", type=int, default=24)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: small swarm, short runs")
    ap.add_argument("--out", default="BENCH_tiers.json")
    args = ap.parse_args()

    if args.smoke:
        cfg = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)
        easy, hard, repeats, steps = 3, 1, 2, 8
    else:
        # the simulator's production window config (SimConfig.pso_cfg)
        cfg = pso.PSOConfig(num_particles=32, epochs=2, inner_steps=8)
        easy, hard = args.easy, args.hard
        repeats, steps = max(args.repeats, 2), args.churn_steps

    mixed = bench_mixed_burst(cfg, easy, hard, repeats)
    frag = bench_fragmentation(cfg, steps)
    sim = bench_simulator(cfg, args.smoke)

    result = {
        "smoke": bool(args.smoke),
        "pso_cfg": {"num_particles": cfg.num_particles,
                    "epochs": cfg.epochs, "inner_steps": cfg.inner_steps},
        "mixed_burst": mixed,
        "fragmentation": frag,
        "simulator": sim,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("name,us_per_call,derived")
    print(f"tiers_seq_{easy + hard}_warm,"
          f"{mixed['sequential_median_s'] * 1e6:.1f},"
          f"{sum(mixed['per_problem_found'])}/{easy + hard}_found")
    print(f"tiers_pipeline_{easy + hard}_warm,"
          f"{mixed['pipeline_median_s'] * 1e6:.1f},"
          f"vs_seq={mixed['pipeline_over_sequential']:.3f}")
    print(f"tiers_uniform_{easy + hard}_warm,"
          f"{mixed['uniform_batch_median_s'] * 1e6:.1f},"
          f"vs_uniform={mixed['pipeline_over_uniform']:.3f}")
    print(f"tiers_frag_revalidated_rate,0.0,"
          f"tiered={frag['tiered']['revalidated_rate']:.3f}"
          f"_content={frag['content_keyed']['revalidated_rate']:.3f}")
    ok = mixed["pass"] and frag["pass"]
    print(f"tiers_acceptance,0.0,{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
