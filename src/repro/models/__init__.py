from repro.models.model import BuiltModel, build_model
