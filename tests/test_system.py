"""End-to-end behaviour tests for the paper's system: interrupt-driven
scheduling with the real matcher, committed ILP schedules, and the
training/serving framework built around it."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.accel import CLOUD, EDGE
from repro.accel.target_graph import free_engine_graph
from repro.configs import get_config
from repro.core import ilp, preemptible_dag
from repro.core.matcher import IMMSchedMatcher
from repro.core.pso import PSOConfig
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.tasks import fixed_scenario
from repro.workloads import get_workload
from repro.workloads.zoo import lm_workload_from_config

jax.config.update("jax_platform_name", "cpu")


def test_interruptible_end_to_end_real_matcher():
    """Urgent task arrives while the array is saturated -> IMMSched frees
    engines (largest slack first), runs the real quantized PSO-Ullmann
    matcher, urgent task meets its deadline."""
    wls = [get_workload("unet"), get_workload("resnet50"),
           get_workload("unet"), get_workload("mobilenetv2")]
    sc = fixed_scenario(wls, urgent_last=True)
    cfg = SimConfig(platform=EDGE, matcher_mode="real",
                    pso_cfg=PSOConfig(num_particles=32, epochs=2,
                                      inner_steps=6),
                    window_stages=2)
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    assert r.finished == r.total
    assert r.urgent_met == r.urgent_total == 1
    # scheduling stayed in the microsecond regime (on-accelerator matching)
    assert r.avg_sched_time < 1e-3


def test_lm_config_schedules_onto_cloud():
    """The framework's own LM architectures are schedulable workloads:
    qwen2.5-3b window -> Cloud engine array -> valid ILP tensors."""
    wl = lm_workload_from_config(get_config("qwen2.5-3b"), block_group=2)
    cap = CLOUD.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=3)
    assert 0 < pd.n <= CLOUD.engines
    tgt = free_engine_graph(CLOUD, [True] * CLOUD.engines)
    res = IMMSchedMatcher(PSOConfig(num_particles=64, epochs=4,
                                    inner_steps=10)).match(
        pd.graph, tgt, key=jax.random.PRNGKey(1))
    assert res.found
    st = ilp.build_schedule_tensors(pd, np.asarray(res.mapping), CLOUD)
    assert ilp.validate_schedule(st, pd) == []


def test_quantized_matches_paper_scheduling_claim():
    """Quantized on-NPU scheduling must be orders of magnitude cheaper in
    the cost model than serial-CPU scheduling of the same instance."""
    from repro.accel.energy import CostModel
    cm = CostModel(EDGE)
    cfg = PSOConfig(num_particles=32, epochs=2, inner_steps=8)
    t_npu, e_npu = cm.sched_immsched(48, 64, cfg, 32)
    # serial work for the same window (analytic IsoSched model)
    n, m = 48, 64
    nodes = 2.0 * n
    mac_ops = nodes * 3.0 * (2 * n * m * m + 2 * n * n * m)
    t_cpu, e_cpu = cm.sched_serial_cpu(mac_ops, int(nodes))
    assert t_cpu / t_npu > 5.0
    assert e_cpu / e_npu > 50.0


def test_train_then_serve_roundtrip():
    """Train a tiny model a few steps, then serve greedily with KV cache —
    the full framework path the dry-run lowers at production scale."""
    from repro.configs.base import TrainConfig
    from repro.data import DataPipeline, SyntheticLMDataset
    from repro.models import build_model
    from repro.runtime.serve_loop import make_decode_step, make_prefill_step
    from repro.runtime.train_loop import make_train_state, make_train_step
    from tests.test_smoke_archs import reduce_config

    cfg = reduce_config(get_config("llama3-8b"))
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, microbatches=1, total_steps=10)
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, mesh=None),
                   donate_argnums=(0,))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    pipe = DataPipeline(ds, global_batch=4)
    for _ in range(3):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))

    prefill = jax.jit(make_prefill_step(model, max_len=24))
    decode = jax.jit(make_decode_step(model))
    toks = jnp.asarray(pipe.next()["tokens"][:, :16])
    logits, caches = prefill(state["params"], {"tokens": toks})
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for i in range(4):
        tok, logits, caches = decode(state["params"],
                                     {"tokens": tok[:, None]},
                                     caches, jnp.int32(16 + i))
    assert tok.shape == (4,)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
