"""Config-driven scenario registry: composable pieces behind one entry point.

Scenario construction used to be five monolithic ``make_*_scenario``
builders in :mod:`repro.sched.tasks`; adding an arrival shape meant
editing that file. This module splits the construction into small
registered pieces — arrival processes, workload pools, urgency and
deadline policies, restart schedules — each registered by name in a
:class:`Registry` and composed by :func:`build_scenario` from a plain
spec dict::

    build_scenario({
        "name": "demo", "seed": 7, "horizon": 0.5,
        "streams": [{
            "arrival":  {"kind": "burst", "rate_hz": 30,
                         "burst_size": 4, "burst_frac": 0.5},
            "workload": {"kind": "uniform", "complexity": "simple"},
            "urgency":  {"kind": "bernoulli", "urgent_frac": 0.3},
            "deadline": {"kind": "slack"},
        }],
        "restarts": {"kind": "at", "times": [0.25]},
    })

Multiple ``streams`` entries share ONE ``np.random.default_rng(seed)``
consumed sequentially (stream order matters), which is exactly how the
legacy mixed-burst builder interleaved its churn phase — the thin
presets in ``tasks.py`` are byte-identical to their historical output
because every piece draws the RNG in the same order the monolithic
loops did. ``"stream": True`` returns a generator-backed
:class:`~repro.sched.tasks.StreamScenario` instead of materializing the
task list (single stream only; the factory recreates the RNG per replay
so the stream is deterministic).

A spec may instead name a preset: ``build_scenario({"preset": "burst",
"args": {...}})`` delegates to the corresponding ``make_*`` builder.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np

from repro.sched.tasks import Scenario, StreamScenario, TaskSpec
from repro.workloads import get_workload, workload_complexity_class


class Registry:
    """Name → builder mapping with decorator registration.

    The ``_MODEL_BUILDERS`` idiom: pieces self-register under a string
    ``kind`` and are instantiated from spec dicts via :meth:`build`,
    so new arrival/workload/urgency/deadline/restart shapes plug in
    without touching the composition code."""

    def __init__(self, kind: str):
        self.kind = kind
        self._builders: Dict[str, Callable] = {}

    def register(self, name: str) -> Callable:
        """Decorator: register ``fn`` as the builder for ``name``."""
        def deco(fn):
            if name in self._builders:
                raise ValueError(
                    f"duplicate {self.kind} builder {name!r}")
            self._builders[name] = fn
            return fn
        return deco

    def get(self, name: str) -> Callable:
        """The registered builder, or ValueError listing known names."""
        try:
            return self._builders[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} kind {name!r}; "
                f"known: {self.names()}") from None

    def names(self) -> List[str]:
        """Sorted registered names (introspection + error messages)."""
        return sorted(self._builders)

    def build(self, spec: Dict, *args):
        """Instantiate from a spec dict: ``{"kind": name, **params}``.

        Positional ``args`` (e.g. the shared RNG and horizon for
        arrival processes) are passed through ahead of the spec's
        keyword parameters."""
        params = dict(spec)
        kind = params.pop("kind", None)
        if kind is None:
            raise ValueError(
                f"{self.kind} spec needs a 'kind' key: {spec!r}")
        return self.get(kind)(*args, **params)


#: Arrival processes: ``builder(rng, horizon, **params)`` yielding
#: :class:`ArrivalEvent`\ s with nondecreasing ``t < horizon``.
ARRIVALS = Registry("arrival")
#: Workload pools: ``builder(**params)`` returning
#: ``draw(rng, event, i) -> WorkloadGraph`` for task ``i`` of an event.
WORKLOADS = Registry("workload")
#: Urgency policies: ``builder(**params)`` returning ``draw(rng) -> bool``.
#: ``never``/``always`` consume NO randomness (draw-order fidelity).
URGENCY = Registry("urgency")
#: Deadline policies: ``builder(**params)`` returning
#: ``fn(t, workload, urgent) -> absolute deadline``.
DEADLINES = Registry("deadline")
#: Restart schedules: ``builder(**params)`` returning a transform
#: ``(tasks, horizon) -> (tasks, horizon, restart_times)``.
RESTARTS = Registry("restarts")


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One arrival instant: ``count`` tasks land at time ``t``;
    ``burst`` marks compound (multi-task) events so workload pools can
    treat burst members differently (the mixed easy/hard burst)."""
    t: float
    count: int
    burst: bool


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@ARRIVALS.register("poisson")
def _poisson_arrivals(rng, horizon, *, rate_hz):
    """Plain Poisson point process: one task per exponential gap.

    Draws ONLY the inter-arrival gap — no burst coin — matching the
    historical non-bursty loop draw-for-draw."""
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_hz)
        if t >= horizon:
            return
        yield ArrivalEvent(t, 1, False)


@ARRIVALS.register("burst")
def _burst_arrivals(rng, horizon, *, rate_hz, burst_size, burst_frac):
    """Compound Poisson: each event flips a ``burst_frac`` coin; heads
    delivers ``burst_size`` simultaneous tasks (multi-tenant fan-in).
    The coin is drawn on EVERY event, even when it comes up tails —
    the draw order the legacy bursty loops used."""
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_hz)
        if t >= horizon:
            return
        if rng.random() < burst_frac:
            yield ArrivalEvent(t, int(burst_size), True)
        else:
            yield ArrivalEvent(t, 1, False)


@ARRIVALS.register("trace")
def _trace_arrivals(rng, horizon, *, times, counts=None):
    """Deterministic replay of explicit arrival instants (no RNG).

    ``times`` must be nondecreasing; ``counts`` optionally sizes each
    event (default 1 task). Events at or past the horizon are dropped,
    mirroring the generative processes."""
    prev = float("-inf")
    for i, t in enumerate(times):
        t = float(t)
        if t < prev:
            raise ValueError("trace arrival times must be nondecreasing")
        prev = t
        if t >= horizon:
            continue
        c = 1 if counts is None else int(counts[i])
        yield ArrivalEvent(t, c, c > 1)


# ---------------------------------------------------------------------------
# workload pools
# ---------------------------------------------------------------------------

@WORKLOADS.register("uniform")
def _uniform_pool(*, complexity):
    """Uniform draw over one complexity class (paper §4.1.2)."""
    pool = workload_complexity_class(complexity)

    def draw(rng, event, i):
        return pool[rng.integers(len(pool))]
    return draw


@WORKLOADS.register("mixed_burst")
def _mixed_burst_pool(*, easy, hard, hard_frac, burst_size):
    """Heterogeneous burst pool: the first ``round(hard_frac *
    burst_size)`` members of a burst event (at least one when
    ``hard_frac > 0``) come from the ``hard`` class, the rest — and all
    non-burst arrivals — from ``easy``. The mixed-burst stress shape
    the tiered matcher pipeline is benchmarked on."""
    easy_pool = workload_complexity_class(easy)
    hard_pool = workload_complexity_class(hard)
    n_hard = max(int(round(hard_frac * burst_size)), 1) \
        if hard_frac > 0 else 0

    def draw(rng, event, i):
        pool = hard_pool if (event.burst and i < n_hard) else easy_pool
        return pool[rng.integers(len(pool))]
    return draw


@WORKLOADS.register("named")
def _named_workload(*, name):
    """A single fixed workload by zoo name — consumes no randomness."""
    wl = get_workload(name)

    def draw(rng, event, i):
        return wl
    return draw


# ---------------------------------------------------------------------------
# urgency policies
# ---------------------------------------------------------------------------

@URGENCY.register("bernoulli")
def _bernoulli_urgency(*, urgent_frac):
    """Each task is urgent with probability ``urgent_frac`` (one
    ``rng.random()`` per task)."""
    def draw(rng):
        return rng.random() < urgent_frac
    return draw


@URGENCY.register("never")
def _never_urgent():
    """All tasks background. Consumes NO randomness — composing this
    with any workload pool reproduces loops that never drew an urgency
    coin (the legacy mixed-burst main phase)."""
    def draw(rng):
        return False
    return draw


@URGENCY.register("always")
def _always_urgent():
    """All tasks urgent, no randomness consumed (the legacy
    fragmentation-churn phase)."""
    def draw(rng):
        return True
    return draw


# ---------------------------------------------------------------------------
# deadline policies
# ---------------------------------------------------------------------------

@DEADLINES.register("slack")
def _slack_deadline(*, deadline_slack=2.0, urgent_slack=1.25,
                    base_exec_estimate=5e-3):
    """Slack × nominal-execution-estimate deadlines (paper §4.1.2):
    urgent tasks get the tighter ``urgent_slack`` multiplier."""
    def fn(t, wl, urgent):
        slack = urgent_slack if urgent else deadline_slack
        nominal = base_exec_estimate * (wl.total_macs / 1e9 + 0.2)
        return t + slack * nominal + 1e-3
    return fn


@DEADLINES.register("fixed")
def _fixed_deadline(*, offset):
    """Constant-offset deadlines: ``arrival + offset`` regardless of
    workload size or urgency."""
    def fn(t, wl, urgent):
        return t + float(offset)
    return fn


# ---------------------------------------------------------------------------
# restart schedules
# ---------------------------------------------------------------------------

@RESTARTS.register("none")
def _no_restarts():
    """No scheduler kill/restart events."""
    def transform(tasks, horizon):
        return tasks, horizon, []
    return transform


@RESTARTS.register("at")
def _restarts_at(*, times):
    """Kill/restart the scheduler process at explicit instants.

    Leaves the task list and horizon untouched, so it composes with
    streaming scenarios."""
    def transform(tasks, horizon):
        return tasks, horizon, [float(x) for x in times]
    return transform


@RESTARTS.register("replay")
def _replay_restarts(*, gap=1e-3):
    """Kill at ``horizon + gap`` and replay the EXACT same traffic
    shifted after the kill (the warm-restart stress shape): every
    phase-2 arrival is a repeat the scheduler has already solved.
    Requires a materialized task list (``needs_materialized``)."""
    def transform(tasks, horizon):
        kill_at = horizon + gap
        replay = [dataclasses.replace(t, arrival=t.arrival + kill_at,
                                      deadline=t.deadline + kill_at)
                  for t in tasks]
        return tasks + replay, 2 * horizon + gap, [kill_at]
    transform.needs_materialized = True
    return transform


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------

def _stream_tasks(rng, horizon: float, stream_spec: Dict
                  ) -> Iterator[TaskSpec]:
    """Tasks of one stream definition, drawn from the shared ``rng``.

    Per arrival event, per member ``i``: workload draw, urgency draw,
    deadline computation — the exact per-task draw order of every
    legacy builder loop. ``task_id`` is left at -1 for the scenario /
    simulator to assign in arrival order."""
    wl_draw = WORKLOADS.build(stream_spec["workload"])
    urg_draw = URGENCY.build(stream_spec.get("urgency", {"kind": "never"}))
    ddl = DEADLINES.build(stream_spec.get("deadline", {"kind": "slack"}))
    for ev in ARRIVALS.build(stream_spec["arrival"], rng, horizon):
        for i in range(ev.count):
            wl = wl_draw(rng, ev, i)
            urgent = bool(urg_draw(rng))
            yield TaskSpec(
                name=wl.name, workload=wl, arrival=float(ev.t),
                priority=2 if urgent else 1,
                deadline=float(ddl(ev.t, wl, urgent)),
                urgent=urgent)


def _generate(spec: Dict, rng) -> Iterator[TaskSpec]:
    """All streams of a spec, sequentially, off ONE shared rng."""
    horizon = float(spec["horizon"])
    for stream_spec in spec["streams"]:
        yield from _stream_tasks(rng, horizon, stream_spec)


def _expected_arrivals(spec: Dict) -> int:
    """Rate × horizon estimate for streaming specs (informational;
    benchmarks report it next to the exact admitted count)."""
    horizon = float(spec["horizon"])
    total = 0.0
    for s in spec["streams"]:
        a = s["arrival"]
        if a["kind"] == "poisson":
            total += a["rate_hz"] * horizon
        elif a["kind"] == "burst":
            total += a["rate_hz"] * horizon * \
                (1 + (a["burst_size"] - 1) * a["burst_frac"])
        elif a["kind"] == "trace":
            counts = a.get("counts")
            total += sum(
                (counts[i] if counts is not None else 1)
                for i, t in enumerate(a["times"]) if float(t) < horizon)
    return int(total)


def _default_name(spec: Dict) -> str:
    parts = [f"{s['arrival']['kind']}-{s['workload']['kind']}"
             for s in spec["streams"]]
    name = "+".join(parts)
    return name + "-stream" if spec.get("stream") else name


def scenario_preset(name: str) -> Callable:
    """Resolve a named scenario preset (the legacy ``make_*`` builders).

    Resolution is lazy — the presets live in :mod:`repro.sched.tasks`,
    which itself composes through this module, so neither module imports
    the other at import time."""
    from repro.sched import tasks as _tasks
    presets = {
        "poisson": _tasks.make_scenario,
        "burst": _tasks.make_burst_scenario,
        "mixed_burst": _tasks.make_mixed_burst_scenario,
        "restart": _tasks.make_restart_scenario,
        "streaming": _tasks.make_streaming_scenario,
    }
    try:
        return presets[name]
    except KeyError:
        raise ValueError(f"unknown scenario preset {name!r}; "
                         f"known: {sorted(presets)}") from None


#: Preset names resolvable through ``build_scenario({"preset": ...})``.
SCENARIO_PRESET_NAMES: Tuple[str, ...] = (
    "poisson", "burst", "mixed_burst", "restart", "streaming")


def build_scenario(spec: Dict):
    """Compose a :class:`Scenario` / :class:`StreamScenario` from a spec.

    Spec keys: ``streams`` (list of ``{"arrival", "workload",
    "urgency", "deadline"}`` piece specs — urgency defaults to
    ``never``, deadline to ``slack``), ``horizon``, ``seed``,
    optional ``name``, ``restarts`` (restart-schedule spec, default
    ``none``), ``stream`` (bool: generator-backed scenario;
    single-stream, non-``replay`` restarts only) and
    ``expected_arrivals`` (streaming estimate override). Alternatively
    ``{"preset": name, "args": {...}}`` delegates to a legacy
    ``make_*`` builder. All streams consume one shared
    ``np.random.default_rng(seed)`` in order."""
    if "preset" in spec:
        spec = dict(spec)
        preset = scenario_preset(spec.pop("preset"))
        kwargs = dict(spec.pop("args", {}))
        if spec:
            raise ValueError(
                f"unexpected keys alongside 'preset': {sorted(spec)}")
        return preset(**kwargs)

    horizon = float(spec["horizon"])
    seed = int(spec.get("seed", 0))
    streams = list(spec.get("streams", []))
    if not streams:
        raise ValueError("spec needs at least one entry in 'streams'")
    transform = RESTARTS.build(spec.get("restarts") or {"kind": "none"})
    name = spec.get("name") or _default_name(spec)

    if spec.get("stream"):
        if len(streams) != 1:
            raise ValueError(
                "streaming scenarios take exactly one stream; "
                "materialize multi-stream specs instead")
        if getattr(transform, "needs_materialized", False):
            raise ValueError(
                "restart policy %r rewrites the task list and cannot "
                "back a streaming scenario" % spec["restarts"]["kind"])
        _, _, restart_times = transform([], horizon)
        exp = spec.get("expected_arrivals")
        if exp is None:
            exp = _expected_arrivals(spec)
        frozen = {"horizon": horizon,
                  "streams": copy.deepcopy(streams)}

        def factory() -> Iterator[TaskSpec]:
            return _generate(frozen, np.random.default_rng(seed))

        return StreamScenario(
            name=name, horizon=horizon, arrivals_factory=factory,
            restarts=restart_times, expected_arrivals=exp)

    rng = np.random.default_rng(seed)
    tasks = list(_generate(spec, rng))
    tasks, horizon, restart_times = transform(tasks, horizon)
    return Scenario(name=name, tasks=tasks, horizon=horizon,
                    restarts=restart_times)
