"""Tiered decision pipeline: batched revalidation (Tier 0), similarity
rebase (Tier 1), residual swarm (Tier 2), the two-level carry store under
fragmentation, pre-finished pad slots, mixed-burst scenario generation,
and per-tier scheduler accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.accel import EDGE
from repro.accel.target_graph import (free_engine_graph,
                                      free_engine_signature)
from repro.core import graphs, preemptible_dag, pso
from repro.core.graphs import compatibility_mask
from repro.core.service import CarryStore, MatcherService, ServiceStats
from repro.core.pso import PSOConfig
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.tasks import fixed_scenario, make_mixed_burst_scenario
from repro.workloads import get_workload

jax.config.update("jax_platform_name", "cpu")

CFG = pso.PSOConfig(num_particles=24, epochs=3, inner_steps=8,
                    early_exit=True)


def _planted(seed, n, m, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _check_mapping(mapping, q, g):
    assert mapping is not None
    M = np.asarray(mapping, dtype=np.int64)
    assert (M.sum(axis=1) == 1).all()
    assert (M.sum(axis=0) <= 1).all()
    covered = M @ g.adj.astype(np.int64) @ M.T
    assert (covered >= q.adj).all()


def _stack(pairs):
    Qs, Gs, Ms = [], [], []
    for q, g in pairs:
        Q, G, mask = graphs.as_device_graphs(q, g)
        Qs.append(Q)
        Gs.append(G)
        Ms.append(mask)
    return jnp.stack(Qs), jnp.stack(Gs), jnp.stack(Ms)


def _fastpath_pair(svc, seed, n=6, m=12, max_seeds=40):
    """A planted problem whose stored carry re-validates (Tier-0 hit on
    repeat) through ``svc`` — mirrors bench_batch's 'servable' filter."""
    for s in range(seed, seed + max_seeds):
        q, g = _planted(s, n, m)
        key = jax.random.PRNGKey(s)
        wk = f"fp/{s}"
        r = svc.match(q, g, key=key, workload_key=wk)
        if not r.found:
            continue
        r2 = svc.match(q, g, key=jax.random.PRNGKey(s + 1000),
                       workload_key=wk)
        if r2.tier == 0:
            return (q, g), key, wk
    raise AssertionError("no fast-pathing planted problem found")


# ---------------------------------------------------------------------------
# pso.revalidate_batch
# ---------------------------------------------------------------------------

def test_revalidate_batch_matches_inkernel_fastpath():
    """The Tier-0 kernel must reach the same verdict AND mapping as the
    in-kernel warm-carry fast path for exact carries."""
    pairs = [_planted(s, 6, 12) for s in range(3)]
    Qb, Gb, maskb = _stack(pairs)
    keys = jnp.stack([np.asarray(jax.random.PRNGKey(i)) for i in range(3)])
    cold = pso.match_batch(keys, Qb, Gb, maskb, CFG)
    carry = (cold["S_star"], cold["f_star"], cold["S_bar"])
    rv = pso.revalidate_batch(Qb, Gb, maskb, CFG, carry)
    warm = pso.match_batch(keys, Qb, Gb, maskb, CFG, carry0=carry)
    np.testing.assert_array_equal(np.asarray(rv["ok"]),
                                  np.asarray(warm["carry_feasible"]))
    for b in range(3):
        if np.asarray(rv["ok"])[b]:
            np.testing.assert_array_equal(
                np.asarray(rv["mapping"])[b],
                np.asarray(warm["carry_mapping"])[b])


def test_revalidate_cold_prior_never_validates():
    pairs = [_planted(s, 6, 12) for s in range(2)]
    Qb, Gb, maskb = _stack(pairs)
    rv = pso.revalidate_batch(Qb, Gb, maskb, CFG,
                              pso.default_carry_batch(maskb))
    assert not np.asarray(rv["ok"]).any()


def test_rebased_carry_never_marks_infeasible_found():
    """A carry rebased onto a problem it cannot solve must fail
    revalidation — feasibility is re-checked against the actual Q/G."""
    easy_q, easy_g = _planted(2, 6, 12)
    Qe, Ge, me = graphs.as_device_graphs(easy_q, easy_g)
    keys = jnp.stack([np.asarray(jax.random.PRNGKey(0))])
    cold = pso.match_batch(keys, Qe[None], Ge[None], me[None], CFG)
    assert np.asarray(cold["feasible"]).any()
    carry = (cold["S_star"], cold["f_star"], cold["S_bar"])

    # an infeasible problem in the same shapes: line(6) into line(4)
    hq, hg = graphs.line_graph(6), graphs.line_graph(4)
    mask_h = compatibility_mask(hq, hg)
    Qh, Gh, mh = preemptible_dag.pad_problem(hq.adj, hg.adj, mask_h,
                                             Qe.shape[0], Ge.shape[0])
    rv = pso.revalidate_batch(jnp.asarray(Qh)[None], jnp.asarray(Gh)[None],
                              jnp.asarray(mh)[None], CFG, carry)
    assert not np.asarray(rv["ok"]).any()


def test_rebase_carry_masks_and_renormalizes():
    q, g = _planted(0, 6, 12)
    _, _, mask = graphs.as_device_graphs(q, g)
    carry = pso.default_carry(mask)
    # drop half the columns from the mask; rebase must renormalize rows
    mask2 = np.asarray(mask).copy()
    mask2[:, ::2] = 0
    S_rb, f, S_bar_rb = pso.rebase_carry(carry, jnp.asarray(mask2))
    S_rb = np.asarray(S_rb)
    assert (S_rb[:, ::2] == 0).all()
    rows = S_rb.sum(axis=1)
    np.testing.assert_allclose(rows[np.asarray(mask2).sum(1) > 0], 1.0,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# CarryStore
# ---------------------------------------------------------------------------

def _sig(free):
    return free_engine_signature(np.asarray(free, bool))


def test_carry_store_exact_lru_eviction_order():
    store = CarryStore(capacity=2, sim_capacity=4, stats=ServiceStats())
    store.put("a", 1)
    store.put("b", 2)
    store.get("a")                    # refresh a → b is now oldest
    store.put("c", 3)                 # evicts b
    assert store.get("a") == (1, True)
    assert store.get("b") == (None, False)
    assert store.get("c") == (3, True)
    assert store.stats.warm_evictions == 1


def test_carry_store_similarity_lru_eviction_order():
    stats = ServiceStats()
    store = CarryStore(capacity=4, sim_capacity=2, stats=stats)
    free = np.ones(16, bool)
    sigs = []
    for i in range(3):
        f = free.copy()
        f[i] = False
        sigs.append(_sig(f))
        store.put_similar("q", (8, 16), sigs[-1], carry=i)
    # capacity 2: the first (oldest) entry was evicted
    assert stats.sim_evictions == 1
    assert store.nearest("q", (8, 16), sigs[0]) is not None
    remaining = {s for (qd, bk, s) in store._sim}
    assert sigs[0] not in remaining and remaining == {sigs[1], sigs[2]}


def test_carry_store_nearest_picks_max_overlap():
    store = CarryStore(capacity=4, sim_capacity=8, stats=ServiceStats())
    base = np.zeros(16, bool)
    near = base.copy()
    near[:8] = True                   # 8 engines free
    far = base.copy()
    far[12:14] = True                 # disjoint pair
    store.put_similar("q", (8, 16), _sig(near), carry="near")
    store.put_similar("q", (8, 16), _sig(far), carry="far")
    query = base.copy()
    query[:6] = True                  # overlaps 'near' by 6, 'far' by 0
    got = store.nearest("q", (8, 16), _sig(query))
    assert got is not None and got[1] == "near"
    # disjoint query finds nothing (zero overlap is not a neighbour)
    query2 = base.copy()
    query2[14:16] = True
    assert store.nearest("q", (8, 16), _sig(query2)) is None
    # different workload digest or bucket never matches
    assert store.nearest("other", (8, 16), _sig(query)) is None
    assert store.nearest("q", (16, 32), _sig(query)) is None


# ---------------------------------------------------------------------------
# Service pipeline: drain tiers
# ---------------------------------------------------------------------------

def test_drain_pipeline_serves_warm_via_tier0_and_sizes_swarm_to_misses():
    svc = MatcherService(CFG)
    (q1, g1), k1, w1 = _fastpath_pair(svc, 100)
    (q2, g2), k2, w2 = _fastpath_pair(svc, 200)
    hq, hg = graphs.line_graph(6), graphs.line_graph(4)  # same bucket,
    s0 = svc.stats_dict()                                # infeasible
    res = svc.match_many([(q1, g1), (q2, g2), (hq, hg)],
                         keys=[k1, k2, jax.random.PRNGKey(9)],
                         workload_keys=[w1, w2, "hard"])
    s1 = svc.stats_dict()
    assert res[0].tier == 0 and res[1].tier == 0
    assert res[0].epochs_run == 0 and res[1].epochs_run == 0
    _check_mapping(res[0].mapping, q1, g1)
    _check_mapping(res[1].mapping, q2, g2)
    assert res[2].tier == 2 and not res[2].found
    # ONE revalidation launch for the warm pair...
    assert s1["tier0_launches"] - s0["tier0_launches"] == 1
    assert s1["tier0_hits"] - s0["tier0_hits"] == 2
    # ...and the swarm launch covered ONLY the residual miss
    assert s1["batch_launches"] - s0["batch_launches"] == 1
    assert s1["batch_problems"] - s0["batch_problems"] == 1
    assert res[2].batch_size == 1
    # the whole group still counts as one coalesced decision
    assert s1["coalesced_requests"] - s0["coalesced_requests"] == 3


def test_tiered_drain_matches_untiered_per_problem():
    """Warm or cold, the pipeline must return the same found flags and
    mappings as the untiered uniform-batch drain (PR-2 baseline)."""
    probs = [_planted(s, 6, 12) for s in range(4)]
    keys = [jax.random.PRNGKey(50 + i) for i in range(4)]
    wks = [f"w{i}" for i in range(4)]
    svc_t = MatcherService(CFG, tiered=True)
    svc_u = MatcherService(CFG, tiered=False)
    for svc in (svc_t, svc_u):
        svc.match_many(probs, keys=keys, workload_keys=wks)     # cold
    warm_t = svc_t.match_many(probs, keys=keys, workload_keys=wks)
    warm_u = svc_u.match_many(probs, keys=keys, workload_keys=wks)
    for rt, ru in zip(warm_t, warm_u):
        assert rt.found == ru.found
        assert rt.epochs_run == ru.epochs_run
        if rt.found:
            np.testing.assert_array_equal(np.asarray(rt.mapping),
                                          np.asarray(ru.mapping))


def test_tier1_rebase_after_engine_drift():
    """Same workload, drifted free-engine set (same bucket): the pipeline
    serves it by rebasing the nearest stored carry — 0 epochs, and the
    mapping is feasible on the NEW target."""
    svc = MatcherService(PSOConfig(num_particles=32, epochs=3,
                                   inner_steps=8))
    wl = get_workload("mobilenetv2")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=2)
    q = pd.graph
    rng = np.random.default_rng(0)

    def state(n_busy):
        free = np.ones(EDGE.engines, bool)
        free[rng.choice(EDGE.engines, n_busy, replace=False)] = False
        return free_engine_graph(EDGE, free), free_engine_signature(free)

    tgt_a, sig_a = state(6)
    r1 = svc.match(q, tgt_a, key=jax.random.PRNGKey(0),
                   workload_key=("mb", sig_a))
    assert r1.found
    # drift within the same shape bucket (same free count, different set)
    hit = False
    for trial in range(1, 6):
        tgt_b, sig_b = state(6)
        if sig_b == sig_a:
            continue
        r2 = svc.match(q, tgt_b, key=jax.random.PRNGKey(trial),
                       workload_key=("mb", sig_b))
        assert r2.bucket == r1.bucket
        assert not r2.warm_hit          # content key missed (drift)
        if r2.tier == 1:
            hit = True
            assert r2.epochs_run == 0 and r2.found
            _check_mapping(r2.mapping, q, tgt_b)
            break
    assert hit, "no drifted state was served by a Tier-1 rebase"
    s = svc.stats_dict()
    assert s["sim_neighbor_hits"] >= 1 and s["tier1_hits"] >= 1


def test_tier1_rebase_in_batched_drain():
    """Tier-1 rebases also run inside drain's batched pipeline."""
    svc = MatcherService(PSOConfig(num_particles=32, epochs=3,
                                   inner_steps=8))
    wl = get_workload("mobilenetv2")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=2)
    q = pd.graph
    rng = np.random.default_rng(1)
    free_a = np.ones(EDGE.engines, bool)
    free_a[rng.choice(EDGE.engines, 6, replace=False)] = False
    tgt_a = free_engine_graph(EDGE, free_a)
    sig_a = free_engine_signature(free_a)
    svc.match(q, tgt_a, key=jax.random.PRNGKey(0),
              workload_key=("mb", sig_a))

    served = False
    for trial in range(1, 6):
        free_b = np.ones(EDGE.engines, bool)
        free_b[rng.choice(EDGE.engines, 6, replace=False)] = False
        sig_b = free_engine_signature(free_b)
        if sig_b == sig_a:
            continue
        tgt_b = free_engine_graph(EDGE, free_b)
        svc.submit(q, tgt_b, key=jax.random.PRNGKey(trial),
                   workload_key=("mb", sig_b))
        res = svc.drain()
        if res[0].tier == 1:
            served = True
            assert res[0].epochs_run == 0
            _check_mapping(res[0].mapping, q, tgt_b)
            break
    assert served
    assert svc.stats_dict()["tier1_launches"] >= 1


def test_drain_without_similarity_never_rebases():
    svc = MatcherService(CFG, similarity=False)
    q, g = _planted(0, 6, 12)
    svc.match(q, g, workload_key=("w", b"\x0f"))
    svc.match(q, g, workload_key=("w", b"\xf0"))
    s = svc.stats_dict()
    assert s["sim_lookups"] == 0 and s["tier1_launches"] == 0


# ---------------------------------------------------------------------------
# Pad slots (service.py padded-batch waste fix)
# ---------------------------------------------------------------------------

def test_pad_slots_prefinished_from_epoch_zero():
    """Pad slots run a trivial pre-finished problem: its carry validates
    in epoch 0, so the pad never re-burns problem 0's epoch budget."""
    svc = MatcherService(CFG)
    probs = [_planted(s, 6, 12) for s in range(3)]    # class 4 → 1 pad
    res = svc.match_many(probs,
                         keys=[jax.random.PRNGKey(i) for i in range(3)])
    assert len(res) == 3
    assert svc.stats.pad_slots_frozen == 1

    # pso-level: the trivial pad problem + carry is done at epoch 0
    req0 = svc._prepare(probs[0][0], probs[0][1], None, None)
    pad_req, pad_carry = svc._pad_slot(res[0].bucket, req0, None)
    assert pad_req is not req0
    outs = pso.match(jax.random.PRNGKey(0), jnp.asarray(pad_req.Qp),
                     jnp.asarray(pad_req.Gp), jnp.asarray(pad_req.maskp),
                     CFG, carry0=tuple(jnp.asarray(c) for c in pad_carry))
    assert int(np.asarray(outs["epochs_run"])) == 0
    assert bool(np.asarray(outs["carry_feasible"]))


def test_pad_slot_degenerate_bucket_falls_back_to_replication():
    svc = MatcherService(CFG)
    q, g = _planted(0, 6, 12)
    req = svc._prepare(q, g, None, None)
    like_carry = pso.default_carry(jnp.asarray(req.maskp))
    pad_req, pad_carry = svc._pad_slot((24, 16), req, like_carry)
    assert pad_req is req and pad_carry is like_carry


# ---------------------------------------------------------------------------
# Scenario generator
# ---------------------------------------------------------------------------

def test_make_mixed_burst_scenario_shapes_and_churn():
    sc = make_mixed_burst_scenario("simple", "complex", rate_hz=30,
                                   horizon=0.5, burst_size=6,
                                   hard_frac=0.34, burst_frac=0.9,
                                   churn_rate_hz=20, seed=3)
    from collections import Counter
    by_instant = {}
    for t in sc.tasks:
        by_instant.setdefault(t.arrival, []).append(t)
    sizes = Counter(len(v) for v in by_instant.values())
    assert max(sizes) == 6, "full bursts share one instant"
    from repro.workloads import workload_complexity_class
    easy_names = {w.name for w in workload_complexity_class("simple")}
    hard_names = {w.name for w in workload_complexity_class("complex")}
    mixed = [v for v in by_instant.values() if len(v) == 6]
    assert any({t.name for t in v} & easy_names and
               {t.name for t in v} & hard_names for v in mixed), \
        "bursts must mix easy and hard workloads"
    churn = [t for t in sc.tasks if t.urgent]
    assert churn, "churn stream must produce urgent tasks"
    assert all(t.name in easy_names for t in churn)
    # determinism
    sc2 = make_mixed_burst_scenario("simple", "complex", rate_hz=30,
                                    horizon=0.5, burst_size=6,
                                    hard_frac=0.34, burst_frac=0.9,
                                    churn_rate_hz=20, seed=3)
    assert [(t.name, t.arrival, t.urgent) for t in sc.tasks] == \
           [(t.name, t.arrival, t.urgent) for t in sc2.tasks]


# ---------------------------------------------------------------------------
# Scheduler accounting
# ---------------------------------------------------------------------------

def test_immsched_tier_counters_surface_in_matcher_stats():
    sc = make_mixed_burst_scenario("simple", "simple", rate_hz=40,
                                   horizon=0.4, burst_size=4,
                                   hard_frac=0.0, burst_frac=0.8, seed=2)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    ms = r.matcher_stats
    total = sum(ms[f"sched_tier{i}_decisions"] for i in range(3))
    assert total > 0
    assert ms["sched_tier2_decisions"] > 0          # cold starts swarm
    # repeat traffic on a stable platform state revalidates
    assert ms["sched_tier0_decisions"] + ms["sched_tier1_decisions"] > 0
    from repro.sched.metrics import pipeline_tier_rates
    rates = pipeline_tier_rates(r)
    assert abs(sum(rates[f"sched_tier{i}_rate"] for i in range(3)) - 1.0) \
        < 1e-9


def test_immsched_revalidate_cost_below_swarm_cost():
    from repro.accel import CostModel
    cost = CostModel(EDGE)
    cfg = PSOConfig(num_particles=32, epochs=2, inner_steps=8)
    st_s, se_s = cost.sched_immsched(48, EDGE.engines, cfg, 16)
    st_r, se_r = cost.sched_immsched_revalidate(48, EDGE.engines, 16)
    assert st_r < st_s / 5
    assert se_r < se_s / 5


def test_isosched_memo_warms_repeat_traffic():
    wls = [get_workload("mobilenetv2")] * 4
    sc = fixed_scenario(wls, urgent_last=False)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, get_scheduler("isosched")).run(sc)
    assert r.matcher_stats["memo_hits"] > 0
    assert r.matcher_stats["memo_misses"] >= 1


# ---------------------------------------------------------------------------
# Popcount-bucketed similarity index (PR 4)
# ---------------------------------------------------------------------------

def test_carry_store_index_matches_linear_scan():
    """Property sweep: the popcount-bucketed probe must return exactly
    what the exhaustive linear scan returns — same neighbour, same carry
    — including overwrite/recency ties, exclusions and shape-mismatched
    signatures."""
    rng = np.random.default_rng(0)
    store = CarryStore(capacity=4, sim_capacity=4096, stats=ServiceStats())
    E = 32
    sigs = []
    for i in range(300):
        bits = rng.random(E) < rng.uniform(0.05, 0.95)
        sig = _sig(bits)
        store.put_similar("q", (8, 16), sig, carry=("c", i))
        sigs.append(sig)
    # overwrite some entries (recency tie-break churn)
    for i in rng.choice(len(sigs), 50, replace=False):
        store.put_similar("q", (8, 16), sigs[i], carry=("c2", int(i)))
    # a second workload group and a shorter-signature group: neither may
    # leak into "q"/(8, 16)/32-bit queries
    for i in range(40):
        store.put_similar("other", (8, 16),
                          _sig(rng.random(E) < 0.5), carry=("o", i))
        store.put_similar("q", (8, 16),
                          _sig(rng.random(16) < 0.5), carry=("short", i))
    for trial in range(60):
        q_bits = rng.random(E) < rng.uniform(0.0, 1.0)
        q_sig = _sig(q_bits)
        excl = sigs[int(rng.integers(len(sigs)))] if trial % 3 == 0 else None
        got = store.nearest("q", (8, 16), q_sig, exclude_sig=excl)
        want = store._nearest_linear("q", (8, 16), q_sig, exclude_sig=excl)
        assert got == want
    # exact-signature queries must return their own entry under both paths
    # (an all-zero signature legitimately has no neighbour)
    for i in (0, 17, 123):
        got = store.nearest("q", (8, 16), sigs[i])
        want = store._nearest_linear("q", (8, 16), sigs[i])
        assert got == want
        if np.unpackbits(np.frombuffer(sigs[i], np.uint8)).sum() > 0:
            assert got is not None


def test_carry_store_index_consistent_after_eviction():
    rng = np.random.default_rng(1)
    store = CarryStore(capacity=4, sim_capacity=32, stats=ServiceStats())
    for i in range(200):
        bits = rng.random(24) < 0.5
        store.put_similar(f"q{i % 3}", (8, 16), _sig(bits), carry=i)
    assert store.sim_entries == 32
    indexed = {(qd, bk, sig)
               for (qd, bk, _nb), group in store._sim_buckets.items()
               for bin_ in group.values() for sig in bin_}
    assert indexed == set(store._sim)
    assert set(store._sim_seq) == set(store._sim)
    # probes still agree with the oracle after heavy eviction churn
    for _ in range(20):
        q_sig = _sig(rng.random(24) < 0.5)
        assert store.nearest("q0", (8, 16), q_sig) == \
            store._nearest_linear("q0", (8, 16), q_sig)


def test_carry_store_linear_fallback_flag():
    store = CarryStore(capacity=4, sim_capacity=8, stats=ServiceStats(),
                       sim_index=False)
    free = np.zeros(16, bool)
    free[:8] = True
    store.put_similar("q", (8, 16), _sig(free), carry="a")
    assert store.nearest("q", (8, 16), _sig(free)) == (_sig(free), "a")


# ---------------------------------------------------------------------------
# Calibrated tier predictor + prune-latency accounting (PR 4)
# ---------------------------------------------------------------------------

def _predictor(overlap_bits=12, total=16):
    """An IMMSchedScheduler with one remembered platform state and a query
    signature overlapping it by ``overlap_bits``/``total``."""
    from repro.sched.schedulers import IMMSchedScheduler
    sched = IMMSchedScheduler()
    sched._state_index = {}
    sched._tier1_obs = {}
    stored = np.zeros(total, bool)
    stored[:overlap_bits] = True
    sched._note_state("w", free_engine_signature(stored))
    query = np.zeros(total, bool)
    query[:overlap_bits] = True
    query[overlap_bits:] = False
    query[-2:] = True                      # drifted free set, high overlap
    return sched, free_engine_signature(query)


def test_tier1_predictor_flips_on_observed_failures():
    sched, sig = _predictor()
    assert sched._predict_tier("w", sig) == 1      # prior 2/3 ≥ 0.5
    sched._note_tier1_outcome("w", sig, False)
    sched._note_tier1_outcome("w", sig, False)     # posterior 2/5 < 0.5
    assert sched._predict_tier("w", sig) == 2
    for _ in range(4):
        sched._note_tier1_outcome("w", sig, True)  # 6/9 ≥ 0.5 again
    assert sched._predict_tier("w", sig) == 1
    # unrelated workloads keep the prior
    sched._note_state("other", sig)
    assert sched._predict_tier("other", sig) == 0  # exact state stored


def test_tier1_calibration_is_bucketed_by_signature_popcount():
    sched, sig = _predictor()
    # drive this bucket's posterior below 0.5 ...
    sched._note_tier1_outcome("w", sig, False)
    sched._note_tier1_outcome("w", sig, False)
    assert sched._tier1_success_prob("w", sig) < 0.5
    # ... a very different free-set size lands in another bucket and
    # still sees the prior
    small = np.zeros(16, bool)
    small[:2] = True
    assert sched._tier1_success_prob("w", free_engine_signature(small)) \
        >= 0.5


def test_immsched_charges_prune_for_cold_swarm_decisions():
    wls = [get_workload("mobilenetv2"), get_workload("resnet50")]
    sc = fixed_scenario(wls, urgent_last=False)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    ms = r.matcher_stats
    # cold arrivals predict Tier 2 → every swarm charge pays the fused
    # pre-prune on top, surfaced via the sched_prune_* counters
    assert ms["sched_tier2_decisions"] > 0
    assert ms["sched_prune_launches"] > 0
    assert ms["sched_prune_wall_s"] > 0
    assert ms["sched_tier1_calib_trials"] == 0     # analytic mode: no obs
    from repro.sched.metrics import pipeline_tier_rates
    rates = pipeline_tier_rates(r)
    assert rates["sched_prune_launches"] == ms["sched_prune_launches"]


def test_prune_cost_scales_with_sweeps():
    from repro.accel import CostModel
    cost = CostModel(EDGE)
    st1, se1 = cost.sched_immsched_prune(48, EDGE.engines, 16, sweeps=1)
    st8, se8 = cost.sched_immsched_prune(48, EDGE.engines, 16, sweeps=8)
    assert st8 > st1 and se8 > se1
    # the pre-prune is far below a swarm launch (it must never dominate
    # the Tier-2 charge it rides on)
    cfg = PSOConfig(num_particles=32, epochs=2, inner_steps=8)
    st_s, _ = cost.sched_immsched(48, EDGE.engines, cfg, 16)
    assert st8 < st_s


def test_service_surfaces_prune_sweeps():
    svc = MatcherService(CFG)
    q, g = _planted(0, 6, 12)
    res = svc.match(q, g, workload_key="prune/w")
    assert res.prune_sweeps >= 1
    sd = svc.stats_dict()
    assert sd["prune_problems"] == 1
    assert sd["prune_sweeps"] == res.prune_sweeps
    assert sd["avg_prune_sweeps"] == pytest.approx(res.prune_sweeps)
    # prune accounting also covers drained (batched) traffic
    svc.submit(q, g, workload_key="prune/w2")
    q2, g2 = _planted(1, 6, 12)
    svc.submit(q2, g2, workload_key="prune/w3")
    svc.drain()
    assert svc.stats_dict()["prune_problems"] >= 3


def test_tier1_calibration_recovers_from_absorbed_bucket():
    """A bucket whose posterior dropped below 0.5 is predicted Tier-2, so
    no Tier-1 predictions (and naively no observations) would ever flow
    again; verified-rebase serves of predicted-Tier-2 decisions must
    re-open it."""
    from types import SimpleNamespace
    sched, sig = _predictor()
    sched._note_tier1_outcome("w", sig, False)
    sched._note_tier1_outcome("w", sig, False)
    assert sched._predict_tier("w", sig) == 2      # absorbed (2/5 < 0.5)
    served_by_rebase = SimpleNamespace(found=True, tier=1)
    for _ in range(4):
        sched._calibrate_tier1([("w", sig, 2)], [served_by_rebase])
    assert sched._predict_tier("w", sig) == 1      # 6/9 ≥ 0.5: recovered
    # neutral evidence never moves the posterior: Tier-0 serves, cold
    # swarm serves, and skipped launches
    before = dict(sched._tier1_obs)
    sched._calibrate_tier1(
        [("w", sig, 2), ("w", sig, 2), ("w", sig, 0)],
        [SimpleNamespace(found=True, tier=0),
         SimpleNamespace(found=True, tier=2), None])
    assert dict(sched._tier1_obs) == before
