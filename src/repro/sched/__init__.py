from repro.sched.tasks import (TaskSpec, Scenario, StreamScenario,
                               make_burst_scenario,
                               make_mixed_burst_scenario,
                               make_restart_scenario, make_scenario,
                               make_streaming_scenario)
from repro.sched.registry import (ARRIVALS, DEADLINES, RESTARTS, URGENCY,
                                  WORKLOADS, build_scenario)
from repro.sched.simulator import (Simulator, SimConfig, SimResult,
                                   TaskTable)
from repro.sched.schedulers import (SCHEDULERS, IMMSchedScheduler,
                                    IsoSchedScheduler, LTSScheduler,
                                    get_scheduler)
from repro.sched.metrics import (frontend_stats, latency_bound_throughput,
                                 pipeline_tier_rates, speedup_table,
                                 energy_efficiency)
