"""Version-compat shims for Pallas TPU symbols.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` on 0.4.x, ``CompilerParams`` later). Kernel modules
import ``CompilerParams`` from here instead of reaching into
``jax.experimental.pallas.tpu`` directly.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:
    CompilerParams = pltpu.TPUCompilerParams
