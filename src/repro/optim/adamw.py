"""AdamW over arbitrary pytrees, with configurable moment dtype.

``state_dtype="bfloat16"`` halves optimizer HBM (the production memory
policy for the giant archs — see DESIGN.md §5); moments are upcast to f32
inside the update, so the math is unchanged up to storage rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import DTYPES


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params, lr) -> (new_params, state)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          state_dtype: str = "float32") -> Optimizer:
    sdt = DTYPES[state_dtype]

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params)
        return {"m": zeros,
                "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            mhat = m32 / c1
            vhat = v32 / c2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p.ndim >= 2:   # no decay on norms/biases
                step = step + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init=init, update=update)
