"""Task specifications + scenario generation for the multi-DNN simulator.

A *scenario* is a timed stream of DNN task instances: background tasks
(periodic/known, what LTS schedulers were designed for) plus *urgent* tasks
with unpredictable (Poisson) arrivals and tight deadlines — the open-ended
setting the paper targets.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np

from repro.workloads import WorkloadGraph


@dataclasses.dataclass
class TaskSpec:
    name: str
    workload: WorkloadGraph
    arrival: float
    priority: int               # higher = more urgent
    deadline: float             # absolute seconds
    urgent: bool = False
    task_id: int = -1


@dataclasses.dataclass
class Scenario:
    name: str
    tasks: List[TaskSpec]
    horizon: float
    #: Scheduler-process kill/restart instants (seconds). At each time the
    #: simulator delivers an ``on_restart`` to the scheduler: its host
    #: process state (compile caches, warm carries, predictor history,
    #: host-CPU queue) dies; tasks already running on the accelerator
    #: keep their engines. With ``SimConfig.persist_dir`` set the
    #: scheduler snapshots before dying and restores after — the
    #: warm-restart path this repo's persistence layer exists for.
    restarts: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.tasks.sort(key=lambda t: t.arrival)
        tasks = []
        for i, t in enumerate(self.tasks):
            if t.task_id not in (-1, i):
                # re-materializing tasks that already belong to another
                # scenario (registry specs, scenario surgery in tests):
                # renumber a COPY so the donor scenario's ids survive —
                # mutating foreign TaskSpecs here silently corrupted the
                # donor's task table
                t = dataclasses.replace(t, task_id=i)
            else:
                t.task_id = i
            tasks.append(t)
        self.tasks = tasks
        self.restarts = sorted(float(r) for r in self.restarts)

    def arrivals_iter(self) -> Iterator[TaskSpec]:
        """Arrival-ordered task stream — the seam the simulator's
        streaming event loop consumes. For a materialized scenario this
        just walks the (already sorted) task list; ``StreamScenario``
        provides the generator-backed equivalent."""
        return iter(self.tasks)


@dataclasses.dataclass
class StreamScenario:
    """A scenario whose tasks are *generated*, not materialized.

    ``arrivals_factory`` returns a fresh arrival-ordered
    ``Iterator[TaskSpec]`` each time ``arrivals_iter`` is called, so one
    StreamScenario can be replayed across schedulers exactly like a
    list-based :class:`Scenario` — but the simulator only ever holds the
    tasks that are currently live, which is what lets a run replay
    millions of arrivals at bounded memory. Task ids are assigned by the
    simulator in arrival order (the factory must yield tasks with
    nondecreasing ``arrival``)."""
    name: str
    horizon: float
    arrivals_factory: Callable[[], Iterator[TaskSpec]]
    restarts: List[float] = dataclasses.field(default_factory=list)
    #: rate × horizon estimate; purely informational (benchmarks report
    #: it next to the exact admitted count)
    expected_arrivals: Optional[int] = None

    def __post_init__(self):
        self.restarts = sorted(float(r) for r in self.restarts)

    def arrivals_iter(self) -> Iterator[TaskSpec]:
        """Fresh arrival-ordered generator over the task stream."""
        return self.arrivals_factory()


def _poisson_stream_spec(complexity: str, *, rate_hz: float = 20.0,
                         horizon: float = 2.0, urgent_frac: float = 0.4,
                         deadline_slack: float = 2.0,
                         urgent_slack: float = 1.25,
                         base_exec_estimate: float = 5e-3,
                         burst_size: int = 1, burst_frac: float = 0.0,
                         seed: int = 0, stream: bool = False) -> dict:
    """Registry spec for the canonical single-class Poisson stream.

    The shared core of :func:`make_scenario`,
    :func:`make_streaming_scenario` and :func:`make_restart_scenario`.
    Non-bursty knobs select the plain ``poisson`` arrival process (no
    burst coin draws), bursty knobs the compound ``burst`` one — the
    same gating the historical loop applied, so the registry path draws
    the RNG identically."""
    bursty = burst_frac > 0.0 and burst_size > 1
    arrival = ({"kind": "burst", "rate_hz": rate_hz,
                "burst_size": burst_size, "burst_frac": burst_frac}
               if bursty else {"kind": "poisson", "rate_hz": rate_hz})
    name = (f"{complexity}-burst{burst_size}" if bursty
            else f"{complexity}-poisson")
    if stream:
        name += "-stream"
    return {
        "name": name, "seed": seed, "horizon": horizon, "stream": stream,
        "streams": [{
            "arrival": arrival,
            "workload": {"kind": "uniform", "complexity": complexity},
            "urgency": {"kind": "bernoulli", "urgent_frac": urgent_frac},
            "deadline": {"kind": "slack",
                         "deadline_slack": deadline_slack,
                         "urgent_slack": urgent_slack,
                         "base_exec_estimate": base_exec_estimate},
        }],
    }


def _poisson_task_stream(complexity: str, *, rate_hz: float,
                         horizon: float, urgent_frac: float,
                         deadline_slack: float, urgent_slack: float,
                         base_exec_estimate: float, burst_size: int,
                         burst_frac: float, seed: int
                         ) -> Iterator[TaskSpec]:
    """Generator behind :func:`make_scenario` / streaming scenarios.

    Backed by the scenario registry's composed pieces, which draw the
    RNG in exactly the order the historical list-building loop did
    (inter-arrival gap, burst coin, then per-task workload/urgency
    draws), so ``list(_poisson_task_stream(...))`` is byte-identical to
    the tasks of the materialized scenario with the same knobs — the
    property ``make_streaming_scenario`` relies on. Yields tasks with
    nondecreasing ``arrival``; ``task_id`` is left at -1 for the
    simulator to assign in arrival order."""
    from repro.sched.registry import _generate
    spec = _poisson_stream_spec(
        complexity, rate_hz=rate_hz, horizon=horizon,
        urgent_frac=urgent_frac, deadline_slack=deadline_slack,
        urgent_slack=urgent_slack, base_exec_estimate=base_exec_estimate,
        burst_size=burst_size, burst_frac=burst_frac, seed=seed)
    return _generate(spec, np.random.default_rng(seed))


def make_scenario(complexity: str, *, rate_hz: float = 20.0,
                  horizon: float = 2.0, urgent_frac: float = 0.4,
                  deadline_slack: float = 2.0,
                  urgent_slack: float = 1.25,
                  base_exec_estimate: float = 5e-3,
                  burst_size: int = 1, burst_frac: float = 0.0,
                  seed: int = 0) -> Scenario:
    """Poisson stream over one complexity class (paper §4.1.2).

    ``deadline_slack`` multiplies a nominal execution estimate to set
    deadlines; urgent tasks get the tighter ``urgent_slack``.

    ``burst_size``/``burst_frac`` turn the stream compound-Poisson: with
    probability ``burst_frac`` an arrival event delivers ``burst_size``
    tasks at the SAME instant (multi-tenant request fan-in — the case the
    coalescing matcher service batches into one launch). A thin preset
    over :func:`repro.sched.registry.build_scenario`; the registry path
    draws exactly the legacy RNG stream, so scenarios are byte-identical
    to historical output (golden-seed tested).
    """
    from repro.sched.registry import build_scenario
    return build_scenario(_poisson_stream_spec(
        complexity, rate_hz=rate_hz, horizon=horizon,
        urgent_frac=urgent_frac, deadline_slack=deadline_slack,
        urgent_slack=urgent_slack, base_exec_estimate=base_exec_estimate,
        burst_size=burst_size, burst_frac=burst_frac, seed=seed))


def make_streaming_scenario(complexity: str, *, rate_hz: float = 20.0,
                            horizon: float = 2.0,
                            urgent_frac: float = 0.4,
                            deadline_slack: float = 2.0,
                            urgent_slack: float = 1.25,
                            base_exec_estimate: float = 5e-3,
                            burst_size: int = 1,
                            burst_frac: float = 0.0,
                            seed: int = 0) -> StreamScenario:
    """Streaming twin of :func:`make_scenario`: same knobs, same RNG
    draws, but tasks are generated on demand instead of materialized, so
    ``rate_hz * horizon`` can be millions without holding millions of
    TaskSpecs. ``make_streaming_scenario(...)`` replayed through the
    simulator is byte-identical to ``make_scenario(...)`` with the same
    arguments (tested in tests/test_scale.py)."""
    from repro.sched.registry import build_scenario
    bursty = burst_frac > 0.0 and burst_size > 1
    spec = _poisson_stream_spec(
        complexity, rate_hz=rate_hz, horizon=horizon,
        urgent_frac=urgent_frac, deadline_slack=deadline_slack,
        urgent_slack=urgent_slack, base_exec_estimate=base_exec_estimate,
        burst_size=burst_size, burst_frac=burst_frac, seed=seed,
        stream=True)
    spec["expected_arrivals"] = int(rate_hz * horizon *
                                    (1 + (burst_size - 1) * burst_frac
                                     if bursty else 1))
    return build_scenario(spec)


def make_burst_scenario(complexity: str, *, burst_size: int = 4,
                        burst_frac: float = 0.5, **kw) -> Scenario:
    """Compound-Poisson burst stream: a ``burst_frac`` fraction of arrival
    events deliver ``burst_size`` simultaneous tasks (PREMA's consolidated
    multi-tenant NPU setting). All other knobs pass through to
    ``make_scenario``."""
    return make_scenario(complexity, burst_size=burst_size,
                         burst_frac=burst_frac, **kw)


def make_mixed_burst_scenario(easy: str = "simple", hard: str = "complex",
                              *, rate_hz: float = 20.0,
                              horizon: float = 2.0,
                              burst_size: int = 8,
                              hard_frac: float = 0.25,
                              burst_frac: float = 0.7,
                              churn_rate_hz: float = 0.0,
                              deadline_slack: float = 2.0,
                              urgent_slack: float = 1.25,
                              base_exec_estimate: float = 5e-3,
                              seed: int = 0) -> Scenario:
    """Heterogeneous easy/hard bursts + engine-fragmentation churn.

    The stress scenario for the tiered matcher pipeline: with probability
    ``burst_frac`` an arrival event delivers ``burst_size`` simultaneous
    tasks of which a ``hard_frac`` fraction come from the ``hard``
    complexity class and the rest from ``easy`` — the mixed burst where a
    uniform batched matcher pays the hard subset's max-epochs for every
    member, but the tiered drain serves the easy majority at revalidation
    cost and sizes the swarm to the hard residue.

    ``churn_rate_hz`` adds an independent Poisson stream of small *urgent*
    ``easy``-class tasks with tight deadlines: their preemptions churn the
    free-engine set (PREMA-style fragmentation), so repeat arrivals see
    drifted platform states — exact content-keyed warm carries miss and
    only Tier-1 similarity rebases keep the warm hit rate up.
    """
    from repro.sched.registry import build_scenario
    deadline = {"kind": "slack", "deadline_slack": deadline_slack,
                "urgent_slack": urgent_slack,
                "base_exec_estimate": base_exec_estimate}
    streams = [{
        # the main phase always flips the burst coin (burst_frac may be
        # 0) and never draws an urgency coin — tasks are background
        "arrival": {"kind": "burst", "rate_hz": rate_hz,
                    "burst_size": burst_size, "burst_frac": burst_frac},
        "workload": {"kind": "mixed_burst", "easy": easy, "hard": hard,
                     "hard_frac": hard_frac, "burst_size": burst_size},
        "urgency": {"kind": "never"},
        "deadline": deadline,
    }]
    if churn_rate_hz > 0:
        streams.append({
            "arrival": {"kind": "poisson", "rate_hz": churn_rate_hz},
            "workload": {"kind": "uniform", "complexity": easy},
            "urgency": {"kind": "always"},
            "deadline": deadline,
        })
    return build_scenario({
        "name": f"mixed-{easy}-{hard}-burst{burst_size}",
        "seed": seed, "horizon": horizon, "streams": streams})


def make_restart_scenario(complexity: str = "simple", *,
                          rate_hz: float = 20.0,
                          phase_horizon: float = 0.5,
                          burst_size: int = 4,
                          burst_frac: float = 0.6,
                          urgent_frac: float = 0.4,
                          restart_gap: float = 1e-3,
                          seed: int = 0, **kw) -> Scenario:
    """Kill/restart stress scenario: identical traffic before and after.

    Phase 1 is a compound-Poisson burst stream over ``[0,
    phase_horizon)``; the scheduler process is killed at
    ``phase_horizon`` (+ ``restart_gap``, so in-flight same-instant
    arrivals land before the kill) and phase 2 **replays the exact same
    workloads and burst pattern** shifted after the restart. Every
    phase-2 arrival is therefore a repeat the scheduler has already
    solved — a warm-restarted scheduler (``SimConfig.persist_dir``)
    serves them from restored carries/posteriors at revalidation cost,
    while a cold restart pays the full first-arrival path again. The
    cold-vs-warm gap in post-restart scheduling latency / deadline tail
    is exactly what ``benchmarks/bench_restart.py`` measures.

    Extra ``kw`` pass through to :func:`make_scenario` (both phases).
    """
    from repro.sched.registry import build_scenario
    spec = _poisson_stream_spec(
        complexity, rate_hz=rate_hz, horizon=phase_horizon,
        urgent_frac=urgent_frac, burst_size=burst_size,
        burst_frac=burst_frac, seed=seed, **kw)
    spec["name"] += "-restart"
    spec["restarts"] = {"kind": "replay", "gap": restart_gap}
    return build_scenario(spec)


def fixed_scenario(workloads: Sequence[WorkloadGraph], *,
                   spacing: float = 1e-3,
                   urgent_last: bool = True,
                   deadline_slack: float = 3.0,
                   base_exec_estimate: float = 5e-3) -> Scenario:
    """Deterministic small scenario (tests + speedup benchmark): background
    tasks arrive at t≈0, one urgent task arrives mid-flight."""
    tasks = []
    for i, wl in enumerate(workloads):
        urgent = urgent_last and (i == len(workloads) - 1)
        arrival = 0.0 + i * spacing if not urgent else 0.5e-3 + i * spacing
        nominal = base_exec_estimate * (wl.total_macs / 1e9 + 0.2)
        tasks.append(TaskSpec(
            name=wl.name, workload=wl, arrival=arrival,
            priority=2 if urgent else 1,
            deadline=arrival + deadline_slack * nominal + 1e-3,
            urgent=urgent))
    horizon = max(t.deadline for t in tasks) * 4.0
    return Scenario(name="fixed", tasks=tasks, horizon=horizon)
