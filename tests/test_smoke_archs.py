"""Per-architecture smoke tests: reduced configs of the same family run one
forward (train) + prefill + decode step on CPU; output shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import MLAConfig, MoEConfig, SSMConfig
from repro.models import build_model

jax.config.update("jax_platform_name", "cpu")

B, S, VOCAB = 2, 32, 256


def reduce_config(cfg):
    """Shrink every dimension while preserving the family's structure."""
    kw = dict(num_layers=2, d_model=64, num_heads=4, kv_heads=2,
              d_ff=128, vocab_size=VOCAB, compute_dtype="float32",
              param_dtype="float32", remat="none")
    if cfg.family == "ssm":      # xlstm: layers % slstm_period == 0
        kw.update(num_layers=4, kv_heads=4,
                  ssm=SSMConfig(kind="xlstm", expand=2, conv_dim=4,
                                chunk=8, slstm_period=2))
    if cfg.family == "hybrid":   # zamba2: groups of period + tail
        kw.update(num_layers=5, kv_heads=4,
                  ssm=SSMConfig(kind="mamba2", state_dim=8, expand=2,
                                conv_dim=4, chunk=8, shared_attn_period=2))
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=8, top_k=2, expert_d_ff=32,
            shared_experts=min(cfg.moe.shared_experts, 1),
            dense_residual_d_ff=32 if cfg.moe.dense_residual_d_ff else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=16, q_lora_rank=24,
                              rope_head_dim=4, nope_head_dim=8,
                              v_head_dim=8)
    if cfg.mrope:
        kw["mrope_sections"] = (2, 3, 3)   # head_dim 16 -> half 8
    if cfg.family in ("encdec", "audio"):
        kw["encoder_layers"] = 2
    return cfg.replace(**kw)


def make_batch(cfg, mode: str, key):
    ks = jax.random.split(key, 4)
    batch = {}
    s_text = S
    if mode == "decode":
        batch["tokens"] = jax.random.randint(ks[0], (B, 1), 0, VOCAB)
        if cfg.mrope:
            batch["positions3"] = jnp.zeros((3, B, 1), jnp.int32)
        return batch
    if cfg.family == "vlm":
        n_patch = 8
        s_text = S - n_patch
        batch["patches"] = jax.random.normal(ks[1], (B, n_patch,
                                                     cfg.d_model))
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(ks[2], (B, 16, cfg.d_model))
    batch["tokens"] = jax.random.randint(ks[0], (B, s_text), 0, VOCAB)
    if cfg.mrope:
        Sfull = S
        pos = jnp.broadcast_to(jnp.arange(Sfull, dtype=jnp.int32)[None],
                               (B, Sfull))
        batch["positions3"] = jnp.broadcast_to(pos[None], (3, B, Sfull))
    if mode == "train":
        batch["labels"] = jax.random.randint(ks[3], (B, s_text), 0, VOCAB)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_forward(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, "train", jax.random.PRNGKey(1))
    logits = jax.jit(model.train_logits)(params, batch)
    exp_seq = batch["tokens"].shape[1] + (8 if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_seq, VOCAB)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_then_decode(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, "prefill", jax.random.PRNGKey(1))
    max_len = S + 8
    logits, caches = jax.jit(model.prefill,
                             static_argnames=("max_len",))(
        params, batch, max_len=max_len)
    assert logits.shape == (B, 1, VOCAB)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()

    step = make_batch(cfg, "decode", jax.random.PRNGKey(2))
    prefill_len = batch["tokens"].shape[1] + (
        8 if cfg.family == "vlm" else 0)
    logits2, caches2 = jax.jit(model.decode)(params, step, caches,
                                             jnp.int32(prefill_len))
    assert logits2.shape == (B, 1, VOCAB)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all(), arch
    # caches keep their structure
    jax.tree.map(lambda a, b: None
                 if a.shape == b.shape else pytest.fail("cache shape"),
                 caches, caches2)


def test_decode_matches_prefill_logits():
    """Teacher-forcing consistency on a dense arch: running prefill over
    t tokens then decoding token t+1 must equal prefilling t+1 tokens."""
    cfg = reduce_config(get_config("llama3-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 9), 0, VOCAB)
    max_len = 16
    lg_full, _ = model.prefill(params, {"tokens": toks}, max_len=max_len)
    _, caches = model.prefill(params, {"tokens": toks[:, :8]},
                              max_len=max_len)
    lg_step, _ = model.decode(params, {"tokens": toks[:, 8:9]}, caches,
                              jnp.int32(8))
    np.testing.assert_allclose(np.asarray(lg_full[:, 0]),
                               np.asarray(lg_step[:, 0]),
                               rtol=2e-4, atol=2e-4)
