"""Schedulers: IMMSched + the five baselines of the paper's evaluation.

Every scheduler implements ``on_event(sim, now, tasks, trigger, arrived)``
and returns a decision dict::

    {"alloc":   {task_id: [engine ids]},
     "preempt": [task_id, ...],
     "delay":   {task_id: seconds},       # scheduling latency seen by task
     "energy":  joules}                   # scheduling energy

Protocol: ``arrival``/``completion`` triggers may charge scheduling cost
(latency via "delay" + energy); ``activate`` triggers are cost-free
dispatch of tasks whose scheduling delay has elapsed. Engines freed for a
delayed urgent task are *reserved* until it activates so preempted victims
cannot bounce back onto them.

``arrived`` is the LIST of all tasks that became schedulable at this
instant (the simulator coalesces simultaneous/burst arrivals into one
event). IMMSched makes one batched matching decision for the burst and
charges its latency once. IsoSched's serial host matcher processes the
burst one problem at a time, queueing on the single CPU. LTS baselines
re-solve their global layout/priority state once per event — one
re-solve covers the burst, the conservative (cheapest-for-baseline)
reading of how those frameworks respond to a scheduling trigger.

Paradigms:
  * IMMSched      — TSS, interruptible: subgraph matching ON the accelerator
                    (parallel PSO-Ullmann; μs-scale), adaptive preemption
                    ratio + largest-slack victim selection.
  * IsoSched-like — TSS, preemptive: *serial* Ullmann matching on the host
                    CPU (ms-scale, grows with query size).
  * PREMA-like    — LTS, exclusive array, token-priority time-multiplexing.
  * Planaria-like — LTS, spatial fission, heavy online layout search.
  * MoCA-like     — LTS, fission + memory-contention awareness.
  * CD-MSA-like   — LTS, EDF cooperative with cross-layer overlap.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core import interrupts, preemptible_dag, ullmann
from repro.core.graphs import compatibility_mask
from repro.core.service import MatcherService
from repro.accel.target_graph import (free_engine_graph,
                                      free_engine_signature,
                                      signature_bits)

_EPS = 1e-15


def _empty_decision():
    return {"alloc": {}, "preempt": [], "delay": {}, "energy": 0.0}


class SchedulerBase:
    name = "base"
    paradigm = "tss"
    overlap = 0.0

    def reset(self, sim):
        self.cpu_free_at = 0.0
        self._pdag_cache: Dict = {}
        self._reserved: Dict[int, List[int]] = {}   # task_id -> engines
        self._restart_count = 0

    def matcher_stats(self) -> Dict[str, float]:
        """Online matcher-service counters; {} for schedulers without one."""
        return {}

    def check_invariants(self, result) -> None:
        """End-of-run cross-checks, called by the simulator on the
        finished :class:`SimResult` when ``SimConfig.validate`` is set.

        Base check: no registered scheduler ever double-books an engine
        (``alloc_conflicts == 0``) — the simulator counts conflicts
        rather than crashing so hostile test schedulers can probe the
        counter, but every real policy must stay clean. Subclasses add
        their own accounting invariants on top (IMMSched: per-tier
        decision counts sum to matcher decisions). Raises
        ``AssertionError`` on violation."""
        assert result.alloc_conflicts == 0, \
            f"{self.name}: {result.alloc_conflicts} engine " \
            "double-bookings in a conflict-free scheduler"

    def on_restart(self, sim, now: float) -> None:
        """Scheduler-process kill/restart at ``now`` (simulator event).

        Base semantics: everything living in the scheduler's host
        process dies — the query-window cache, engine reservations (the
        accelerator keeps running its dispatched tasks; only the
        scheduler's bookkeeping of promised engines is lost) and any
        queued host-CPU scheduling work (a fresh process has a free
        CPU). Subclasses lose their matcher/memo state on top, and
        IMMSched snapshots/restores through the persistence layer when
        ``sim.cfg.persist_dir`` is set."""
        self._restart_count += 1
        self._pdag_cache.clear()
        self._reserved.clear()
        self.cpu_free_at = now

    # -- engine bookkeeping ------------------------------------------------

    def _free_engines(self, sim, tasks) -> List[int]:
        used: Set[int] = set()
        for t in tasks:
            if t.status == "running":
                used.update(t.engines)
        # drop stale reservations, keep live ones out of the free pool
        # (a reserved task may have finished and left the live table)
        for tid in list(self._reserved):
            try:
                alive = tasks[tid].status == "ready"
            except (KeyError, IndexError):
                alive = False
            if not alive:
                del self._reserved[tid]
        for engines in self._reserved.values():
            used.update(engines)
        return [e for e in range(sim.platform.engines) if e not in used]

    def _waiting(self, tasks):
        return sorted([t for t in tasks if t.status == "ready"],
                      key=lambda t: (-t.spec.priority, t.spec.arrival))

    def _dispatch(self, sim, now, tasks, decision=None):
        """Cost-free work-conserving dispatch of ready, delay-elapsed tasks:
        reserved engines first, then the free pool."""
        decision = decision or _empty_decision()
        free = self._free_engines(sim, tasks)
        for v in decision["alloc"].values():
            free = [e for e in free if e not in set(v)]
        for t in self._waiting(tasks):
            if t.spec.task_id in decision["alloc"]:
                continue
            if now < t.ready_at - _EPS or \
                    t.spec.task_id in decision["delay"]:
                continue
            engines = self._reserved.pop(t.spec.task_id, [])
            engines = [e for e in engines
                       if e in free or e not in self._all_running(tasks)]
            if not engines:
                if not free:
                    continue
                engines = free[:min(t.par_cap, len(free))]
            engines = engines[:t.par_cap]
            free = [e for e in free if e not in set(engines)]
            if engines:
                decision["alloc"][t.spec.task_id] = engines
        return decision

    @staticmethod
    def _all_running(tasks) -> Set[int]:
        out: Set[int] = set()
        for t in tasks:
            if t.status == "running":
                out.update(t.engines)
        return out

    # -- query-window construction ------------------------------------------

    def _pdag(self, sim, task):
        key = (task.spec.name, sim.cfg.window_stages)
        if key not in self._pdag_cache:
            cap = sim.platform.engine_tile_capacity_macs()
            self._pdag_cache[key] = preemptible_dag.build_preemptible_dag(
                [(task.spec.task_id, task.spec.workload, 0)],
                tile_capacity_macs=cap,
                window_stages=sim.cfg.window_stages)
        return self._pdag_cache[key]

    def _window_tiles(self, sim, task) -> int:
        return max(self._pdag(sim, task).n, 1)


# ---------------------------------------------------------------------------
# TSS schedulers
# ---------------------------------------------------------------------------

class IMMSchedScheduler(SchedulerBase):
    """TSS, interruptible, with the *tiered* matcher pipeline's latency
    accounting: every matching decision is first a cheap revalidation
    (Tier 0/1 — one projection on the accelerator), and only predicted
    warm misses (the hard subset of a burst) pay for a swarm launch.
    The predictor mirrors the service's carry store: a (workload,
    free-engine signature) pair seen before is a Tier-0 hit; the same
    workload on a sufficiently-overlapping engine set is a Tier-1 rebase;
    anything else swarms (Tier 2)."""
    name = "immsched"
    paradigm = "tss"

    _SIG_MEMORY = 64                 # platform states remembered per task
    _REBASE_OVERLAP = 0.5            # min engine-set overlap for a Tier-1
                                     # rebase prediction
    _T1_PRIOR = (2, 3)               # pseudo-counts behind the analytic
                                     # ≥50%-overlap heuristic (2/3 prior
                                     # success); real-mode outcomes shift
                                     # the posterior per (workload,
                                     # engine-signature) bucket
    _T1_PC_BUCKET = 8                # popcount band width of the bucket
    _PRUNE_SWEEPS = 4                # assumed fused pre-prune iterations
                                     # until real launches calibrate it

    def __init__(self, quantized: bool = True):
        self.quantized = quantized
        self._service: Optional[MatcherService] = None

    def reset(self, sim):
        super().reset(sim)
        self._tier_decisions = {"tier0": 0, "tier1": 0, "tier2": 0}
        # every task routed through the tier predictor (normal bursts +
        # urgent interrupts); check_invariants pins the per-tier split
        # to this total
        self._matcher_decisions = 0
        self._restart_stats = {"restored_carries": 0,
                               "restored_sim_entries": 0,
                               "restored_posterior_buckets": 0,
                               "restored_state_sigs": 0,
                               "snapshots_saved": 0,
                               "boot_restores": 0}
        self._boot_service(sim)

    def _boot_service(self, sim, from_restart: bool = False) -> None:
        """(Re)create the host-process matcher state: the online service,
        the tier predictor's platform-state index and the calibrated
        Tier-1 posterior. With ``sim.cfg.persist_dir`` set the service
        gets the on-disk AOT executable cache and the newest valid
        snapshot (carries + predictor posteriors) is restored — a warm
        boot; otherwise every structure starts cold (``persist_dir=None``
        explicitly disables the service's env-var fallback so the cold
        arm never warms up from ``REPRO_PERSIST_DIR``). Restores are
        attributed to the ``restart_restored_*`` counters only when this
        boot follows an in-run restart event; a warm boot at simulation
        start (a previous run's snapshot) counts in ``boot_restores``."""
        # online matcher service: compiled-shape cache + warm starts keyed
        # by (workload, free-engine set), early-exit epochs, tiered drain
        cfg = sim.cfg.pso_cfg.replace(quantized=self.quantized)
        persist_dir = getattr(sim.cfg, "persist_dir", None)
        self._service = MatcherService(cfg,
                                       persist_dir=persist_dir or False)
        # per workload: LRU of seen platform states, sig → unpacked bits
        self._state_index: Dict[str, "OrderedDict[bytes, np.ndarray]"] = {}
        # observed Tier-1 rebase outcomes per (workload, popcount band of
        # the engine signature): [successes, trials]
        self._tier1_obs: Dict[tuple, List[int]] = {}
        self._prune_stats = {"launches": 0, "wall_s": 0.0, "energy_j": 0.0}
        if persist_dir:
            extra = self._service.restore_snapshot()
            if extra is not None:
                self._restore_predictor(extra.get("predictor", {}),
                                        count=from_restart)
                if from_restart:
                    self._restart_stats["restored_carries"] += \
                        self._service.stats.restored_carries
                    self._restart_stats["restored_sim_entries"] += \
                        self._service.stats.restored_sim_entries
                else:
                    self._restart_stats["boot_restores"] += 1

    def on_restart(self, sim, now):
        """Kill/restart of the scheduler process (simulator event).

        Graceful when persistence is on: the service snapshots its warm
        state with the tier predictor's posteriors riding in the
        snapshot's ``extra`` dict, then every host structure is dropped
        (process death) and ``_boot_service`` restores from disk. With
        no ``persist_dir`` this is a cold restart: carries, compile LRU,
        predictor history and calibration all start over — the baseline
        arm of ``benchmarks/bench_restart.py``."""
        persist_dir = getattr(sim.cfg, "persist_dir", None)
        if persist_dir and self._service is not None:
            self._service.save_snapshot(
                extra={"predictor": self._predictor_state()})
            self._restart_stats["snapshots_saved"] += 1
        super().on_restart(sim, now)
        self._boot_service(sim, from_restart=True)

    # -- predictor snapshot codecs ---------------------------------------

    def _predictor_state(self) -> Dict:
        """JSON-safe encoding of the tier predictor: the per-workload
        platform-state LRU (signatures only — bit vectors are re-derived
        on load) and the calibrated Tier-1 posterior counts."""
        return {
            "state_index": [[name, [sig.hex() for sig in sigs]]
                            for name, sigs in self._state_index.items()],
            "tier1_obs": [[name, band, h, t]
                          for (name, band), (h, t)
                          in self._tier1_obs.items()],
        }

    def _restore_predictor(self, d: Dict, count: bool = True) -> None:
        """Inverse of ``_predictor_state`` (tolerates missing keys so a
        snapshot written by a service without a scheduler restores as a
        plain carry restore). ``count=False`` restores without touching
        the ``restart_restored_*`` counters (boot-time warm boots)."""
        for name, sigs in d.get("state_index", []):
            for hex_sig in sigs:
                self._note_state(name, bytes.fromhex(hex_sig))
                if count:
                    self._restart_stats["restored_state_sigs"] += 1
        for name, band, h, t in d.get("tier1_obs", []):
            self._tier1_obs[(name, int(band))] = [int(h), int(t)]
            if count:
                self._restart_stats["restored_posterior_buckets"] += 1

    def matcher_stats(self) -> Dict[str, float]:
        d = self._service.stats_dict() if self._service else {}
        for k, v in getattr(self, "_tier_decisions", {}).items():
            d[f"sched_{k}_decisions"] = v
        d["sched_matcher_decisions"] = getattr(
            self, "_matcher_decisions", 0)
        obs = getattr(self, "_tier1_obs", {})
        d["sched_tier1_calib_hits"] = sum(v[0] for v in obs.values())
        d["sched_tier1_calib_trials"] = sum(v[1] for v in obs.values())
        for k, v in getattr(self, "_prune_stats", {}).items():
            d[f"sched_prune_{k}"] = v
        d["restart_count"] = getattr(self, "_restart_count", 0)
        for k, v in getattr(self, "_restart_stats", {}).items():
            d[f"restart_{k}"] = v
        return d

    def check_invariants(self, result) -> None:
        """Tier-accounting cross-checks on top of the base conflict
        check: every task routed through the tier predictor landed in
        exactly one tier (``sched_tier{0,1,2}_decisions`` sum to
        ``sched_matcher_decisions``) and the Tier-1 calibration never
        records more successes than trials. Runs on every
        ``SimConfig.validate`` simulation, analytic or real."""
        super().check_invariants(result)
        ms = result.matcher_stats
        tiers = sum(ms.get(f"sched_tier{i}_decisions", 0)
                    for i in range(3))
        charged = ms.get("sched_matcher_decisions", 0)
        assert tiers == charged, \
            f"per-tier decisions ({tiers}) != tasks routed through " \
            f"the tier predictor ({charged})"
        assert ms.get("sched_tier1_calib_hits", 0) <= \
            ms.get("sched_tier1_calib_trials", 0), "calibration hits " \
            "exceed trials"

    # -- warm-state predictor (mirrors the service carry store) ----------

    def _free_sig(self, sim, tasks) -> bytes:
        free = set(self._free_engines(sim, tasks))
        return free_engine_signature(
            [e in free for e in range(sim.platform.engines)])

    def _tier1_bucket(self, name: str, sig: bytes) -> tuple:
        """Calibration bucket: workload × popcount band of the free-engine
        signature (platform states with similar free-set sizes fail or
        succeed rebases together under fragmentation churn)."""
        pc = int(signature_bits(sig).sum())
        return (name, pc // self._T1_PC_BUCKET)

    def _tier1_success_prob(self, name: str, sig: bytes) -> float:
        """Posterior Tier-1 rebase success probability for this bucket:
        observed real-mode outcomes blended with the pseudo-count prior
        the analytic ≥50%-overlap heuristic implies. With no observations
        this is the prior (> 0.5), so analytic-only runs predict exactly
        as before calibration existed."""
        h, t = self._tier1_obs.get(self._tier1_bucket(name, sig), (0, 0))
        ph, pt = self._T1_PRIOR
        return (h + ph) / (t + pt)

    def _note_tier1_outcome(self, name: str, sig: bytes, ok: bool) -> None:
        """Record a real-mode rebase outcome for a predicted-Tier-1
        decision (served at tier ≤ 1 = the rebase verified)."""
        key = self._tier1_bucket(name, sig)
        h, t = self._tier1_obs.get(key, (0, 0))
        self._tier1_obs[key] = [h + (1 if ok else 0), t + 1]

    def _calibrate_tier1(self, preds, raws) -> None:
        """Update the rebase posterior from a real-mode launch.

        Predicted-Tier-1 decisions record their outcome directly. A
        predicted-Tier-2 decision that the pipeline actually served by a
        *verified rebase* (``raw.tier == 1``) records a success too —
        without it a bucket whose posterior once dropped below 0.5 would
        be predicted Tier-2 forever (outcomes only flow from Tier-1
        predictions) even while the real pipeline keeps rebasing it
        fine. Tier-0 serves and cold misses are neutral: neither says
        anything about rebase success."""
        for (name, sig, ptier), raw in zip(preds, raws):
            if raw is None:
                continue
            if ptier == 1:
                self._note_tier1_outcome(name, sig,
                                         raw.found and raw.tier <= 1)
            elif ptier == 2 and raw.found and raw.tier == 1:
                self._note_tier1_outcome(name, sig, True)

    def _predict_tier(self, name: str, sig: bytes) -> int:
        sigs = self._state_index.get(name)
        if not sigs:
            return 2
        if sig in sigs:
            return 0
        bits = signature_bits(sig)
        denom = max(int(bits.sum()), 1)
        for b in sigs.values():         # bits decoded once, at note time
            if b.shape == bits.shape \
                    and int((b & bits).sum()) / denom >= self._REBASE_OVERLAP:
                # overlap alone over-promises under churn: gate the Tier-1
                # prediction on the calibrated success posterior so a
                # bucket whose rebases keep failing re-verification is
                # charged (and predicted) as a swarm decision again
                if self._tier1_success_prob(name, sig) >= 0.5:
                    return 1
                return 2
        return 2

    def _note_state(self, name: str, sig: bytes) -> None:
        d = self._state_index.setdefault(name, OrderedDict())
        d[sig] = signature_bits(sig)
        d.move_to_end(sig)
        while len(d) > self._SIG_MEMORY:
            d.popitem(last=False)

    def _prune_cost(self, sim, n: int, m: int, engines: int):
        """Latency/energy of the fused pre-prune a Tier-2 (cold/swarm)
        decision pays before its first epoch. The assumed sweep count is
        calibrated online against the real launches' ``prune_sweeps``
        observable once any are available; charges accumulate in
        ``sched_prune_*`` stats."""
        sweeps = self._PRUNE_SWEEPS
        if self._service is not None \
                and self._service.stats.prune_problems > 0:
            sweeps = max(1, round(self._service.stats.avg_prune_sweeps))
        st, se = sim.cost.sched_immsched_prune(n, m, engines, sweeps=sweeps)
        self._prune_stats["launches"] += 1
        self._prune_stats["wall_s"] += st
        self._prune_stats["energy_j"] += se
        return st, se

    def _charge_tiers(self, sim, normal, sig, decision) -> None:
        """Per-tier latency for a burst: one revalidation launch covers
        the warm tasks (Tier 0/1); a swarm launch sized to the
        predicted-miss (hard) subset — plus the fused mask pre-prune that
        precedes any swarm — is charged only to those tasks; an easy task
        in a mixed burst no longer waits out the hard neighbours' swarm.
        A fully cold burst issues NO revalidation launch (the real
        pipeline skips Tier 0/1 when nothing is stored), so it is charged
        prune + swarm alone."""
        m = sim.platform.engines
        self._matcher_decisions += len(normal)
        tiers = {t.spec.task_id: self._predict_tier(t.spec.name, sig)
                 for t in normal}
        warm = [t for t in normal if tiers[t.spec.task_id] < 2]
        hard = [t for t in normal if tiers[t.spec.task_id] == 2]
        st_r = se_r = 0.0
        if warm:
            n_warm = max(self._window_tiles(sim, t) for t in warm)
            st_r, se_r = sim.cost.sched_immsched_revalidate(
                min(n_warm, 64), m, max(min(n_warm, m) // 2, 1),
                batch=len(warm))
        st_s = se_s = 0.0
        if hard:
            n_hard = max(self._window_tiles(sim, t) for t in hard)
            eng = max(min(n_hard, m) // 2, 1)
            st_p, se_p = self._prune_cost(sim, min(n_hard, 64), m, eng)
            st_s, se_s = sim.cost.sched_immsched(
                min(n_hard, 64), m, sim.cfg.pso_cfg, eng)
            st_s += st_p
            se_s += se_p
        for t in normal:
            tier = tiers[t.spec.task_id]
            self._tier_decisions[f"tier{tier}"] += 1
            # Tier-2 tasks queue behind the revalidation launch (if one
            # ran) before their swarm completes
            decision["delay"][t.spec.task_id] = (st_r if tier < 2
                                                 else st_r + st_s)
            self._note_state(t.spec.name, sig)
        decision["energy"] += se_r + se_s

    def on_event(self, sim, now, tasks, trigger, arrived=None):
        if trigger == "activate":
            return self._dispatch(sim, now, tasks)
        decision = _empty_decision()
        if trigger == "arrival" and arrived:
            urgent = [t for t in arrived if t.spec.urgent]
            normal = [t for t in arrived if not t.spec.urgent]
            if urgent:
                self._interrupt(sim, now, tasks, urgent, decision)
            if normal:
                self._charge_tiers(sim, normal,
                                   self._free_sig(sim, tasks), decision)
        elif trigger == "completion":
            waiting = self._waiting(tasks)
            if waiting:
                self._charge_tiers(sim, waiting[:1],
                                   self._free_sig(sim, tasks), decision)
        return self._dispatch(sim, now, tasks, decision)

    def _interrupt(self, sim, now, tasks, urgent_list, decision):
        """Free engines for a burst of urgent tasks: victim selection runs
        per task against the shrinking pool, but the subgraph matchings of
        the whole burst go out as ONE batched service decision, and the
        burst pays one (the largest) scheduling latency — not K of them."""
        running = [
            interrupts.RunningTask(
                task_id=t.spec.task_id, priority=t.spec.priority,
                engines=list(t.engines),
                remaining_time=t.remaining_time(len(t.engines)),
                deadline=t.spec.deadline, live_bytes=t.live_bytes)
            for t in tasks if t.status == "running"]
        free = self._free_engines(sim, tasks)
        self._matcher_decisions += len(urgent_list)
        preempted: set = set()
        grants = []          # (urgent, engines, freed_engines, need)
        preds = []           # (name, sig, predicted tier) per grant
        st_batch = se_batch = 0.0
        for urgent in urgent_list:
            live = [r for r in running if r.task_id not in preempted]
            n = self._window_tiles(sim, urgent)
            est_exec = urgent.remaining_time(min(n, sim.platform.engines))
            ratio = interrupts.adaptive_preemption_ratio(
                est_exec, urgent.spec.deadline - now)
            need = interrupts.engines_needed_for(n, sim.platform.engines,
                                                 ratio)
            dec = interrupts.select_victims(live, free, need,
                                            urgent.spec.priority, now)
            engines = dec.freed_engines[:need]
            m = max(len(dec.freed_engines), 1)
            # tiered accounting: a (workload, freed-engine-set) pair the
            # pipeline has warm state for re-validates instead of swarming
            freed_set = set(dec.freed_engines)
            sig = free_engine_signature(
                [e in freed_set for e in range(sim.platform.engines)])
            tier = self._predict_tier(urgent.spec.name, sig)
            self._tier_decisions[f"tier{tier}"] += 1
            self._note_state(urgent.spec.name, sig)
            preds.append((urgent.spec.name, sig, tier))
            if tier < 2:
                st, se = sim.cost.sched_immsched_revalidate(
                    min(n, 64), m, max(len(engines), 1))
            else:
                st_p, se_p = self._prune_cost(sim, min(n, 64), m,
                                              max(len(engines), 1))
                st, se = sim.cost.sched_immsched(
                    min(n, 64), m, sim.cfg.pso_cfg, max(len(engines), 1))
                st += st_p
                se += se_p
            # one batched launch: latency = slowest problem in the batch,
            # energy = one swarm (the problems share it), not K swarms
            st_batch = max(st_batch, st)
            se_batch = max(se_batch, se)
            preempted.update(dec.victims)
            decision["preempt"].extend(dec.victims)
            # engines this task did not take stay idle for the next one
            free = [e for e in dec.freed_engines if e not in set(engines)]
            grants.append((urgent, engines, dec.freed_engines, need))
        if sim.cfg.matcher_mode == "real":
            mapped, raws = self._real_match_batch(
                sim, [(u, freed) for u, _, freed, _ in grants])
            for i, (urgent, engines, freed, need) in enumerate(grants):
                if mapped[i]:
                    grants[i] = (urgent, mapped[i][:max(need, 1)],
                                 freed, need)
            self._calibrate_tier1(preds, raws)
        # deconflict: a real-match maps over its task's FULL freed set, so
        # a later grant may land on engines an earlier task already took —
        # reservations must stay disjoint within the burst. A fully
        # claimed grant falls back to its own freed list, then to any
        # engine freed for the burst as a whole.
        all_freed = [e for _, _, freed, _ in grants for e in freed]
        claimed: Set[int] = set()
        for urgent, engines, freed, need in grants:
            engines = [e for e in engines if e not in claimed]
            if not engines:
                pool = ([e for e in freed if e not in claimed]
                        or [e for e in all_freed if e not in claimed])
                engines = pool[:max(need, 1)]
            claimed.update(engines)
            decision["delay"][urgent.spec.task_id] = st_batch
            self._reserved[urgent.spec.task_id] = engines
        decision["energy"] += se_batch

    def _real_match_batch(self, sim, pairs):
        """Run the burst's matchings as one coalesced service launch.
        ``pairs``: (urgent_task, freed_engine_list) per urgent arrival.
        Returns ``(engines, results)``: per-task engine lists (None where
        no match) and the raw per-task ``ServiceMatchResult`` (None where
        no problem was launched) for tier-outcome calibration."""
        problems, wkeys, sigs, targets, slots = [], [], [], [], []
        for urgent, freed in pairs:
            pd = self._pdag(sim, urgent)
            free = [e in set(freed) for e in range(sim.platform.engines)]
            tgt = free_engine_graph(sim.platform, free)
            if pd.n == 0 or tgt.n < 4:
                slots.append(None)
                continue
            q = pd.graph
            if q.n > tgt.n:
                keep = np.sort(np.argsort(
                    [t.stage for t in pd.tiles])[:tgt.n])
                q = type(q)(adj=q.adj[np.ix_(keep, keep)],
                            types=q.types[keep], weights=q.weights[keep])
            slots.append(len(problems))
            problems.append((q, tgt))
            targets.append(tgt)
            sig = free_engine_signature(free)
            wkeys.append((urgent.spec.name, sig))
            sigs.append(sig)
        results = (self._service.match_many(problems, workload_keys=wkeys,
                                            engine_sigs=sigs)
                   if problems else [])
        out: List[Optional[List[int]]] = []
        raws = []
        for slot in slots:
            raws.append(None if slot is None else results[slot])
            if slot is None or not results[slot].found:
                out.append(None)
                continue
            engine_ids = targets[slot].weights.astype(int)
            _, cols = np.where(results[slot].mapping)
            out.append([int(engine_ids[c]) for c in cols])
        return out, raws


class IsoSchedScheduler(SchedulerBase):
    """TSS + preemption, but scheduling = serial Ullmann on the host CPU.

    Warm traffic goes through a minimal host-side memo cache keyed like
    the matcher service — (workload, window config, platform state) — so
    a repeat decision re-verifies the cached mapping with one refinement
    sweep instead of re-running the backtracking search. This keeps the
    IsoSched baseline apples-to-apples with IMMSched's warm tiers in
    `benchmarks/`: both sides get to remember their last decision; the
    gap that remains is serial-CPU vs on-accelerator matching."""
    name = "isosched"
    paradigm = "tss"

    def reset(self, sim):
        super().reset(sim)
        self._memo: Set = set()
        self._memo_hits = 0
        self._memo_misses = 0

    def on_restart(self, sim, now):
        """IsoSched keeps all matcher state on the host CPU, so a process
        restart flushes the memo cache unconditionally — the serial
        baseline has no persistence story, which is part of what
        ``bench_restart`` measures against."""
        super().on_restart(sim, now)
        self._memo.clear()

    def matcher_stats(self) -> Dict[str, float]:
        return {"memo_hits": self._memo_hits,
                "memo_misses": self._memo_misses,
                "memo_entries": len(getattr(self, "_memo", {})),
                "restart_count": getattr(self, "_restart_count", 0)}

    def on_event(self, sim, now, tasks, trigger, arrived=None):
        if trigger == "activate":
            return self._dispatch(sim, now, tasks)
        decision = _empty_decision()
        # serial host matcher: a burst is processed ONE problem at a time,
        # each queueing behind the previous on the single CPU. Victim
        # selection tracks the burst's earlier picks (task statuses only
        # change when the decision is applied) so reservations stay
        # disjoint, as they were when each arrival was its own event.
        targets = []
        if trigger == "arrival" and arrived:
            targets = list(arrived)
            preempted: Set[int] = set()
            claimed: Set[int] = set()
            for a in arrived:
                if not a.spec.urgent:
                    continue
                running = [
                    interrupts.RunningTask(
                        task_id=t.spec.task_id, priority=t.spec.priority,
                        engines=list(t.engines),
                        remaining_time=t.remaining_time(len(t.engines)),
                        deadline=t.spec.deadline, live_bytes=t.live_bytes)
                    for t in tasks
                    if t.status == "running"
                    and t.spec.task_id not in preempted]
                free = [e for e in self._free_engines(sim, tasks)
                        if e not in claimed]
                n = self._window_tiles(sim, a)
                need = interrupts.engines_needed_for(
                    n, sim.platform.engines, 1.0)
                dec = interrupts.select_victims(
                    running, free, need, a.spec.priority, now)
                preempted.update(dec.victims)
                decision["preempt"].extend(dec.victims)
                engines = [e for e in dec.freed_engines
                           if e not in claimed][:need]
                claimed.update(engines)
                self._reserved[a.spec.task_id] = engines
        elif trigger == "completion":
            waiting = self._waiting(tasks)
            targets = waiting[:1]
        for target in targets:
            st, se = self._serial_match_cost(sim, target, now)
            decision["delay"][target.spec.task_id] = st
            decision["energy"] += se
        return self._dispatch(sim, now, tasks, decision)

    def _serial_match_cost(self, sim, task, now):
        n = self._window_tiles(sim, task)
        m = sim.platform.engines
        # host memo keyed like the service: (workload, window config,
        # platform state). IsoSched always matches onto the full array,
        # so the state component is the all-free signature.
        sig = free_engine_signature([True] * m)
        memo_key = (task.spec.name, sim.cfg.window_stages, m, sig)
        if memo_key in self._memo:
            # warm hit: re-verify the remembered mapping with ONE
            # refinement sweep — no backtracking search
            self._memo_hits += 1
            mac_ops, nodes = 2.0 * n * m * m + 2.0 * n * n * m, 1
            st, se = sim.cost.sched_serial_cpu(mac_ops, int(nodes))
            start = max(self.cpu_free_at, now)
            self.cpu_free_at = start + st
            return (start - now) + st, se
        self._memo_misses += 1
        if sim.cfg.matcher_mode == "real":
            pd = self._pdag(sim, task)
            tgt = free_engine_graph(sim.platform,
                                    [True] * sim.platform.engines)
            q = pd.graph
            if q.n > tgt.n:
                keep = np.sort(np.argsort(
                    [t.stage for t in pd.tiles])[:tgt.n])
                q = type(q)(adj=q.adj[np.ix_(keep, keep)],
                            types=q.types[keep], weights=q.weights[keep])
            stats = ullmann.SerialStats()
            mask = compatibility_mask(q, tgt)
            sols = ullmann.serial_ullmann(q.adj, tgt.adj, mask,
                                          max_solutions=1, stats=stats)
            mac_ops, nodes = stats.mac_ops, stats.nodes_visited
            if not sols:
                # nothing to remember: an unmatchable window has no
                # mapping to re-verify, so repeats pay the search again
                st, se = sim.cost.sched_serial_cpu(mac_ops, int(nodes))
                start = max(self.cpu_free_at, now)
                self.cpu_free_at = start + st
                return (start - now) + st, se
        else:
            # calibrated against serial_ullmann stats on planted windows
            nodes = 0.3 * n
            sweeps_per_node = 2.0
            mac_ops = nodes * sweeps_per_node * (
                2 * n * m * m + 2 * n * n * m)
        self._memo.add(memo_key)
        st, se = sim.cost.sched_serial_cpu(mac_ops, int(nodes))
        # single host CPU: queue behind earlier scheduling work
        start = max(self.cpu_free_at, now)
        self.cpu_free_at = start + st
        return (start - now) + st, se


# ---------------------------------------------------------------------------
# LTS baselines
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LTSVariant:
    name: str
    fission: bool            # spatial sharing (Planaria/MoCA/CD-MSA)
    overlap: float           # cross-layer overlap factor (CD-MSA)
    mem_contention: float    # serial-bucket penalty per co-runner
    sched_scale: float       # online scheduling latency multiplier


LTS_VARIANTS = {
    "prema": LTSVariant("prema", fission=False, overlap=0.0,
                        mem_contention=0.0, sched_scale=0.45),
    "planaria": LTSVariant("planaria", fission=True, overlap=0.0,
                           mem_contention=0.20, sched_scale=1.3),
    "moca": LTSVariant("moca", fission=True, overlap=0.0,
                       mem_contention=0.05, sched_scale=0.42),
    "cdmsa": LTSVariant("cdmsa", fission=True, overlap=0.3,
                        mem_contention=0.15, sched_scale=0.85),
}


class LTSScheduler(SchedulerBase):
    paradigm = "lts"

    def __init__(self, variant: str):
        self.variant = LTS_VARIANTS[variant]
        self.name = variant
        self.overlap = self.variant.overlap

    def _sched_cost(self, sim, tasks, now):
        """Online re-scheduling on the host CPU: LTS frameworks re-solve a
        layout/partition optimization per decision (paper Fig. 2a — often
        orders of magnitude longer than the execution itself)."""
        # only tasks the host can actually see (arrived, not finished):
        # reading pending/unarrived tasks would leak future information
        # into the cost model and break streaming runs, where unarrived
        # tasks simply don't exist yet
        n_layers = int(np.mean(
            [len(t.spec.workload.layers) for t in tasks
             if t.status in ("ready", "running")] or [32]))
        work_ops = 2.0e5 * n_layers * sim.platform.engines / 64.0
        t = (work_ops / (sim.platform.cpu_gops * 1e9)
             + 2e-3) * self.variant.sched_scale
        start = max(self.cpu_free_at, now)
        self.cpu_free_at = start + t
        return (start - now) + t, t * sim.cost.cpu_watts

    def on_event(self, sim, now, tasks, trigger, arrived=None):
        if trigger == "activate":
            return (self._dispatch(sim, now, tasks)
                    if not self.variant.fission
                    else self._fission_alloc(sim, now, tasks, None))
        decision = _empty_decision()
        waiting = self._waiting(tasks)
        if not waiting and trigger != "completion":
            return decision
        st, se = self._sched_cost(sim, tasks, now)
        decision["energy"] = se

        if not self.variant.fission:
            # PREMA: exclusive array, priority time-multiplexing
            if not waiting:
                return self._dispatch(sim, now, tasks, decision)
            best = waiting[0]
            running = [t for t in tasks if t.status == "running"]
            if running:
                cur = running[0]
                if best.spec.priority <= cur.spec.priority:
                    return decision
                decision["preempt"].append(cur.spec.task_id)
            decision["delay"][best.spec.task_id] = st
            self._reserved[best.spec.task_id] = list(
                range(sim.platform.engines))
            return decision

        # fission variants: recompute proportional spatial shares (one
        # layout re-solve covers the whole burst; each task still waits
        # out the scheduling latency before activation)
        for a in (arrived or []):
            decision["delay"][a.spec.task_id] = st
        return self._fission_alloc(sim, now, tasks, decision)

    def _fission_alloc(self, sim, now, tasks, decision):
        decision = decision or _empty_decision()
        active = [t for t in tasks if t.status in ("running", "ready")]
        if self.name == "cdmsa":
            active.sort(key=lambda t: t.spec.deadline)        # EDF
        else:
            active.sort(key=lambda t: (-t.spec.priority, t.spec.arrival))
        eligible = [t for t in active
                    if t.status == "running"
                    or (now >= t.ready_at - _EPS
                        and t.spec.task_id not in decision["delay"])]
        total_prio = sum(t.spec.priority for t in eligible) or 1
        E = sim.platform.engines
        cursor = 0
        n_active = len(eligible)
        for t in eligible:
            share = max(1, int(E * t.spec.priority / total_prio))
            share = min(share, t.par_cap, E - cursor)
            if share <= 0:
                break
            engines = list(range(cursor, cursor + share))
            cursor += share
            if t.status == "running":
                if set(engines) == set(t.engines):
                    continue
                decision["preempt"].append(t.spec.task_id)
            decision["alloc"][t.spec.task_id] = engines
            # memory contention under sharing
            pen = self.variant.mem_contention * max(n_active - 1, 0)
            if pen > 0:
                t.ser_s *= (1.0 + pen)
                t.work_total += 0.0
        return decision


SCHEDULERS = {
    "immsched": lambda: IMMSchedScheduler(),
    "isosched": lambda: IsoSchedScheduler(),
    "prema": lambda: LTSScheduler("prema"),
    "planaria": lambda: LTSScheduler("planaria"),
    "moca": lambda: LTSScheduler("moca"),
    "cdmsa": lambda: LTSScheduler("cdmsa"),
}


def get_scheduler(name: str) -> SchedulerBase:
    return SCHEDULERS[name]()
