"""Online matcher service: a tiered revalidate → rebase → swarm pipeline.

``pso.match`` alone is a batch API: every new (n, m) query/target shape
triggers an XLA recompile (seconds) and every call restarts the swarm from
the cold uniform prior — the opposite of what an *online* scheduler needs
when tasks arrive unpredictably at microsecond granularity. The
``MatcherService`` turns it into a service:

  * **Shape classes** — query/target problems are bucketed to padded
    ``(n_pad, m_pad)`` classes via ``preemptible_dag.pad_problem`` (dummy
    tiles pinned to dummy PEs, semantics preserved), so repeat arrivals of
    any size within a bucket reuse one compiled executable.
  * **Bounded compile LRU** — one jit wrapper per (bucket, config), held in
    an LRU of ``cache_capacity`` entries; evicting an entry drops its
    executable. Repeat arrivals never recompile.
  * **Warm starts** — the final global-controller state ``(S*, f*, S̄)`` of
    each call is remembered in a two-level :class:`CarryStore`: an *exact*
    content-keyed LRU plus a *similarity* index keyed by
    (query digest, bucket, free-engine signature) for platform-state
    drift.
  * **Early exit** — the service enables ``cfg.early_exit`` so easy
    matches stop scanning epochs once a feasible mapping clears the
    fitness bound (1 epoch instead of T on planted instances).

**The tiered decision pipeline.** ``drain`` flushes every same-bucket
request through three stages, so a mixed easy/hard burst costs one cheap
revalidation launch plus a swarm sized to the hard subset — strictly no
worse than sequential, and far better than the uniform batch that pays
max-epochs × B whenever one hard problem rides in a burst of easy ones:

  * **Tier 0 — batched revalidation.** All requests with a stored exact
    carry are re-validated in ONE ``pso.revalidate_batch`` launch: one
    structured projection + feasibility check per problem, no epochs.
    Hits are served immediately at revalidation cost.
  * **Tier 1 — similarity rebase.** Tier-0 misses (and cold requests)
    whose workload matches a *similar* platform state — same query
    digest, nearest free-engine set by bitmask overlap — are re-run
    through the same revalidation kernel with the neighbour's carry,
    which ``pso.rebase_carry`` projects onto the new compatibility mask.
    A hit stores the rebased carry under this problem's exact key (next
    arrival is a Tier-0 hit); the verified mapping is feasibility-checked
    against the actual problem, so a rebased carry can never yield an
    infeasible mapping marked found.
  * **Tier 2 — swarm.** Only the residual misses launch the full batched
    swarm (``pso.match_batch``), warm-seeded with their failed exact
    carry or the rebased neighbour consensus (f* reset to -inf: fitness
    is not transferable across platform states, direction is).

Batch launches are padded to a small set of classes (``batch_classes``)
that joins the compile-cache key; pad slots are filled with a *trivial
pre-finished problem* whose carry validates in epoch 0, so padding never
re-burns a real problem's epoch budget (its only cost is the slot width).

Per-tier statistics (launches / problems checked / hits / wall time) are
exported via ``stats`` / ``stats_dict()`` and surfaced by
``sched.metrics`` through ``SimResult.matcher_stats``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.accel.target_graph import signature_bits
from repro.checkpoint.manager import CheckpointManager
from repro.core import persist, pso
from repro.core.graphs import (Graph, compatibility_mask,
                               topological_relabel)
from repro.core.matcher import (MatchResult, build_distributed_match,
                                build_distributed_match_batch,
                                build_distributed_revalidate_batch,
                                collect_batch_results, collect_result)
from repro.core.preemptible_dag import pad_problem
from repro.kernels import backend as kernel_backend


def _round_up(v: int, mult: int) -> int:
    mult = max(mult, 1)
    return ((v + mult - 1) // mult) * mult


def shape_bucket(n: int, m: int, n_multiple: int = 8,
                 m_multiple: int = 16) -> Tuple[int, int]:
    """Stable padded shape class for an (n, m) matching problem.

    The target bucket must leave room for the ``n_pad - n`` dummy PEs that
    ``pad_problem`` pins the dummy query tiles to.
    """
    n_pad = _round_up(max(n, 1), n_multiple)
    m_pad = _round_up(max(m, 1) + (n_pad - n), m_multiple)
    return n_pad, m_pad


@dataclasses.dataclass
class TierStats:
    """Counters for one pipeline stage."""
    launches: int = 0                # jit dispatches this tier issued
    checked: int = 0                 # real problems examined
    hits: int = 0                    # requests served by this tier
    wall_s: float = 0.0              # wall time spent in this tier

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.checked, 1)


@dataclasses.dataclass
class ServiceStats:
    """Cumulative counters for one ``MatcherService`` incarnation.

    Counters cover the compile LRU, warm-start stores, per-tier pipeline
    activity, the fused pre-prune observable the scheduler calibrates
    against, and the warm-restart persistence layer (``jit_traces`` /
    ``aot_*`` / ``snapshot_*`` / ``restored_*``). Exported flat — plus
    derived rates — by ``MatcherService.stats_dict()``; counters reset
    with the process (a restart starts a fresh incarnation, which is
    exactly what the restart benchmarks measure)."""
    calls: int = 0
    compile_cache_hits: int = 0      # bucket already had an executable
    compile_cache_misses: int = 0    # new bucket → jit compile
    compile_evictions: int = 0
    warm_hits: int = 0               # exact carry found for the call
    warm_misses: int = 0
    warm_evictions: int = 0
    epochs_run: int = 0              # total epochs actually executed
    epochs_budgeted: int = 0         # cfg.epochs × calls
    epoch_fused_launches: int = 0    # swarm dispatches whose epochs ran
                                     # through the fused epoch kernel
                                     # (KernelBackend.epoch_fused_batch)
    epoch_finish_launches: int = 0   # swarm dispatches whose epoch
                                     # epilogue ran through the fused
                                     # tail (KernelBackend.epoch_finish)
    epoch_finish_problems: int = 0   # problems those epilogues covered
                                     # (batch dispatches count B each)
    found: int = 0
    batch_launches: int = 0          # swarm (Tier-2) batch executions
    coalesced_requests: int = 0      # requests served in a shared launch
    batch_problems: int = 0          # real problems through the swarm path
    batch_slots: int = 0             # padded swarm batch slots launched
    carry_fastpath_hits: int = 0     # requests served by revalidation only
                                     # (0 epochs: Tier 0, Tier 1, or the
                                     # in-kernel fast path)
    pad_slots_frozen: int = 0        # pad slots pre-finished from epoch 0
    prune_problems: int = 0          # real problems that ran the pre-prune
    prune_sweeps: int = 0            # total fused prune iterations executed
    sim_lookups: int = 0             # similarity-store nearest() queries
    sim_neighbor_hits: int = 0       # queries that found a neighbour carry
    sim_evictions: int = 0
    # -- warm-restart persistence (AOT executable cache + snapshots) ----
    jit_traces: int = 0              # Python-level jit traces this process
                                     # actually ran (the cold-start cost a
                                     # warm restart must NOT pay: a
                                     # restored burst asserts == 0)
    aot_cache_hits: int = 0          # executables deserialized from disk
    aot_cache_misses: int = 0        # persistence on, but no blob on disk
    aot_exports: int = 0             # executables serialized to disk
    aot_export_failures: int = 0     # export unsupported → plain jit
    aot_call_fallbacks: int = 0      # deserialized blob rejected the call
                                     # signature → live re-trace
    snapshot_saves: int = 0
    snapshot_restores: int = 0       # successful state restores
    snapshot_stale_skipped: int = 0  # version/digest drift → ignored
    snapshot_skipped_keys: int = 0   # entries with unencodable keys
    restored_carries: int = 0        # exact carries loaded by restore
    restored_sim_entries: int = 0    # similarity entries loaded by restore
    # -- async front end (AsyncServiceFrontEnd) ------------------------
    fe_submitted: int = 0            # requests offered to the front end
    fe_admitted: int = 0             # requests accepted into the queue
    fe_shed: int = 0                 # rejected by admission control
    fe_forced_drains: int = 0        # block-policy drains to make room
    fe_drains: int = 0               # total front-end drain rounds
    fe_drain_deadline: int = 0       # rounds fired by slack crossing
    fe_drain_batch_full: int = 0     # rounds fired by a full batch class
    fe_drain_flush: int = 0          # rounds fired by explicit flush
    fe_queue_peak: int = 0           # max observed queue depth
    fe_wait_s: float = 0.0           # total queue-wait time (admit→drain)
    tier0: TierStats = dataclasses.field(default_factory=TierStats)
    tier1: TierStats = dataclasses.field(default_factory=TierStats)
    tier2: TierStats = dataclasses.field(default_factory=TierStats)

    @property
    def epochs_saved(self) -> int:
        """Budgeted minus executed epochs (early exit + fast paths)."""
        return self.epochs_budgeted - self.epochs_run

    @property
    def compile_hit_rate(self) -> float:
        """Fraction of calls served by an already-built executable."""
        return self.compile_cache_hits / max(self.calls, 1)

    @property
    def warm_hit_rate(self) -> float:
        """Fraction of calls that found an exact stored carry."""
        return self.warm_hits / max(self.calls, 1)

    @property
    def revalidated_rate(self) -> float:
        """Fraction of calls served without any swarm epoch (all tiers)."""
        return self.carry_fastpath_hits / max(self.calls, 1)

    @property
    def avg_prune_sweeps(self) -> float:
        """Mean fused pre-prune iterations per pruned problem — the
        prune-latency observable the scheduler's analytic cost model is
        calibrated against."""
        return self.prune_sweeps / max(self.prune_problems, 1)

    @property
    def batch_occupancy(self) -> float:
        """Real problems per launched swarm slot (1.0 = no padding waste).

        Vacuously 1.0 when the pipeline served everything without a
        swarm launch — zero launches waste zero pad slots."""
        if self.batch_slots == 0:
            return 1.0
        return self.batch_problems / self.batch_slots


@dataclasses.dataclass
class ServiceMatchResult(MatchResult):
    bucket: Tuple[int, int] = (0, 0)
    compile_cache_hit: bool = False
    warm_hit: bool = False
    latency_s: float = 0.0           # wall time of the launches that
                                     # served this request
    batch_size: int = 1              # real problems in the serving launch
    coalesced: bool = False          # served together with other requests
    tier: int = 2                    # pipeline stage that served it:
                                     # 0 revalidate, 1 rebase, 2 swarm


@dataclasses.dataclass
class _PendingRequest:
    """A submitted problem, pre-padded to its shape bucket so ``drain``
    can group by bucket without touching the graphs again."""
    key: jax.Array
    workload_key: object
    order: np.ndarray
    crop: Tuple[int, int]
    bucket: Tuple[int, int]
    Qp: np.ndarray
    Gp: np.ndarray
    maskp: np.ndarray
    engine_sig: Optional[bytes] = None   # free-engine bitmask (Tier-1 key)
    qdigest: str = ""                    # query-content digest (Tier-1 key)
    cdigest: str = ""                    # full-content digest (Tier-0 key)


@dataclasses.dataclass(eq=False)
class _PipelineItem:
    """One request flowing through the tiers of a bucket-group pipeline."""
    req: _PendingRequest
    ticket: int
    warm_key: Tuple
    carry: Optional[tuple]           # exact stored carry (Tier-0 input)
    warm_hit: bool
    seed: Optional[tuple] = None     # rebased neighbour carry (Tier-2 seed)
    t0: float = 0.0                  # pipeline intake timestamp
    latency_s: float = 0.0           # intake → end of the serving launch
    result: Optional[ServiceMatchResult] = None


class CarryStore:
    """Two-level warm-start store for the tiered pipeline.

    * **exact** — LRU of full content keys (workload key + shapes + a
      digest of Qp/Gp/maskp): a hit means *this exact problem* was solved
      before; its carry feeds Tier 0.
    * **similarity** — LRU keyed by ``(query digest, bucket, engine
      signature)``: entries describe *which platform state* a carry was
      produced on. ``nearest`` returns the stored carry whose free-engine
      bitmask overlaps the query's the most (ties go to the most recently
      stored), feeding Tier 1 rebases under fragmentation drift.

    ``nearest`` probes a **popcount-bucketed index**: entries of one
    (query digest, bucket) group are binned by the popcount of their
    free-engine bitmask, and bins are visited in decreasing order of the
    best overlap they could possibly hold (``min(pop, query_pop)``),
    stopping as soon as the bound cannot beat the best hit found — at
    thousands of stored platform states the probe touches a handful of
    bins instead of scanning the store. The exhaustive linear scan is
    kept as ``_nearest_linear`` (``sim_index=False`` fallback, and the
    oracle the index is property-tested against).
    """

    def __init__(self, capacity: int, sim_capacity: int,
                 stats: ServiceStats, sim_index: bool = True):
        self.capacity = max(int(capacity), 1)
        self.sim_capacity = max(int(sim_capacity), 1)
        self.stats = stats
        self.sim_index = bool(sim_index)
        self._exact: "OrderedDict[Tuple, tuple]" = OrderedDict()
        self._sim: "OrderedDict[Tuple, Tuple[np.ndarray, tuple]]" = \
            OrderedDict()
        # recency sequence per similarity key (== iteration order of
        # ``_sim``): the index's explicit most-recent-wins tiebreaker
        self._sim_seq: Dict[Tuple, int] = {}
        self._seq = 0
        # (qdigest, bucket, bit-length) -> {popcount: OrderedDict[sig]}
        self._sim_buckets: Dict[Tuple, Dict[int, "OrderedDict[bytes, None]"]] \
            = {}

    def __len__(self) -> int:
        return len(self._exact)

    @property
    def sim_entries(self) -> int:
        """Number of entries currently in the similarity store."""
        return len(self._sim)

    def clear(self) -> None:
        """Drop both stores and the derived popcount index/recency."""
        self._exact.clear()
        self._sim.clear()
        self._sim_seq.clear()
        self._sim_buckets.clear()

    # -- exact tier --------------------------------------------------------

    def get(self, key) -> Tuple[Optional[tuple], bool]:
        """Exact-store lookup → ``(carry, hit)``; refreshes LRU recency
        and counts ``warm_hits``/``warm_misses``."""
        if key in self._exact:
            self._exact.move_to_end(key)
            self.stats.warm_hits += 1
            return self._exact[key], True
        self.stats.warm_misses += 1
        return None, False

    def put(self, key, carry) -> None:
        """Store ``carry`` (a ``(S*, f*, S̄)`` tuple of (n, m)/(n, m)/
        scalar arrays) under the exact content key, evicting LRU
        entries beyond ``capacity``."""
        self._exact[key] = carry
        while len(self._exact) > self.capacity:
            self._exact.popitem(last=False)
            self.stats.warm_evictions += 1

    # -- similarity tier ---------------------------------------------------

    @staticmethod
    def _bits(sig: bytes) -> np.ndarray:
        return signature_bits(sig)

    def put_similar(self, qdigest: str, bucket: Tuple[int, int],
                    sig: bytes, carry) -> None:
        """Store ``carry`` under the similarity key (query digest, shape
        bucket, free-engine signature) and index it by signature
        popcount; refreshes recency for most-recent-wins ``nearest``
        tiebreaks."""
        key = (qdigest, bucket, sig)
        bits = self._bits(sig)
        fresh = key not in self._sim
        self._sim[key] = (bits, carry)
        self._sim.move_to_end(key)
        self._seq += 1
        self._sim_seq[key] = self._seq
        if fresh:
            group = self._sim_buckets.setdefault(
                (qdigest, bucket, bits.shape[0]), {})
            group.setdefault(int(bits.sum()), OrderedDict())[sig] = None
        while len(self._sim) > self.sim_capacity:
            old_key, (old_bits, _) = self._sim.popitem(last=False)
            self._drop_sim_key(old_key, old_bits)
            self.stats.sim_evictions += 1

    def _drop_sim_key(self, key: Tuple, bits: np.ndarray) -> None:
        """Remove an evicted similarity entry from the popcount index
        (``bits``: the entry's already-unpacked bit vector)."""
        qd, bk, sig = key
        self._sim_seq.pop(key, None)
        gkey = (qd, bk, bits.shape[0])
        group = self._sim_buckets.get(gkey)
        if group is None:
            return
        pc = int(bits.sum())
        bin_ = group.get(pc)
        if bin_ is not None:
            bin_.pop(sig, None)
            if not bin_:
                del group[pc]
        if not group:
            del self._sim_buckets[gkey]

    def nearest(self, qdigest: str, bucket: Tuple[int, int], sig: bytes,
                exclude_sig: Optional[bytes] = None
                ) -> Optional[Tuple[bytes, tuple]]:
        """Stored carry of the platform state nearest to ``sig``.

        Nearest = max popcount of the AND of the free-engine bitmasks;
        ties broken toward the smaller symmetric difference, then toward
        the most recently stored entry. Returns ``(stored_sig, carry)``
        or None when no same-workload entry overlaps at all. Served from
        the popcount-bucketed index (identical results to
        ``_nearest_linear`` — property-tested) unless ``sim_index`` is
        off.
        """
        if not self.sim_index:
            return self._nearest_linear(qdigest, bucket, sig, exclude_sig)
        bits = self._bits(sig)
        qpop = int(bits.sum())
        group = self._sim_buckets.get((qdigest, bucket, bits.shape[0]))
        if not group or qpop == 0:
            return None

        def upper_bound(pc: int) -> Tuple[int, int]:
            # best (overlap, -symdiff) any popcount-pc bitmask can score
            ov = min(pc, qpop)
            return ov, -(pc + qpop - 2 * ov)

        best = None
        best_score = (0, float("-inf"), -1)     # (overlap, -symdiff, seq)
        for pc in sorted(group, key=upper_bound, reverse=True):
            ub = upper_bound(pc)
            if ub[0] <= 0 or ub < (best_score[0], best_score[1]):
                break        # bins are bound-sorted: nothing below can win
            for s in group[pc]:
                if s == exclude_sig:
                    continue
                key = (qdigest, bucket, s)
                b, carry = self._sim[key]
                overlap = int((b & bits).sum())
                if overlap <= 0:
                    continue
                score = (overlap, -int((b ^ bits).sum()),
                         self._sim_seq[key])
                if score > best_score:
                    best_score = score
                    best = (s, carry)
        return best

    # -- snapshot support --------------------------------------------------

    def export_state(self) -> Tuple[List[Tuple[Tuple, tuple]],
                                    List[Tuple[Tuple, tuple]]]:
        """Both stores as ``(exact_items, sim_items)`` key/carry lists.

        Items come out in LRU order (least recent first) so an
        ``import_state`` replay reproduces recency — evictions and
        ``nearest`` most-recent-wins tiebreaks behave identically after
        a snapshot/restore round trip. Carries are returned as stored
        (device or host arrays); the snapshot writer converts to numpy.
        """
        exact = [(k, c) for k, c in self._exact.items()]
        sim = [(k, c) for k, (_, c) in self._sim.items()]
        return exact, sim

    def import_state(self, exact_items, sim_items) -> Tuple[int, int]:
        """Replay exported items into this (fresh) store, oldest first.

        Uses the normal ``put``/``put_similar`` paths so the similarity
        popcount index and recency sequence are rebuilt from scratch —
        the snapshot never persists derived index structures, only the
        keys and carries. Returns ``(n_exact, n_sim)`` loaded. Entries
        beyond this store's capacities age out exactly as live puts
        would."""
        for k, c in exact_items:
            self.put(k, c)
        for (qdigest, bucket, sig), c in sim_items:
            self.put_similar(qdigest, bucket, sig, c)
        return len(exact_items), len(sim_items)

    def _nearest_linear(self, qdigest: str, bucket: Tuple[int, int],
                        sig: bytes, exclude_sig: Optional[bytes] = None
                        ) -> Optional[Tuple[bytes, tuple]]:
        """Exhaustive-scan fallback (and the index's test oracle)."""
        bits = self._bits(sig)
        best = None
        best_score = (0, float("-inf"))
        for (qd, bk, s), (b, carry) in self._sim.items():
            if qd != qdigest or bk != bucket or s == exclude_sig:
                continue
            if b.shape != bits.shape:
                continue
            overlap = int((b & bits).sum())
            if overlap <= 0:
                continue
            score = (overlap, -int((b ^ bits).sum()))
            if score >= best_score:     # >=: most recent wins ties
                best_score = score
                best = (s, carry)
        return best


class MatcherService:
    """Warm-start online wrapper around Algorithm 1.

    Single-device by default; pass ``mesh`` + ``axis_names`` to run each
    bucket's executable as the collective-fused distributed matcher.
    ``tiered=False`` disables the staged pipeline and restores the
    uniform one-swarm-launch-per-batch drain (the PR-2 baseline);
    ``similarity=False`` keeps the pipeline but disables Tier-1 rebases
    (the content-keyed baseline).

    **Warm-restart persistence.** Pass ``persist_dir`` (or set
    ``REPRO_PERSIST_DIR``; pass ``persist_dir=False`` to force
    persistence off even when the env var is set — the cold-restart
    baseline arm) to survive process restarts:

      * ``<persist_dir>/aot/`` — each single-device executable is
        ``jax.export``-serialized on its first trace and lazily
        deserialized on the first compile-LRU miss of a restarted
        process, so the first post-restart burst runs with
        ``stats.jit_traces == 0``. Keys include the resolved kernel
        backend, every ``PSOConfig`` field, bucketing parameters, jax
        version and platform (``config_digest``) — drift is a clean
        miss, never a wrong program. Mesh-sharded executables are not
        exported (the blob pins device topology); they rely on the XLA
        compilation-cache fallback below.
      * ``<persist_dir>/snapshots/`` — ``save_snapshot`` /
        ``restore_snapshot`` persist the :class:`CarryStore` (exact +
        similarity carries; the popcount index is rebuilt on load) and
        the prune-sweep calibration counters through
        :class:`~repro.checkpoint.manager.CheckpointManager` (atomic
        commit, ``keep=snapshot_keep``). Snapshots are versioned and
        digest-validated: a restore against a drifted config is skipped
        cleanly (``snapshot_stale_skipped``), never mis-applied.
      * ``<persist_dir>/xla/`` — JAX's persistent compilation cache is
        enabled here (process-global; opt out with ``REPRO_JAX_CACHE=0``)
        so the residual XLA compile of deserialized modules and of the
        non-exportable mesh executables is also served from disk.
    """

    def __init__(self, cfg: Optional[pso.PSOConfig] = None, *,
                 mesh=None, axis_names: Sequence[str] = ("data",),
                 cache_capacity: int = 16, warm_capacity: int = 256,
                 warm_start: bool = True, early_exit: bool = True,
                 n_multiple: int = 8, m_multiple: int = 16,
                 batch_classes: Sequence[int] = (1, 2, 4, 8),
                 tiered: bool = True, similarity: bool = True,
                 sim_capacity: int = 128, sim_index: bool = True,
                 persist_dir: Union[str, bool, None] = None,
                 aot_cache: Optional[bool] = None,
                 snapshot_keep: int = 3):
        cfg = cfg or pso.PSOConfig()
        if early_exit and not cfg.early_exit:
            cfg = cfg.replace(early_exit=True)
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.cache_capacity = max(int(cache_capacity), 1)
        self.warm_start = warm_start
        self.n_multiple = n_multiple
        self.m_multiple = m_multiple
        self.batch_classes = tuple(sorted(set(int(b) for b in batch_classes)))
        assert self.batch_classes and self.batch_classes[0] >= 1
        self.tiered = tiered
        self.similarity = similarity
        self.stats = ServiceStats()
        self._carries = CarryStore(warm_capacity, sim_capacity, self.stats,
                                   sim_index=sim_index)
        self._compiled: "OrderedDict[Tuple, object]" = OrderedDict()
        self._pending: List[_PendingRequest] = []
        # -- persistence wiring -------------------------------------------
        # persist_dir: a path enables persistence there; None defers to
        # the REPRO_PERSIST_DIR env var; False forces persistence OFF
        # even when the env var is set (cold-restart baselines must not
        # silently warm up from an operator's persist root).
        if persist_dir is None:
            persist_dir = persist.default_persist_dir()
        self.persist_dir = persist_dir if persist_dir else None
        if aot_cache is None:
            aot_cache = persist.aot_cache_enabled()
        self._aot: Optional[persist.AOTCache] = None
        self._ckpt: Optional[CheckpointManager] = None
        if self.persist_dir:
            if aot_cache:
                self._aot = persist.AOTCache(
                    os.path.join(self.persist_dir, "aot"), self.stats)
            self._ckpt = CheckpointManager(
                os.path.join(self.persist_dir, "snapshots"),
                async_save=False, keep=snapshot_keep)
            persist.enable_jax_compilation_cache(
                os.path.join(self.persist_dir, "xla"))

    @property
    def warm_capacity(self) -> int:
        """Exact warm-start store capacity (entries)."""
        return self._carries.capacity

    def clear_carries(self) -> None:
        """Drop every stored warm-start carry (exact and similarity)."""
        self._carries.clear()

    @property
    def config_digest(self) -> str:
        """Digest guarding everything persisted by this service: resolved
        kernel backend + all ``PSOConfig`` fields + shape-bucketing
        parameters + jax version/platform + mesh-ness. AOT executables
        and snapshots from a process whose digest differs are ignored."""
        return kernel_backend.config_digest(
            self.cfg,
            extra=("svc-v1", jax.__version__, jax.default_backend(),
                   self.n_multiple, self.m_multiple, self.batch_classes,
                   self.mesh is not None))

    # -- caches ------------------------------------------------------------

    def _cache_put(self, cache_key, fn):
        self._compiled[cache_key] = fn
        while len(self._compiled) > self.cache_capacity:
            self._compiled.popitem(last=False)
            self.stats.compile_evictions += 1
        return fn

    def _cache_get(self, cache_key):
        fn = self._compiled.get(cache_key)
        if fn is not None:
            self._compiled.move_to_end(cache_key)
            self.stats.compile_cache_hits += 1
        return fn

    def _count_first_call(self, fn):
        """Wrap a live-jit executable so its lazy first-call trace shows
        up in ``stats.jit_traces`` (the observable the AOT cache zeroes
        out across restarts)."""
        fired: List[int] = []

        def wrapped(*args):
            if not fired:
                fired.append(1)
                self.stats.jit_traces += 1
            return fn(*args)

        return wrapped

    def _resolve_executable(self, cache_key, kind: str,
                            bucket: Tuple[int, int], bclass: int, build):
        """Compile-LRU lookup with the on-disk AOT layer behind it.

        Miss order: (1) in-memory LRU; (2) deserialized ``jax.export``
        blob — runs with NO Python trace; (3) ``build()`` a live jit
        function, which traces on first call and (when exportable and
        persistence is on) serializes itself to disk for the next
        process. Every path lands in the LRU under ``cache_key``."""
        fn = self._cache_get(cache_key)
        if fn is not None:
            return fn
        self.stats.compile_cache_misses += 1
        if self._aot is not None:
            aot_key = f"{kind}-n{bucket[0]}m{bucket[1]}-b{bclass}" \
                      f"-{self.config_digest}"
            loaded = self._aot.load(aot_key, build)
            if loaded is not None:
                self.stats.aot_cache_hits += 1
                return self._cache_put(cache_key, loaded)
            self.stats.aot_cache_misses += 1
            built = build()
            if getattr(built, "aot_exportable", True):
                return self._cache_put(
                    cache_key, self._aot.wrap_exporting(aot_key, built))
            return self._cache_put(cache_key, self._count_first_call(built))
        return self._cache_put(cache_key, self._count_first_call(build()))

    def _executable(self, bucket: Tuple[int, int]):
        """Single-problem swarm executable for one shape bucket."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(key, Q, G, mask, carry0, _cfg=cfg):
                    return pso._match_body(key, Q, G, mask, _cfg, carry0)

                return jax.jit(fn)
            return build_distributed_match(bucket, self.mesh, self.cfg,
                                           self.axis_names)

        return self._resolve_executable(bucket, "match", bucket, 1, build)

    def _executable_batch(self, bucket: Tuple[int, int], bclass: int):
        """One swarm executable per (shape bucket, padded batch class)."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(keys, Qb, Gb, maskb, carry0, _cfg=cfg):
                    return pso._match_batch_body(keys, Qb, Gb, maskb, _cfg,
                                                 carry0)

                return jax.jit(fn)
            return build_distributed_match_batch(bucket, self.mesh,
                                                 self.cfg, self.axis_names,
                                                 bclass)

        return self._resolve_executable((bucket, bclass), "batch",
                                        bucket, bclass, build)

    def _executable_reval(self, bucket: Tuple[int, int], bclass: int):
        """Tier-0/1 revalidation executable (no epochs, no keys)."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(Qb, Gb, maskb, carry0, _cfg=cfg):
                    return pso._revalidate_batch_body(Qb, Gb, maskb, _cfg,
                                                      carry0)

                return jax.jit(fn)
            return build_distributed_revalidate_batch(
                bucket, self.mesh, self.cfg, self.axis_names, bclass)

        return self._resolve_executable((bucket, bclass, "reval"), "reval",
                                        bucket, bclass, build)

    def _batch_class(self, k: int) -> int:
        """Smallest padded batch class holding k problems."""
        for c in self.batch_classes:
            if c >= k:
                return c
        return self.batch_classes[-1]

    @staticmethod
    def _warm_key(req: _PendingRequest) -> Tuple:
        """Exact warm starts are only valid for the *same* problem (f*
        values are not comparable across different Q/G), so the key always
        includes the content digest ``_prepare`` computed; the request's
        ``workload_key`` additionally scopes entries to the caller's
        (workload, platform-state) naming."""
        return (req.workload_key, req.Qp.shape[0], req.Gp.shape[0],
                req.cdigest)

    def _get_carry(self, warm_key):
        if not self.warm_start:
            self.stats.warm_misses += 1
            return None, False
        return self._carries.get(warm_key)

    def _put_carry(self, warm_key, carry):
        if self.warm_start:
            self._carries.put(warm_key, carry)

    def _store_result_carries(self, req: _PendingRequest, warm_key,
                              res: MatchResult) -> None:
        """Store a fresh carry under the exact key, and — when the call
        produced a served decision on a known platform state — under the
        similarity key too, so future drifted states can rebase it."""
        self._put_carry(warm_key, res.carry)
        if (self.warm_start and self.similarity and res.found
                and req.engine_sig is not None):
            self._carries.put_similar(req.qdigest, req.bucket,
                                      req.engine_sig, res.carry)

    # -- snapshots ---------------------------------------------------------

    def save_snapshot(self, step: Optional[int] = None,
                      extra: Optional[Dict] = None) -> int:
        """Persist the service's warm state as one atomic checkpoint.

        Saved: every :class:`CarryStore` entry (exact and similarity,
        in LRU order; carries land as one ``.npy`` leaf per array) plus
        the prune-sweep calibration counters
        (``prune_problems``/``prune_sweeps`` — the observable the
        scheduler's analytic cost model reads). NOT saved: compiled
        executables (the AOT cache owns those), transient stats, pending
        requests. ``extra`` (JSON-serializable) rides in the snapshot
        metadata — the scheduler stores its tier-predictor posteriors
        there. Entries whose keys cannot be encoded (non-str/int/bytes/
        tuple workload keys) are skipped and counted
        (``snapshot_skipped_keys``). Returns the committed step number.
        Requires ``persist_dir``."""
        if self._ckpt is None:
            raise RuntimeError("save_snapshot needs persist_dir "
                               "(or REPRO_PERSIST_DIR)")
        exact_items, sim_items = self._carries.export_state()
        arrays: Dict[str, np.ndarray] = {}
        exact_keys, exact_carries = [], []
        for k, c in exact_items:
            try:
                exact_keys.append(persist.encode_key(k))
            except TypeError:
                self.stats.snapshot_skipped_keys += 1
                continue
            exact_carries.append(c)
        sim_keys, sim_carries = [], []
        for k, c in sim_items:
            try:
                sim_keys.append(persist.encode_key(k))
            except TypeError:
                self.stats.snapshot_skipped_keys += 1
                continue
            sim_carries.append(c)
        arrays.update(persist.carry_leaves("exact", exact_carries))
        arrays.update(persist.carry_leaves("sim", sim_carries))
        # flat-dict checkpoints must be non-empty for restore_flat to see
        # a committed structure even when no carries are stored yet
        arrays["snapshot.marker"] = np.zeros((), np.int8)
        extras = {
            "format_version": persist.SNAPSHOT_VERSION,
            "config_digest": self.config_digest,
            "exact_keys": exact_keys,
            "sim_keys": sim_keys,
            "calibration": {
                "prune_problems": int(self.stats.prune_problems),
                "prune_sweeps": int(self.stats.prune_sweeps),
            },
            "extra": extra or {},
        }
        if step is None:
            latest = self._ckpt.latest_step()
            step = 0 if latest is None else latest + 1
        self._ckpt.save(step, arrays, extras=extras)
        self._ckpt.wait()
        self.stats.snapshot_saves += 1
        return step

    def restore_snapshot(self, step: Optional[int] = None
                         ) -> Optional[Dict]:
        """Load the newest (or ``step``-th) snapshot into this service.

        Validation before anything is touched: the snapshot's format
        version and ``config_digest`` must match this service's — a
        snapshot written under a different kernel backend, ``PSOConfig``,
        bucketing, jax version or platform is counted in
        ``snapshot_stale_skipped`` and ignored (warm state from a
        drifted config could verify carries that no longer mean the same
        thing). On success the :class:`CarryStore` is rebuilt (recency
        preserved, similarity popcount index reconstructed), the
        prune-sweep calibration counters are re-seeded, and the
        snapshot's ``extra`` dict is returned (``{}`` when none was
        stored). Returns None when nothing (valid) exists to restore.
        Requires ``persist_dir``."""
        if self._ckpt is None:
            raise RuntimeError("restore_snapshot needs persist_dir "
                               "(or REPRO_PERSIST_DIR)")
        try:
            arrays, extras = self._ckpt.restore_flat(step)
        except (OSError, ValueError, KeyError):
            arrays, extras = None, None
        if arrays is None:
            return None
        if extras.get("format_version") != persist.SNAPSHOT_VERSION or \
                extras.get("config_digest") != self.config_digest:
            self.stats.snapshot_stale_skipped += 1
            return None
        exact_keys = [persist.decode_key(k) for k in extras["exact_keys"]]
        sim_keys = [persist.decode_key(k) for k in extras["sim_keys"]]
        exact_carries = persist.carries_from_leaves(
            "exact", arrays, len(exact_keys))
        sim_carries = persist.carries_from_leaves(
            "sim", arrays, len(sim_keys))
        n_exact, n_sim = self._carries.import_state(
            list(zip(exact_keys, exact_carries)),
            list(zip(sim_keys, sim_carries)))
        calib = extras.get("calibration", {})
        self.stats.prune_problems += int(calib.get("prune_problems", 0))
        self.stats.prune_sweeps += int(calib.get("prune_sweeps", 0))
        self.stats.snapshot_restores += 1
        self.stats.restored_carries += n_exact
        self.stats.restored_sim_entries += n_sim
        return extras.get("extra", {})

    # -- matching ----------------------------------------------------------

    def _prepare(self, query: Graph, target: Graph, key, workload_key,
                 engine_sig: Optional[bytes] = None) -> _PendingRequest:
        """Relabel, bucket and pad a problem on the host — the jit call
        uploads Qp/Gp/maskp once; no device→host→device round trip.

        ``engine_sig`` (the free-engine bitmask, see
        ``accel.target_graph.free_engine_signature``) keys the similarity
        store; when omitted it is recovered from a ``(name, sig)``-style
        ``workload_key`` whose last element is bytes — the scheduler's
        existing naming convention."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if engine_sig is None and isinstance(workload_key, tuple) \
                and workload_key and isinstance(workload_key[-1], bytes):
            engine_sig = workload_key[-1]
        q, order = topological_relabel(query)
        n, m = q.n, target.n
        mask = compatibility_mask(q, target)
        bucket = shape_bucket(n, m, self.n_multiple, self.m_multiple)
        Qp, Gp, maskp = pad_problem(q.adj, target.adj, mask, *bucket)
        # one hashing pass yields both keys: the query-only digest (the
        # similarity key) is a prefix state of the full content digest
        # (the exact warm key)
        h = hashlib.sha1(np.ascontiguousarray(Qp).tobytes())
        qdigest = h.hexdigest()
        h.update(np.ascontiguousarray(Gp).tobytes())
        h.update(np.ascontiguousarray(maskp).tobytes())
        return _PendingRequest(key=key, workload_key=workload_key,
                               order=order, crop=(n, m), bucket=bucket,
                               Qp=Qp, Gp=Gp, maskp=maskp,
                               engine_sig=engine_sig, qdigest=qdigest,
                               cdigest=h.hexdigest())

    def _note_prune(self, problems: int, sweeps: int) -> None:
        """Account the fused pre-prune work a launch reported (the
        ``prune_sweeps`` observable of the match/revalidate kernels)."""
        if self.cfg.prune_mask and problems > 0:
            self.stats.prune_problems += problems
            self.stats.prune_sweeps += int(sweeps)

    def _tiers_active(self) -> bool:
        """Tier 0/1 only exist when the kernel fast path they batch is on
        (otherwise serving at 0 epochs would change semantics)."""
        return (self.tiered and self.warm_start
                and self.cfg.early_exit and self.cfg.carry_fastpath)

    def match(self, query: Graph, target: Graph,
              key: Optional[jax.Array] = None,
              workload_key=None,
              engine_sig: Optional[bytes] = None) -> ServiceMatchResult:
        """Match ``query`` onto ``target`` through the service caches.

        ``workload_key`` names the (workload, platform-state) class for
        warm-start scoping — e.g. ``(task_name, free_engine_signature)``.
        Results are exactly the unpadded equivalent of a direct
        ``pso.match`` on the same problem. A single call serves warm
        repeats through the in-kernel carry fast path (Tier 0, free
        inside the swarm launch) and attempts a Tier-1 rebase on an
        exact-carry MISS with a similar stored platform state. Unlike
        ``drain``, a failed exact carry goes straight to the swarm —
        probing the similarity store behind it would add a second
        dispatch to every warm single call; batch that traffic through
        ``submit``/``drain`` to get the full pipeline.
        """
        t0 = time.perf_counter()
        self.stats.calls += 1
        self.stats.epochs_budgeted += self.cfg.epochs
        req = self._prepare(query, target, key, workload_key, engine_sig)
        key, bucket = req.key, req.bucket
        order, (n, m) = req.order, req.crop
        Qp, Gp, maskp = req.Qp, req.Gp, req.maskp

        warm_key = self._warm_key(req)
        carry0, warm_hit = self._get_carry(warm_key)
        if carry0 is not None:
            self.stats.tier0.checked += 1

        # Tier 1 (single-call path): exact miss, but a similar platform
        # state is stored — revalidate its rebased carry before swarming.
        seed = None
        if carry0 is None and self._tiers_active() and self.similarity \
                and req.engine_sig is not None:
            item = _PipelineItem(req=req, ticket=0, warm_key=warm_key,
                                 carry=None, warm_hit=False, t0=t0)
            nb = self._lookup_neighbor(item)
            if nb is not None:
                residual = self._launch_revalidate(bucket, [item], [nb],
                                                   tier=1)
                if not residual:
                    res = item.result
                    res.latency_s = time.perf_counter() - t0
                    return res
                seed = item.seed

        hits_before = self.stats.compile_cache_hits
        fn = self._executable(bucket)
        compile_hit = self.stats.compile_cache_hits > hits_before

        if carry0 is None:
            carry0 = seed if seed is not None \
                else pso.default_carry(jnp.asarray(maskp))

        if self.mesh is None:
            outs = fn(key, Qp, Gp, maskp, carry0)
        else:
            num_shards = int(np.prod([self.mesh.shape[a]
                                      for a in self.axis_names]))
            keys = jax.random.split(key, num_shards)
            outs = fn(keys, Qp, Gp, maskp, carry0)

        base = collect_result(outs, order=order, crop=(n, m))
        res = ServiceMatchResult(**{f.name: getattr(base, f.name)
                                    for f in dataclasses.fields(MatchResult)})
        self._store_result_carries(req, warm_key, res)
        self.stats.epochs_run += res.epochs_run
        self._note_prune(1, res.prune_sweeps)
        if res.found:
            self.stats.found += 1
        if res.carry_verified:
            # the in-kernel fast path IS Tier 0 for a single call
            self.stats.carry_fastpath_hits += 1
            self.stats.tier0.hits += 1
            res.tier = 0
        else:
            self.stats.tier2.launches += 1
            self.stats.epoch_fused_launches += 1
            self.stats.epoch_finish_launches += 1
            self.stats.epoch_finish_problems += 1
            self.stats.tier2.checked += 1
            if res.found:
                self.stats.tier2.hits += 1
            res.tier = 2
        res.bucket = bucket
        res.compile_cache_hit = compile_hit
        res.warm_hit = warm_hit
        res.latency_s = time.perf_counter() - t0
        return res

    # -- request coalescing ------------------------------------------------

    def submit(self, query: Graph, target: Graph,
               key: Optional[jax.Array] = None, workload_key=None,
               engine_sig: Optional[bytes] = None) -> int:
        """Queue a problem for the next ``drain``; returns its ticket
        index into the results list ``drain`` will return."""
        self._pending.append(self._prepare(query, target, key, workload_key,
                                           engine_sig))
        return len(self._pending) - 1

    @property
    def pending(self) -> int:
        """Number of submitted problems waiting for the next drain."""
        return len(self._pending)

    def drain(self) -> List[ServiceMatchResult]:
        """Flush the pending queue through the tiered pipeline.

        Same-bucket requests form one pipeline group: Tier 0 revalidates
        every stored carry in one cheap launch, Tier 1 rebases similar
        carries for the misses, and only the residual requests launch the
        Tier-2 swarm (chunked to batch classes). Results come back in
        submission order; each request's ``latency_s`` is the wall time
        of the launches that actually served it, so an easy request no
        longer pays a hard neighbour's epochs.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        results: List[Optional[ServiceMatchResult]] = [None] * len(pending)
        groups: "OrderedDict[Tuple[int, int], List[int]]" = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(req.bucket, []).append(i)
        max_chunk = self.batch_classes[-1]
        for bucket, idxs in groups.items():
            reqs = [pending[i] for i in idxs]
            if self._tiers_active():
                self._run_pipeline(bucket, reqs, idxs, results)
            else:
                for pos in range(0, len(idxs), max_chunk):
                    chunk = idxs[pos:pos + max_chunk]
                    self._launch_batch_legacy(
                        bucket, [pending[i] for i in chunk], chunk, results)
        return results  # type: ignore[return-value]

    def match_many(self, problems: Sequence[Tuple[Graph, Graph]],
                   keys: Optional[Sequence[jax.Array]] = None,
                   workload_keys: Optional[Sequence] = None,
                   engine_sigs: Optional[Sequence[Optional[bytes]]] = None
                   ) -> List[ServiceMatchResult]:
        """Convenience: submit a burst of (query, target) problems and
        drain them through the tiered pipeline."""
        for i, (q, g) in enumerate(problems):
            self.submit(q, g,
                        key=None if keys is None else keys[i],
                        workload_key=(None if workload_keys is None
                                      else workload_keys[i]),
                        engine_sig=(None if engine_sigs is None
                                    else engine_sigs[i]))
        return self.drain()

    # -- the tiered pipeline ----------------------------------------------

    def _intake(self, reqs: List[_PendingRequest], tickets: List[int]
                ) -> List[_PipelineItem]:
        """Shared per-request intake for both drain paths: call/budget
        accounting, exact-carry lookup, group coalescing stats."""
        t_start = time.perf_counter()
        items: List[_PipelineItem] = []
        for req, ticket in zip(reqs, tickets):
            self.stats.calls += 1
            self.stats.epochs_budgeted += self.cfg.epochs
            wk = self._warm_key(req)
            carry, hit = self._get_carry(wk)
            items.append(_PipelineItem(req=req, ticket=ticket, warm_key=wk,
                                       carry=carry, warm_hit=hit,
                                       t0=t_start))
        if len(items) > 1:
            # the group shares ONE pipeline decision, whichever tier ends
            # up serving each member
            self.stats.coalesced_requests += len(items)
        return items

    def _run_pipeline(self, bucket, reqs: List[_PendingRequest],
                      tickets: List[int], results: List) -> None:
        """Revalidate → similarity-rebase → swarm for one bucket group."""
        items = self._intake(reqs, tickets)
        max_chunk = self.batch_classes[-1]

        # ---- Tier 0: batched revalidation of every stored carry ----
        residual: List[_PipelineItem] = [it for it in items
                                         if it.carry is None]
        cand = [it for it in items if it.carry is not None]
        for pos in range(0, len(cand), max_chunk):
            chunk = cand[pos:pos + max_chunk]
            residual.extend(self._launch_revalidate(
                bucket, chunk, [it.carry for it in chunk], tier=0))

        # ---- Tier 1: rebase the nearest similar carry for the misses ----
        if self.similarity and residual:
            t1_items, t1_carries = [], []
            for it in residual:
                nb = self._lookup_neighbor(it)
                if nb is not None:
                    t1_items.append(it)
                    t1_carries.append(nb)
            for pos in range(0, len(t1_items), max_chunk):
                self._launch_revalidate(
                    bucket, t1_items[pos:pos + max_chunk],
                    t1_carries[pos:pos + max_chunk], tier=1)

        # ---- Tier 2: swarm sized to the residual (hard) subset ----
        residual = [it for it in items if it.result is None]
        for pos in range(0, len(residual), max_chunk):
            self._launch_swarm(bucket, residual[pos:pos + max_chunk])

        for it in items:
            it.result.latency_s = it.latency_s
            results[it.ticket] = it.result

    def _lookup_neighbor(self, item: _PipelineItem) -> Optional[tuple]:
        """Similarity-store probe for one Tier-0 miss; returns the carry
        of the nearest stored platform state, or None."""
        req = item.req
        if req.engine_sig is None:
            return None
        self.stats.sim_lookups += 1
        nb = self._carries.nearest(
            req.qdigest, req.bucket, req.engine_sig,
            # the exact carry already failed revalidation — don't retry it
            exclude_sig=req.engine_sig if item.carry is not None else None)
        if nb is None:
            return None
        self.stats.sim_neighbor_hits += 1
        return nb[1]

    def _launch_revalidate(self, bucket, items: List[_PipelineItem],
                           carries: List[tuple], tier: int
                           ) -> List[_PipelineItem]:
        """One Tier-0/1 launch: revalidate B carries in a single dispatch.

        Hits get their result attached (0 epochs, revalidation cost);
        misses are returned for the next tier. Tier-1 misses keep the
        rebased carry (f* reset to -inf) as their Tier-2 swarm seed."""
        t0 = time.perf_counter()
        B = len(items)
        bclass = self._batch_class(B)
        tstats = self.stats.tier0 if tier == 0 else self.stats.tier1

        hits_before = self.stats.compile_cache_hits
        fn = self._executable_reval(bucket, bclass)
        compile_hit = self.stats.compile_cache_hits > hits_before

        reqs = [it.req for it in items]
        padded, carries = list(reqs), list(carries)
        if bclass > B:
            pad_req, pad_carry = self._pad_slot(bucket, reqs[0], carries[0])
            padded += [pad_req] * (bclass - B)
            carries += [pad_carry] * (bclass - B)
        Qb = np.stack([r.Qp for r in padded])
        Gb = np.stack([r.Gp for r in padded])
        maskb = np.stack([r.maskp for r in padded])
        carry0 = tuple(np.stack([np.asarray(c[i]) for c in carries])
                       for i in range(3))

        outs = fn(Qb, Gb, maskb, carry0)
        # Tier 0 re-validates this problem's own carry (carried-f* gate);
        # Tier 1 additionally requires the rebased projection to clear the
        # fitness bound on THIS problem (stored f* isn't transferable)
        ok = np.asarray(outs["ok" if tier == 0 else "ok_rebase"])
        maps = np.asarray(outs["mapping"])
        fits = np.asarray(outs["fitness"])
        S_rb = np.asarray(outs["S_star"])
        S_bar_rb = np.asarray(outs["S_bar"])
        sweeps = np.asarray(outs["prune_sweeps"]).reshape(-1)
        self._note_prune(B, int(sweeps[:B].sum()))
        done = time.perf_counter()

        tstats.launches += 1
        tstats.checked += B
        tstats.wall_s += done - t0
        misses: List[_PipelineItem] = []
        for j, it in enumerate(items):
            it.latency_s = done - it.t0
            if not ok[j]:
                if tier == 1:
                    it.seed = (S_rb[j], np.float32(-np.inf), S_bar_rb[j])
                misses.append(it)
                continue
            tstats.hits += 1
            self.stats.carry_fastpath_hits += 1
            self.stats.found += 1
            if tier == 0:
                carry, f_res = carries[j], float(np.asarray(carries[j][1]))
            else:
                carry = (S_rb[j], fits[j], S_bar_rb[j])
                f_res = float(fits[j])
                self._put_carry(it.warm_key, carry)
                if self.warm_start and it.req.engine_sig is not None:
                    self._carries.put_similar(it.req.qdigest, bucket,
                                              it.req.engine_sig, carry)
            it.result = self._revalidated_result(
                it, maps[j], f_res, carry, tier=tier, batch=B,
                compile_hit=compile_hit, prune_sweeps=int(sweeps[j]))
        return misses

    def _revalidated_result(self, item: _PipelineItem, M_c: np.ndarray,
                            f_res: float, carry, *, tier: int, batch: int,
                            compile_hit: bool, prune_sweeps: int = 0
                            ) -> ServiceMatchResult:
        """Host-side result for a request served by revalidation alone —
        the 0-epoch equivalent of what ``collect_result`` produces when
        the in-kernel fast path skipped every epoch."""
        req, cfg = item.req, self.cfg
        n, m = req.crop
        M = np.asarray(M_c)[:n, :m]
        unperm = np.empty_like(M)
        unperm[req.order, :] = M
        return ServiceMatchResult(
            mapping=unperm,
            feasible_count=0,
            f_star=f_res,
            f_star_trace=np.full((cfg.epochs, cfg.inner_steps), f_res,
                                 np.float32),
            all_mappings=np.zeros((0, n, m), np.uint8),
            all_feasible=np.zeros((0,), bool),
            all_fitness=np.zeros((0,), np.float32),
            carry=carry, epochs_run=0, carry_verified=True,
            prune_sweeps=prune_sweeps,
            bucket=req.bucket, compile_cache_hit=compile_hit,
            warm_hit=item.warm_hit, batch_size=batch,
            coalesced=batch > 1, tier=tier)

    # -- batch launches ----------------------------------------------------

    def _pad_slot(self, bucket, like: _PendingRequest, like_carry
                  ) -> Tuple[_PendingRequest, tuple]:
        """Pad filler for a batch launch: a trivial problem whose carry
        re-validates in epoch 0, so ``scan_epochs_batch`` freezes the pad
        slots immediately instead of re-burning a real problem's epoch
        budget (the old behaviour replicated problem 0 verbatim). Falls
        back to that replication (slot 0's problem AND carry, so the pad
        mirrors its trajectory exactly) for the degenerate n_pad > m_pad
        buckets where no injective trivial mask exists."""
        n_pad, m_pad = bucket
        if m_pad < n_pad:
            return like, like_carry
        Qp = np.zeros((n_pad, n_pad), dtype=like.Qp.dtype)
        Gp = np.zeros((m_pad, m_pad), dtype=like.Gp.dtype)
        maskp = np.zeros((n_pad, m_pad), dtype=like.maskp.dtype)
        idx = np.arange(n_pad)
        maskp[idx, idx] = 1
        S_id = np.zeros((n_pad, m_pad), np.float32)
        S_id[idx, idx] = 1.0
        # f* = +inf clears ANY early_exit_fitness bound, so the pad slot
        # is pre-finished regardless of the configured threshold
        carry = (S_id, np.float32(np.inf), S_id.copy())
        req = _PendingRequest(key=like.key, workload_key=None,
                              order=np.arange(n_pad),
                              crop=(n_pad, m_pad), bucket=bucket,
                              Qp=Qp, Gp=Gp, maskp=maskp)
        return req, carry

    def _launch_swarm(self, bucket, items: List[_PipelineItem]) -> None:
        """One Tier-2 swarm launch over the pipeline's residual items
        (carries already resolved: failed exact carry, rebased neighbour
        seed, or the cold prior)."""
        t0 = time.perf_counter()
        B = len(items)
        bclass = self._batch_class(B)

        hits_before = self.stats.compile_cache_hits
        fn = self._executable_batch(bucket, bclass)
        compile_hit = self.stats.compile_cache_hits > hits_before

        reqs = [it.req for it in items]
        carries = []
        for it in items:
            if it.carry is not None:
                carries.append(it.carry)
            elif it.seed is not None:
                carries.append(it.seed)
            else:
                carries.append(pso.default_carry(jnp.asarray(it.req.maskp)))

        pad = bclass - B
        padded = list(reqs)
        if pad:
            pad_req, pad_carry = self._pad_slot(bucket, reqs[0], carries[0])
            padded += [pad_req] * pad
            carries = carries + [pad_carry] * pad
            if pad_req is not reqs[0] and self.cfg.early_exit \
                    and self.cfg.carry_fastpath:
                self.stats.pad_slots_frozen += pad
        keysb = np.stack([np.asarray(r.key) for r in padded])
        Qb = np.stack([r.Qp for r in padded])
        Gb = np.stack([r.Gp for r in padded])
        maskb = np.stack([r.maskp for r in padded])
        carry0 = tuple(np.stack([np.asarray(c[i]) for c in carries])
                       for i in range(3))

        outs = fn(keysb, Qb, Gb, maskb, carry0)
        batch_results = collect_batch_results(
            outs, bclass,
            orders=[r.order for r in padded],
            crops=[r.crop for r in padded])
        done = time.perf_counter()

        self.stats.batch_launches += 1
        self.stats.batch_problems += B
        self.stats.batch_slots += bclass
        self.stats.tier2.launches += 1
        self.stats.epoch_fused_launches += 1
        self.stats.epoch_finish_launches += 1
        self.stats.epoch_finish_problems += B
        self.stats.tier2.checked += B
        self.stats.tier2.wall_s += done - t0
        for j, it in enumerate(items):
            base = batch_results[j]
            res = ServiceMatchResult(
                **{f.name: getattr(base, f.name)
                   for f in dataclasses.fields(MatchResult)})
            self._store_result_carries(it.req, it.warm_key, res)
            self.stats.epochs_run += res.epochs_run
            self._note_prune(1, res.prune_sweeps)
            if res.found:
                self.stats.found += 1
                self.stats.tier2.hits += 1
            if res.carry_verified:
                self.stats.carry_fastpath_hits += 1
            res.bucket = bucket
            res.compile_cache_hit = compile_hit
            res.warm_hit = it.warm_hit
            res.batch_size = B
            res.coalesced = B > 1
            res.tier = 2
            # end-to-end drain latency: a Tier-2 request also waited out
            # every pipeline launch that preceded this one
            it.latency_s = done - it.t0
            it.result = res

    def _launch_batch_legacy(self, bucket, reqs: List[_PendingRequest],
                             tickets: List[int], results: List) -> None:
        """The untiered (PR-2) drain path: every request goes straight to
        one uniform swarm launch. Kept as the ``tiered=False`` baseline —
        `benchmarks/bench_tiers.py` measures the pipeline against it."""
        items = self._intake(reqs, tickets)
        self._launch_swarm(bucket, items)
        for it in items:
            it.result.latency_s = it.latency_s
            results[it.ticket] = it.result

    # -- reporting ---------------------------------------------------------

    def stats_dict(self) -> Dict[str, float]:
        """Flat ``{counter: value}`` export of :class:`ServiceStats`
        plus derived rates and per-tier breakdowns — the payload
        ``SimResult.matcher_stats`` surfaces (see the README stats
        glossary for per-key meanings)."""
        s = self.stats
        out = {
            "calls": s.calls,
            "compile_cache_hits": s.compile_cache_hits,
            "compile_cache_misses": s.compile_cache_misses,
            "compile_hit_rate": s.compile_hit_rate,
            "warm_hits": s.warm_hits,
            "warm_misses": s.warm_misses,
            "warm_hit_rate": s.warm_hit_rate,
            "epochs_run": s.epochs_run,
            "epochs_budgeted": s.epochs_budgeted,
            "epochs_saved": s.epochs_saved,
            "epoch_fused_launches": s.epoch_fused_launches,
            "epoch_finish_launches": s.epoch_finish_launches,
            "epoch_finish_problems": s.epoch_finish_problems,
            "epoch_backend": kernel_backend.resolve_backend_name(
                self.cfg.backend),
            "found": s.found,
            "batch_launches": s.batch_launches,
            "coalesced_requests": s.coalesced_requests,
            "batch_problems": s.batch_problems,
            "batch_slots": s.batch_slots,
            "batch_occupancy": s.batch_occupancy,
            "carry_fastpath_hits": s.carry_fastpath_hits,
            "revalidated_rate": s.revalidated_rate,
            "pad_slots_frozen": s.pad_slots_frozen,
            "prune_problems": s.prune_problems,
            "prune_sweeps": s.prune_sweeps,
            "avg_prune_sweeps": s.avg_prune_sweeps,
            "sim_lookups": s.sim_lookups,
            "sim_neighbor_hits": s.sim_neighbor_hits,
            "sim_evictions": s.sim_evictions,
            "sim_entries": self._carries.sim_entries,
            "jit_traces": s.jit_traces,
            "aot_cache_hits": s.aot_cache_hits,
            "aot_cache_misses": s.aot_cache_misses,
            "aot_exports": s.aot_exports,
            "aot_export_failures": s.aot_export_failures,
            "aot_call_fallbacks": s.aot_call_fallbacks,
            "snapshot_saves": s.snapshot_saves,
            "snapshot_restores": s.snapshot_restores,
            "snapshot_stale_skipped": s.snapshot_stale_skipped,
            "snapshot_skipped_keys": s.snapshot_skipped_keys,
            "restored_carries": s.restored_carries,
            "restored_sim_entries": s.restored_sim_entries,
            "fe_submitted": s.fe_submitted,
            "fe_admitted": s.fe_admitted,
            "fe_shed": s.fe_shed,
            "fe_forced_drains": s.fe_forced_drains,
            "fe_drains": s.fe_drains,
            "fe_drain_deadline": s.fe_drain_deadline,
            "fe_drain_batch_full": s.fe_drain_batch_full,
            "fe_drain_flush": s.fe_drain_flush,
            "fe_queue_peak": s.fe_queue_peak,
            "fe_wait_s": s.fe_wait_s,
        }
        for name in ("tier0", "tier1", "tier2"):
            t: TierStats = getattr(s, name)
            out[f"{name}_launches"] = t.launches
            out[f"{name}_checked"] = t.checked
            out[f"{name}_hits"] = t.hits
            out[f"{name}_hit_rate"] = t.hit_rate
            out[f"{name}_wall_s"] = t.wall_s
        return out


@dataclasses.dataclass
class _QueuedRequest:
    rid: int
    query: Graph
    target: Graph
    deadline: float
    enqueued_at: float
    key: Optional[jax.Array] = None
    workload_key: object = None
    engine_sig: Optional[bytes] = None


class AsyncServiceFrontEnd:
    """Admission-controlled arrival queue in front of a MatcherService.

    ``MatcherService.submit``/``drain`` are caller-driven: whoever
    submits must also decide when to flush, so under sustained load the
    queue either grows without bound or gets drained one request at a
    time. This front end owns that decision. Requests enter a bounded
    queue (``max_depth``); when it is full the ``policy`` either
    **sheds** the new request (recorded, result ``None``) or **blocks**
    it by forcing a drain round to make room first. A queued batch is
    drained through the service's tiered pipeline when either

      * the queue can fill the service's largest batch class
        (``batch_classes[-1]`` requests queued) — launch-shaped, or
      * the *oldest* queued request's slack ``deadline - now`` falls to
        ``slack_threshold_s`` — deadline-shaped (checked at submit time
        and by ``poll``), or
      * the caller explicitly ``flush``\\ es.

    Every trigger reason, shed, forced drain, queue peak, and cumulative
    queue wait flows into the service's ``ServiceStats`` (``fe_*`` keys
    of ``stats_dict()``), so ``SimResult.matcher_stats`` →
    ``metrics.frontend_stats`` report it per run.

    Time is an explicit ``now`` parameter everywhere (falling back to
    ``clock()``), so the front end drops into the event-driven simulator
    — which advances virtual time — as readily as onto a wall clock.
    """

    def __init__(self, service: MatcherService, *, max_depth: int = 64,
                 policy: str = "shed", slack_threshold_s: float = 0.0,
                 clock=time.perf_counter):
        assert policy in ("shed", "block"), policy
        assert max_depth >= 1
        self.service = service
        self.max_depth = int(max_depth)
        self.policy = policy
        self.slack_threshold_s = float(slack_threshold_s)
        self._clock = clock
        self._queue: List[_QueuedRequest] = []
        self._results: Dict[int, Optional[ServiceMatchResult]] = {}
        self._next_rid = 0

    # -- observables ---------------------------------------------------

    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet drained)."""
        return len(self._queue)

    def next_deadline_check(self) -> float:
        """Earliest instant the deadline trigger could fire (the oldest
        queued deadline minus the slack threshold); +inf when idle. An
        event-driven host schedules its next ``poll`` here."""
        if not self._queue:
            return float("inf")
        return min(q.deadline for q in self._queue) - self.slack_threshold_s

    # -- request path --------------------------------------------------

    def submit(self, query: Graph, target: Graph, *,
               deadline: float = float("inf"),
               now: Optional[float] = None,
               key: Optional[jax.Array] = None, workload_key=None,
               engine_sig: Optional[bytes] = None) -> int:
        """Offer a request; returns a request id for ``take_result``.

        A shed request (queue full under the shed policy) still gets an
        id — its result is recorded as ``None`` immediately.
        """
        now = self._clock() if now is None else now
        stats = self.service.stats
        rid = self._next_rid
        self._next_rid += 1
        stats.fe_submitted += 1
        if len(self._queue) >= self.max_depth:
            if self.policy == "shed":
                stats.fe_shed += 1
                self._results[rid] = None
                return rid
            stats.fe_forced_drains += 1
            self._drain(now, "batch_full")
        self._queue.append(_QueuedRequest(
            rid=rid, query=query, target=target, deadline=float(deadline),
            enqueued_at=now, key=key, workload_key=workload_key,
            engine_sig=engine_sig))
        stats.fe_admitted += 1
        stats.fe_queue_peak = max(stats.fe_queue_peak, len(self._queue))
        self._check_triggers(now)
        return rid

    def poll(self, now: Optional[float] = None) -> int:
        """Fire any due drain trigger; returns requests drained (0 if
        none due). Hosts call this when time passes without submits —
        e.g. at ``next_deadline_check()``."""
        now = self._clock() if now is None else now
        return self._check_triggers(now)

    def flush(self, now: Optional[float] = None) -> int:
        """Drain everything queued regardless of triggers."""
        now = self._clock() if now is None else now
        return self._drain(now, "flush")

    def take_result(self, rid: int) -> Optional[ServiceMatchResult]:
        """Pop the result for ``rid``: a ``ServiceMatchResult``, or
        ``None`` if the request was shed. Raises ``KeyError`` while the
        request is still queued (not drained yet)."""
        return self._results.pop(rid)

    # -- internals -----------------------------------------------------

    def _check_triggers(self, now: float) -> int:
        if not self._queue:
            return 0
        if len(self._queue) >= self.service.batch_classes[-1]:
            return self._drain(now, "batch_full")
        oldest_slack = min(q.deadline for q in self._queue) - now
        if oldest_slack <= self.slack_threshold_s:
            return self._drain(now, "deadline")
        return 0

    def _drain(self, now: float, reason: str) -> int:
        if not self._queue:
            return 0
        stats = self.service.stats
        stats.fe_drains += 1
        setattr(stats, f"fe_drain_{reason}",
                getattr(stats, f"fe_drain_{reason}") + 1)
        batch, self._queue = self._queue, []
        tickets = [self.service.submit(q.query, q.target, key=q.key,
                                       workload_key=q.workload_key,
                                       engine_sig=q.engine_sig)
                   for q in batch]
        results = self.service.drain()
        for q, ticket in zip(batch, tickets):
            self._results[q.rid] = results[ticket]
            stats.fe_wait_s += max(now - q.enqueued_at, 0.0)
        return len(batch)
