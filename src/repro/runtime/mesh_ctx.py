"""Trace-time mesh context: lets model internals pin activation shardings
without threading a Mesh through every signature.

The step factories (train_loop/serve_loop) enter ``with mesh_context(mesh)``
around the model call while *tracing*; ``constrain(x, *symbols)`` becomes a
``with_sharding_constraint`` against the active mesh (no-op when unsharded).

Symbols: "batch" → the combined FSDP/data axes, "tensor" → the model axis,
None → replicated. Dims whose size does not divide the axis fall back to
None (same contract as runtime.sharding)."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import jax

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_profile() -> str:
    return getattr(_STATE, "profile", "2d")


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], profile: str = "2d"):
    prev, prev_p = current_mesh(), current_profile()
    _STATE.mesh, _STATE.profile = mesh, profile
    try:
        yield
    finally:
        _STATE.mesh, _STATE.profile = prev, prev_p


def _axes(mesh: Mesh):
    from repro.runtime.sharding import mesh_axes
    return mesh_axes(mesh, current_profile())


def constrain(x, *symbols):
    """Apply a symbolic sharding constraint if a mesh is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    fsdp, tensor = _axes(mesh)
    spec = []
    for dim, sym in enumerate(symbols):
        if sym == "batch" and fsdp:
            size = int(np.prod([mesh.shape[a] for a in fsdp]))
            spec.append((fsdp if len(fsdp) > 1 else fsdp[0])
                        if x.shape[dim] % size == 0 and x.shape[dim] > 1
                        else None)
        elif sym == "tensor" and tensor:
            spec.append(tensor if x.shape[dim] % mesh.shape[tensor] == 0
                        else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
