"""Hypothesis-driven invariant fuzzing across the whole pipeline.

Random scenario compositions — bursts × churn × restarts ×
streaming-vs-materialized × scheduler choice — drawn through the
scenario registry, with the cross-cutting invariants asserted on full
simulator runs:

  * no scheduler ever double-books an engine (``alloc_conflicts == 0``)
    and every per-event ``SimConfig.validate`` check holds;
  * IMMSched's per-tier decision counts sum to the tasks routed through
    the tier predictor (``sched_matcher_decisions``);
  * streaming and materialized scenarios built from the same spec
    produce bitwise-equal ``SimResult``s;
  * the heap event loop ≡ ``run_legacy`` bitwise, restarts included;
  * the matcher service never serves an infeasible mapping, whatever
    tier (warm fast path, similarity rebase, swarm) produced it;
  * a snapshot saved mid-run restores bitwise into a fresh service.

Everything here is ``fuzz``-marked and excluded from the default lane
(pytest.ini ``addopts``); CI runs a seeded smoke with
``REPRO_FUZZ_EXAMPLES=8 pytest -m fuzz``. Under real hypothesis the
profile is derandomized (fixed corpus); the `_hyp_compat` fallback is
deterministic by construction.
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

from _hyp_compat import HAVE_HYPOTHESIS, given, settings, st
from test_scenario_registry import _task_rec

from repro.accel import EDGE
from repro.accel.target_graph import free_engine_signature
from repro.core import graphs, pso
from repro.core.service import MatcherService
from repro.sched.registry import build_scenario
from repro.sched.schedulers import SCHEDULERS, get_scheduler
from repro.sched.simulator import SimConfig, Simulator
from repro.sched.tasks import Scenario

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.fuzz

#: Examples per property; CI smoke pins REPRO_FUZZ_EXAMPLES=8 so the
#: four scenario properties alone cover >= 25 random compositions.
FUZZ_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "10"))

#: Small swarm so service launches stay sub-second on CPU.
FUZZ_CFG = pso.PSOConfig(num_particles=16, epochs=2, inner_steps=6,
                         early_exit=True)


def fuzz_settings(n=None):
    kw = dict(max_examples=n or FUZZ_EXAMPLES, deadline=None)
    if HAVE_HYPOTHESIS:
        kw["derandomize"] = True    # fixed CI corpus, no example DB
    return settings(**kw)


def _cfg(**kw):
    return SimConfig(platform=EDGE, matcher_mode="analytic", **kw)


# ---------------------------------------------------------------------------
# spec strategies (drawn through the registry's public spec surface)
# ---------------------------------------------------------------------------

@st.composite
def stream_specs(draw):
    kind = draw(st.sampled_from(["poisson", "burst"]))
    rate = float(draw(st.integers(10, 45)))
    if kind == "poisson":
        arrival = {"kind": "poisson", "rate_hz": rate}
        burst_size = 4
    else:
        burst_size = draw(st.integers(2, 5))
        arrival = {"kind": "burst", "rate_hz": rate,
                   "burst_size": burst_size,
                   "burst_frac": draw(st.floats(0.1, 0.9))}
    wl = draw(st.sampled_from(["uniform", "mixed", "named"]))
    if wl == "uniform":
        workload = {"kind": "uniform",
                    "complexity": draw(st.sampled_from(
                        ["simple", "middle"]))}
    elif wl == "mixed":
        workload = {"kind": "mixed_burst", "easy": "simple",
                    "hard": "middle",
                    "hard_frac": draw(st.floats(0.0, 0.8)),
                    "burst_size": burst_size}
    else:
        workload = {"kind": "named",
                    "name": draw(st.sampled_from(
                        ["mobilenetv2", "resnet50"]))}
    urgency = draw(st.sampled_from([
        {"kind": "never"}, {"kind": "always"},
        {"kind": "bernoulli", "urgent_frac": 0.4}]))
    deadline = draw(st.sampled_from([
        {"kind": "slack"},
        {"kind": "slack", "deadline_slack": 1.2, "urgent_slack": 0.8},
        {"kind": "fixed", "offset": 0.5}]))
    return {"arrival": arrival, "workload": workload,
            "urgency": urgency, "deadline": deadline}


@st.composite
def scenario_specs(draw, allow_replay=True, single_stream=False):
    n_streams = 1 if single_stream else draw(st.integers(1, 2))
    restarts = [{"kind": "none"},
                {"kind": "at",
                 "times": [draw(st.floats(0.0, 0.25))]}]
    if allow_replay:
        restarts.append({"kind": "replay", "gap": 1e-3})
    return {
        "name": "fuzz", "seed": draw(st.integers(0, 10 ** 6)),
        "horizon": draw(st.floats(0.1, 0.3)),
        "streams": [draw(stream_specs()) for _ in range(n_streams)],
        "restarts": draw(st.sampled_from(restarts)),
    }


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

@fuzz_settings()
@given(scenario_specs(), st.sampled_from(sorted(SCHEDULERS)))
def test_fuzz_sim_invariants(spec, sched_name):
    sc = build_scenario(spec)
    r = Simulator(_cfg(validate=True), get_scheduler(sched_name)).run(sc)
    assert not r.truncated
    assert r.alloc_conflicts == 0
    assert 0 <= r.finished <= r.total == len(sc.tasks)
    assert r.deadline_met <= r.finished
    assert r.urgent_met <= r.urgent_total <= r.total
    assert r.busy_integral <= EDGE.engines * r.sim_horizon + 1e-9
    p = r.percentiles or {}
    if "sched_p50" in p:
        assert p["sched_p50"] <= p["sched_p99"] <= p["sched_p999"]
    if sched_name == "immsched":
        ms = r.matcher_stats
        tiers = sum(ms[f"sched_tier{i}_decisions"] for i in range(3))
        assert tiers == ms["sched_matcher_decisions"]


@fuzz_settings()
@given(scenario_specs(allow_replay=False, single_stream=True))
def test_fuzz_streaming_equals_materialized(spec):
    mat = build_scenario({**spec, "stream": False})
    stm = build_scenario({**spec, "stream": True})
    ra = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(mat)
    rb = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(stm)
    assert dataclasses.asdict(ra) == dataclasses.asdict(rb)


@fuzz_settings()
@given(scenario_specs(), st.sampled_from(["immsched", "prema", "cdmsa"]))
def test_fuzz_heap_loop_equals_legacy(spec, sched_name):
    ra = Simulator(_cfg(validate=True),
                   get_scheduler(sched_name)).run(build_scenario(spec))
    rb = Simulator(_cfg(validate=True),
                   get_scheduler(sched_name)).run_legacy(
                       build_scenario(spec))
    assert dataclasses.asdict(ra) == dataclasses.asdict(rb)


@fuzz_settings()
@given(scenario_specs())
def test_fuzz_registry_rebuild_deterministic(spec):
    a, b = build_scenario(spec), build_scenario(spec)
    assert (a.name, a.horizon, a.restarts) == (b.name, b.horizon,
                                               b.restarts)
    assert [_task_rec(t) for t in a.tasks] == \
        [_task_rec(t) for t in b.tasks]
    # re-materializing a's tasks into a fresh scenario must not disturb
    # a's ids (the __post_init__ idempotence fix, under fuzz)
    ids = [t.task_id for t in a.tasks]
    if a.tasks:
        early = dataclasses.replace(a.tasks[0], arrival=0.0, task_id=-1)
        Scenario(name="merged", tasks=[early] + list(a.tasks),
                 horizon=a.horizon)
        assert [t.task_id for t in a.tasks] == ids


# ---------------------------------------------------------------------------
# matcher service: feasibility + snapshot round trips under drift
# ---------------------------------------------------------------------------

def _planted(seed, n=6, m=12, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    return q, graphs.embed_query_in_target(kt, q, m)


def _check_mapping(mapping, q, g):
    M = np.asarray(mapping, dtype=np.int64)
    assert (M.sum(axis=1) == 1).all()
    assert (M.sum(axis=0) <= 1).all()
    covered = M @ g.adj.astype(np.int64) @ M.T
    assert (covered >= q.adj).all()


_SVC = []


def _service():
    if not _SVC:
        _SVC.append(MatcherService(FUZZ_CFG, persist_dir=False))
    return _SVC[0]


@fuzz_settings()
@given(st.integers(0, 7), st.integers(0, 3),
       st.lists(st.booleans(), min_size=16, max_size=16))
def test_fuzz_service_never_serves_infeasible(qseed, variant, free_bits):
    """Repeats, drifted targets and drifted engine signatures drive the
    warm/rebase/swarm tiers; whatever tier answers, a found mapping must
    be feasible against the ACTUAL problem."""
    svc = _service()
    q, g0 = _planted(qseed)
    g = g0 if variant == 0 else graphs.embed_query_in_target(
        jax.random.PRNGKey(9000 + 13 * qseed + variant), q, 12)
    sig = free_engine_signature(free_bits)
    r = svc.match(q, g, key=jax.random.PRNGKey(31 * qseed + variant),
                  workload_key=(f"wl{qseed}", sig))
    if r.found:
        _check_mapping(r.mapping, q, g)
    s = svc.stats
    assert s.found <= s.calls
    for tier in (s.tier0, s.tier1, s.tier2):
        assert 0 <= tier.hits <= max(tier.checked, tier.launches)


_SNAP = []


def _snap_service():
    if not _SNAP:
        d = tempfile.mkdtemp(prefix="fuzz-snap-")
        _SNAP.append(MatcherService(FUZZ_CFG, persist_dir=d,
                                    aot_cache=False))
    return _SNAP[0]


@fuzz_settings(min(FUZZ_EXAMPLES, 6))
@given(st.integers(0, 5),
       st.lists(st.booleans(), min_size=16, max_size=16))
def test_fuzz_snapshot_roundtrip_mid_run(seed, free_bits):
    """Snapshots taken mid-fuzz (store growing across examples) restore
    bitwise into a fresh twin service."""
    svc = _snap_service()
    q, g = _planted(seed)
    svc.match(q, g, key=jax.random.PRNGKey(seed),
              workload_key=(f"snap{seed}",
                            free_engine_signature(free_bits)))
    assert svc.verify_snapshot_roundtrip()


# ---------------------------------------------------------------------------
# real matcher mode: analytic accounting must hold on live launches too
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_fuzz_real_mode_invariants(seed):
    sc = build_scenario({
        "name": f"fuzz-real-{seed}", "seed": seed, "horizon": 0.12,
        "streams": [{
            "arrival": {"kind": "poisson", "rate_hz": 25},
            "workload": {"kind": "uniform", "complexity": "simple"},
            "urgency": {"kind": "bernoulli", "urgent_frac": 0.3},
        }],
    })
    cfg = SimConfig(platform=EDGE, matcher_mode="real",
                    pso_cfg=FUZZ_CFG, window_stages=2, validate=True)
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    ms = r.matcher_stats
    assert r.alloc_conflicts == 0
    assert sum(ms[f"sched_tier{i}_decisions"] for i in range(3)) == \
        ms["sched_matcher_decisions"]
    assert ms["found"] <= ms["calls"]
