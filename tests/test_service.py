"""Online MatcherService: compiled-shape cache accounting, warm starts,
early exit, and parity with the direct matcher."""
import jax
import numpy as np
import pytest

from repro.core import graphs, pso
from repro.core.matcher import IMMSchedMatcher
from repro.core.service import MatcherService, shape_bucket

jax.config.update("jax_platform_name", "cpu")

CFG = pso.PSOConfig(num_particles=24, epochs=3, inner_steps=8)


def _planted(seed, n, m, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _check_mapping(mapping, q, g):
    assert mapping is not None
    M = np.asarray(mapping, dtype=np.int64)
    assert (M.sum(axis=1) == 1).all()
    assert (M.sum(axis=0) <= 1).all()
    covered = M @ g.adj.astype(np.int64) @ M.T
    assert (covered >= q.adj).all()


def test_shape_bucket_stable_and_padded():
    assert shape_bucket(8, 16) == (8, 16)
    assert shape_bucket(9, 16) == (16, 32)     # room for 7 dummy PEs
    assert shape_bucket(10, 24) == shape_bucket(12, 26)
    n_pad, m_pad = shape_bucket(5, 9)
    assert n_pad >= 5 and m_pad >= 9 + (n_pad - 5)


def test_cache_hit_miss_accounting_across_buckets():
    svc = MatcherService(CFG)
    qa, ga = _planted(0, 6, 12)     # bucket A
    qb, gb = _planted(1, 8, 16)     # bucket A? (8,16) vs (8,16): (6,12)->(8,16)
    qc, gc = _planted(2, 10, 24)    # bucket B (16, 32)

    r1 = svc.match(qa, ga, key=jax.random.PRNGKey(0))
    assert not r1.compile_cache_hit and not r1.warm_hit
    r2 = svc.match(qb, gb, key=jax.random.PRNGKey(1))
    assert r2.bucket == r1.bucket           # same shape class
    assert r2.compile_cache_hit             # no recompile for repeat bucket
    assert not r2.warm_hit                  # different problem content
    r3 = svc.match(qc, gc, key=jax.random.PRNGKey(2))
    assert r3.bucket != r1.bucket
    assert not r3.compile_cache_hit         # new bucket compiles

    s = svc.stats_dict()
    assert s["calls"] == 3
    assert s["compile_cache_misses"] == 2
    assert s["compile_cache_hits"] == 1
    assert s["warm_hits"] == 0 and s["warm_misses"] == 3

    # repeat of the first problem: compile hit AND warm hit
    r4 = svc.match(qa, ga, key=jax.random.PRNGKey(3))
    assert r4.compile_cache_hit and r4.warm_hit
    assert svc.stats_dict()["warm_hits"] == 1


def test_compile_cache_is_bounded_lru():
    svc = MatcherService(CFG, cache_capacity=1)
    qa, ga = _planted(0, 6, 12)
    qc, gc = _planted(2, 10, 24)
    svc.match(qa, ga)
    svc.match(qc, gc)                       # evicts bucket A
    assert svc.stats_dict()["compile_cache_misses"] == 2
    assert len(svc._compiled) == 1
    svc.match(qa, ga)                       # must recompile bucket A
    assert svc.stats_dict()["compile_cache_misses"] == 3


def test_warm_start_no_worse_than_cold_at_equal_budget():
    """Same problem, same epoch budget: the warm-started call must reach at
    least the cold call's best fitness (the carry holds S*/f*), and with
    early exit must not need more epochs."""
    q, g = _planted(2, 10, 24)
    svc = MatcherService(CFG)
    cold = svc.match(q, g, key=jax.random.PRNGKey(0), workload_key="wl")
    warm = svc.match(q, g, key=jax.random.PRNGKey(1), workload_key="wl")
    assert warm.warm_hit
    assert warm.f_star >= cold.f_star - 1e-6
    assert warm.epochs_run <= cold.epochs_run
    if cold.found:
        assert warm.found
        _check_mapping(warm.mapping, q, g)


def test_early_exit_same_mapping_as_full_budget():
    """On a unique-solution planted instance, the early-exited service call
    and the full-budget direct matcher must return the same mapping."""
    q, g = _planted(3, 8, 16)
    svc = MatcherService(CFG, early_exit=True)
    res_fast = svc.match(q, g, key=jax.random.PRNGKey(3))
    res_full = IMMSchedMatcher(CFG).match(q, g, key=jax.random.PRNGKey(3))
    assert res_fast.found and res_full.found
    _check_mapping(res_fast.mapping, q, g)
    assert res_fast.epochs_run <= res_full.epochs_run
    np.testing.assert_array_equal(np.asarray(res_fast.mapping),
                                  np.asarray(res_full.mapping))


def test_service_parity_with_direct_matcher():
    """With early exit off and a bucket-exact problem (no padding), the
    service is bit-identical to the direct matcher path."""
    q, g = _planted(1, 8, 16)       # (8, 16) == its own bucket
    assert shape_bucket(8, 16) == (8, 16)
    svc = MatcherService(CFG, early_exit=False, warm_start=False)
    res_s = svc.match(q, g, key=jax.random.PRNGKey(7))
    res_d = IMMSchedMatcher(CFG).match(q, g, key=jax.random.PRNGKey(7))
    assert res_s.found == res_d.found
    assert res_s.feasible_count == res_d.feasible_count
    np.testing.assert_allclose(res_s.f_star, res_d.f_star, rtol=1e-6)
    np.testing.assert_array_equal(res_s.all_feasible, res_d.all_feasible)
    if res_d.found:
        np.testing.assert_array_equal(np.asarray(res_s.mapping),
                                      np.asarray(res_d.mapping))


def test_early_exit_pays_fewer_epochs():
    q, g = _planted(0, 6, 12)
    svc = MatcherService(CFG)       # early exit on by default
    res = svc.match(q, g, key=jax.random.PRNGKey(0))
    assert res.found
    assert res.epochs_run < CFG.epochs
    assert svc.stats_dict()["epochs_saved"] > 0


def test_infeasible_problem_reports_not_found():
    q = graphs.line_graph(6)
    g = graphs.line_graph(4)
    svc = MatcherService(CFG)
    res = svc.match(q, g)
    assert not res.found
    assert res.epochs_run == CFG.epochs     # never exits early


# -- async front end ----------------------------------------------------

from repro.core.service import AsyncServiceFrontEnd  # noqa: E402

FE_CFG = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)


def _frontend(max_depth=8, policy="shed", slack=0.1, classes=(1, 2, 4)):
    svc = MatcherService(FE_CFG, batch_classes=classes)
    return svc, AsyncServiceFrontEnd(svc, max_depth=max_depth,
                                     policy=policy,
                                     slack_threshold_s=slack)


def test_frontend_batch_full_trigger():
    svc, fe = _frontend()
    probs = [_planted(i, 6, 12) for i in range(4)]
    rids = [fe.submit(q, g, deadline=100.0, now=0.0) for q, g in probs]
    # 4th submit fills the largest batch class -> drains without polling
    assert fe.depth == 0
    s = svc.stats_dict()
    assert s["fe_drains"] == 1 and s["fe_drain_batch_full"] == 1
    assert s["fe_queue_peak"] == 4
    for rid in rids:
        assert fe.take_result(rid) is not None


def test_frontend_deadline_trigger_and_poll():
    svc, fe = _frontend(slack=0.1)
    q, g = _planted(0, 6, 12)
    rid = fe.submit(q, g, deadline=1.0, now=0.0)
    with pytest.raises(KeyError):
        fe.take_result(rid)             # still queued
    assert fe.next_deadline_check() == pytest.approx(0.9)
    assert fe.poll(now=0.5) == 0        # slack 0.5 > threshold
    assert fe.poll(now=0.95) == 1       # slack 0.05 <= threshold
    s = svc.stats_dict()
    assert s["fe_drain_deadline"] == 1
    assert s["fe_wait_s"] == pytest.approx(0.95)
    assert fe.take_result(rid) is not None


def test_frontend_shed_policy_bounds_depth():
    svc, fe = _frontend(max_depth=2, slack=0.0)
    q, g = _planted(1, 6, 12)
    kept = [fe.submit(q, g, deadline=1e9, now=0.0) for _ in range(2)]
    shed = fe.submit(q, g, deadline=1e9, now=0.0)
    assert fe.depth == 2
    s = svc.stats_dict()
    assert s["fe_shed"] == 1
    assert s["fe_admitted"] == 2 and s["fe_submitted"] == 3
    assert fe.take_result(shed) is None         # shed -> recorded None
    assert fe.flush(now=1.0) == 2
    assert svc.stats_dict()["fe_drain_flush"] == 1
    for rid in kept:
        assert fe.take_result(rid) is not None


def test_frontend_block_policy_forces_drain():
    svc, fe = _frontend(max_depth=2, slack=0.0)
    fe.policy = "block"
    q, g = _planted(2, 6, 12)
    rids = [fe.submit(q, g, deadline=1e9, now=float(i)) for i in range(3)]
    s = svc.stats_dict()
    assert s["fe_shed"] == 0
    assert s["fe_forced_drains"] == 1   # room was made, nothing dropped
    assert fe.depth == 1                # the post-drain admit
    fe.flush(now=3.0)
    for rid in rids:
        assert fe.take_result(rid) is not None


def test_frontend_counters_flow_through_stats_dict():
    svc, fe = _frontend()
    q, g = _planted(3, 6, 12)
    fe.submit(q, g, deadline=50.0, now=0.0)
    fe.flush(now=1.0)
    s = svc.stats_dict()
    for key in ("fe_submitted", "fe_admitted", "fe_shed",
                "fe_forced_drains", "fe_drains", "fe_drain_deadline",
                "fe_drain_batch_full", "fe_drain_flush",
                "fe_queue_peak", "fe_wait_s"):
        assert key in s
    assert s["fe_submitted"] == s["fe_admitted"] == 1
    assert s["fe_drains"] == s["fe_drain_flush"] == 1
