"""Workload zoo + preemptible DAG property tests."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.accel import EDGE
from repro.configs import ARCHS, get_config
from repro.core import preemptible_dag
from repro.workloads import WORKLOAD_ZOO, get_workload
from repro.workloads.zoo import lm_workload_from_config


@pytest.mark.parametrize("name", sorted(WORKLOAD_ZOO))
def test_zoo_graphs_valid(name):
    wg = get_workload(name)
    wg.validate()
    assert wg.total_macs > 1e6
    assert wg.total_bytes > 1e3
    adj = wg.adjacency()
    # weakly connected-ish: no fully isolated compute layer
    iso = (adj.sum(0) + adj.sum(1)) == 0
    assert iso.sum() <= 1, f"{name} has isolated layers"


def test_complexity_ordering():
    """Complex (LLM) workloads must carry more MACs than Simple ones."""
    simple = get_workload("mobilenetv2").total_macs
    complex_ = get_workload("llama3-8b-wl").total_macs
    assert complex_ > 5 * simple


@pytest.mark.parametrize("arch", ARCHS)
def test_every_arch_lowers_to_scheduler_workload(arch):
    """The bridge: all 10 assigned architectures are schedulable."""
    wl = lm_workload_from_config(get_config(arch), block_group=2)
    wl.validate()
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=2)
    assert pd.n > 0
    assert pd.graph.is_dag()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8))
def test_window_monotone_in_stages(window, max_split):
    wl = get_workload("resnet50")
    cap = EDGE.engine_tile_capacity_macs()
    pd1 = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=window,
        max_split=max_split)
    pd2 = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=window + 1,
        max_split=max_split)
    assert pd2.n >= pd1.n
    # tiles carry positive work and valid stages
    for t in pd1.tiles:
        assert t.macs > 0
        assert 0 <= t.stage < window


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5))
def test_progress_shrinks_remaining_window(progress):
    wl = get_workload("mobilenetv2")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, progress)], tile_capacity_macs=cap, window_stages=3)
    for t in pd.tiles:
        assert progress <= t.stage < progress + 3
