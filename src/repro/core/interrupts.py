"""Interrupt-driven preemption decisions (paper §3.3, Fig. 4).

Pure decision logic, driven by the event simulator in ``repro.sched`` (and
usable standalone). Two policies from the paper:

  * **adaptive single-core preemption ratio** — how many engines to free for
    the urgent task, scaled by its deadline pressure;
  * **largest-slack-first victim selection** — among running tasks, preempt
    those with the most execution-time slack so preemption does not cause
    *their* deadlines to be missed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class RunningTask:
    task_id: int
    priority: int                  # higher = more urgent
    engines: List[int]             # engines currently held
    remaining_time: float          # at current allocation
    deadline: float                # absolute
    live_bytes: float = 0.0        # context that must drain on preemption

    def slack(self, now: float) -> float:
        return (self.deadline - now) - self.remaining_time


@dataclasses.dataclass
class PreemptionDecision:
    victims: List[int]                       # task ids preempted
    freed_engines: List[int]
    engines_requested: int
    preemption_ratio: float


def adaptive_preemption_ratio(urgent_exec_time: float, ddl_window: float,
                              lo: float = 0.25, hi: float = 1.0) -> float:
    """Fraction of the (busy) array the urgent task may grab.

    Pressure ≈ exec_time / available_window: a task that barely fits its
    deadline takes the whole array; a relaxed one takes a quarter.
    """
    if ddl_window <= 0:
        return hi
    pressure = urgent_exec_time / ddl_window
    return float(np.clip(lo + (hi - lo) * pressure, lo, hi))


def select_victims(running: Sequence[RunningTask], idle_engines: List[int],
                   engines_needed: int, urgent_priority: int,
                   now: float) -> PreemptionDecision:
    """Free engines for the urgent task: idle first, then preempt
    lower-priority tasks in largest-slack-first order (paper Fig. 4 — tasks
    with slack absorb preemption without deadline violations; higher-priority
    running tasks are never interrupted)."""
    freed = list(idle_engines)
    victims: List[int] = []
    if len(freed) < engines_needed:
        candidates = [t for t in running if t.priority < urgent_priority]
        candidates.sort(key=lambda t: t.slack(now), reverse=True)
        for t in candidates:
            if len(freed) >= engines_needed:
                break
            victims.append(t.task_id)
            freed.extend(t.engines)
    return PreemptionDecision(
        victims=victims, freed_engines=freed,
        engines_requested=engines_needed,
        preemption_ratio=(len(freed) and engines_needed / len(freed) or 0.0))


def engines_needed_for(n_tiles: int, max_engines: int,
                       ratio: float) -> int:
    """Engine demand of a query window of ``n_tiles`` tiles, capped by the
    adaptive preemption ratio."""
    want = min(n_tiles, max_engines)
    return max(1, min(want, int(np.ceil(max_engines * ratio))))
