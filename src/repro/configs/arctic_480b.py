"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base]:
128-expert top-2 MoE in parallel with a dense residual FFN."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, kv_heads=8, d_ff=4864, vocab_size=32000,
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual_d_ff=4864),
    param_dtype="bfloat16")
