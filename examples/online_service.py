"""Online matcher service under a stream of unpredictable arrivals.

Simulates the scheduling hot path: DNN windows (query DAGs) arriving
against a changing free-engine set on the Edge array, served through the
``MatcherService``. The first arrival of each shape class pays the jit
compile; every repeat hits the compiled-shape cache, warm-starts from the
previous consensus S̄/S*, and early-exits as soon as a feasible mapping
clears the bound — microsecond-class decisions after warm-up.

    PYTHONPATH=src python examples/online_service.py
"""
import time

import jax
import numpy as np

from repro.accel import EDGE
from repro.accel.target_graph import (free_engine_graph,
                                      free_engine_signature)
from repro.core import preemptible_dag
from repro.core.pso import PSOConfig
from repro.core.service import MatcherService
from repro.workloads import get_workload


def main():
    cap = EDGE.engine_tile_capacity_macs()
    windows = {}
    for name in ("mobilenetv2", "resnet50"):
        pd = preemptible_dag.build_preemptible_dag(
            [(0, get_workload(name), 0)], tile_capacity_macs=cap,
            window_stages=2)
        windows[name] = pd.graph
        print(f"{name}: window of {pd.graph.n} tiles")

    # two platform states: all engines free / half the array busy
    free_all = [True] * EDGE.engines
    free_half = [e % 2 == 0 for e in range(EDGE.engines)]

    svc = MatcherService(PSOConfig(num_particles=32, epochs=4,
                                   inner_steps=8))
    rng = np.random.default_rng(0)
    arrivals = [(rng.choice(list(windows)), rng.random() < 0.5)
                for _ in range(12)]

    print(f"\n{'arrival':<22}{'bucket':<12}{'compiled':<10}"
          f"{'warm':<7}{'epochs':<8}latency")
    for i, (name, busy_half) in enumerate(arrivals):
        free = free_half if busy_half else free_all
        q = windows[name]
        tgt = free_engine_graph(EDGE, free)
        if q.n > tgt.n:             # window larger than the free array
            keep = np.arange(tgt.n)
            q = type(q)(adj=q.adj[np.ix_(keep, keep)], types=q.types[keep],
                        weights=q.weights[keep])
        t0 = time.perf_counter()
        res = svc.match(q, tgt, key=jax.random.PRNGKey(i),
                        workload_key=(name, free_engine_signature(free)))
        dt = time.perf_counter() - t0
        state = "half-busy" if busy_half else "idle"
        print(f"{name + '/' + state:<22}{str(res.bucket):<12}"
              f"{'hit' if res.compile_cache_hit else 'COMPILE':<10}"
              f"{'yes' if res.warm_hit else 'no':<7}"
              f"{res.epochs_run:<8}{dt * 1e3:9.2f} ms"
              + ("" if res.found else "   (infeasible)"))

    s = svc.stats_dict()
    print(f"\ncompile cache: {s['compile_cache_hits']}/{s['calls']} hits, "
          f"warm starts: {s['warm_hits']}/{s['calls']}, "
          f"epochs saved by early exit: {s['epochs_saved']}/"
          f"{s['epochs_budgeted']}")


if __name__ == "__main__":
    main()
