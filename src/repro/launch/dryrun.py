import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with ZERO device allocation (ShapeDtypeStruct
inputs, abstract state via jax.eval_shape):

  * proof the sharding is coherent (lower().compile() succeeds on the
    16×16 single-pod and 2×16×16 multi-pod production meshes),
  * ``compiled.memory_analysis()``   → bytes/device (fits-in-HBM proof),
  * ``compiled.cost_analysis()``     → HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the partitioned HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand sizes).

Also dry-runs the paper's own technique: the distributed PSO-Ullmann
matcher sharded over the full mesh (``--arch immsched-matcher``).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, arch_shapes, get_config, get_train_config,
                           input_specs, parallelism_profile)
from repro.configs.base import ShapeConfig, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.runtime import sharding as shd
from repro.runtime.serve_loop import make_decode_step, make_prefill_step
from repro.runtime.train_loop import (make_train_state, make_train_step,
                                      state_specs)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str) -> dict:
    """Per-device *wire bytes* of every collective in the partitioned HLO.

    Operand shapes are not printed in post-optimization HLO, so we use the
    RESULT shape R plus the replica-group size g with ring-algorithm wire
    costs per participating device:
        all-gather:          (g-1)/g · R          (R = gathered result)
        reduce-scatter:      (g-1)   · R          (R = scattered result)
        all-reduce:          2(g-1)/g · R         (RS + AG)
        all-to-all:          (g-1)/g · R
        collective-permute:  R
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVES:
            continue
        result = m.group(1)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(result))
        g = _group_size(line)
        if op == "all-gather":
            wire = nbytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif op == "all-reduce":
            wire = nbytes * 2 * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            wire = nbytes * (g - 1) / max(g, 1)
        else:                      # collective-permute
            wire = nbytes
        out[op] += wire
        count[op] += 1
    return {"bytes": {k: int(v) for k, v in out.items()}, "counts": count,
            "total_bytes": int(sum(out.values()))}


def model_flops(arch: str, shape: ShapeConfig) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference), D = tokens."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.expert_d_ff
        routed_all = cfg.num_layers * m.num_experts * per_expert
        routed_active = cfg.num_layers * m.top_k * per_expert
        active = total - routed_all + routed_active
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * active * tokens
    if shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch      # decode: 1 new token


def probe_config(arch: str, k: int):
    """Reduced-depth, fully-unrolled config: k pattern units.

    Pattern unit = 1 layer (dense/moe/vlm; deepseek keeps its dense
    block0), 1 enc + 1 dec layer (encdec), slstm_period layers (xlstm),
    shared_attn_period layers (zamba2). k = period+1 ("tail" probe) gives
    zamba2's trailing mamba-only layers.
    """
    cfg = get_config(arch)
    if cfg.family in ("dense", "moe", "vlm"):
        first = 1 if cfg.name.startswith("deepseek") else 0
        return cfg.replace(num_layers=first + k, unroll=True)
    if cfg.family in ("encdec", "audio"):
        return cfg.replace(num_layers=k, encoder_layers=k, unroll=True)
    if cfg.family == "ssm":
        return cfg.replace(num_layers=k * cfg.ssm.slstm_period, unroll=True)
    if cfg.family == "hybrid":
        period = cfg.ssm.shared_attn_period
        # k<=4: k groups; k==5 (sentinel): 2 groups + 1 tail mamba layer
        n = k * period if k <= 4 else 2 * period + 1
        return cfg.replace(num_layers=n, unroll=True)
    raise ValueError(cfg.family)


def pattern_counts(arch: str) -> dict:
    """How many pattern units the full config has (for probe scaling)."""
    cfg = get_config(arch)
    if cfg.family in ("dense", "moe", "vlm"):
        first = 1 if cfg.name.startswith("deepseek") else 0
        return {"units": cfg.num_layers - first, "tail": 0}
    if cfg.family in ("encdec", "audio"):
        assert cfg.num_layers == cfg.encoder_layers
        return {"units": cfg.num_layers, "tail": 0}
    if cfg.family == "ssm":
        return {"units": cfg.num_layers // cfg.ssm.slstm_period, "tail": 0}
    if cfg.family == "hybrid":
        period = cfg.ssm.shared_attn_period
        return {"units": cfg.num_layers // period,
                "tail": cfg.num_layers % period}
    raise ValueError(cfg.family)


def lower_cell(arch: str, shape: ShapeConfig, mesh, cfg=None, tcfg=None,
               batch_override: int = 0, microbatch_override: int = 0):
    """Build and lower the cell's step function. Returns `lowered`."""
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    B = batch_override or shape.global_batch
    profile = parallelism_profile(arch, shape.name)

    if shape.mode == "train":
        tcfg = tcfg or get_train_config(arch)
        if profile == "fsdp_only":
            # batch shards over ALL axes → no microbatch split needed
            microbatch_override = 1
        if microbatch_override:
            tcfg = __import__("dataclasses").replace(
                tcfg, microbatches=microbatch_override)
        state_abs = jax.eval_shape(
            lambda k: make_train_state(model, tcfg, k), key)
        sspecs = state_specs(state_abs, mesh, profile)
        batch = input_specs(arch, shape, abstract=True, batch_override=B)
        bspecs = shd.infer_batch_specs(batch, mesh, profile)
        step = make_train_step(model, tcfg, mesh, profile)
        jitted = jax.jit(step,
                         in_shardings=(shd.named(sspecs, mesh),
                                       shd.named(bspecs, mesh)),
                         out_shardings=(shd.named(sspecs, mesh), None),
                         donate_argnums=(0,))
        return jitted.lower(state_abs, batch)

    params_abs = jax.eval_shape(model.init, key)
    pspecs = shd.infer_param_specs(params_abs, mesh)

    if shape.mode == "prefill":
        batch = input_specs(arch, shape, abstract=True, batch_override=B)
        bspecs = shd.infer_batch_specs(batch, mesh)
        caches_abs = jax.eval_shape(
            lambda: model.init_caches(B, shape.seq_len))
        cspecs = shd.infer_cache_specs(caches_abs, mesh)
        step = make_prefill_step(model, mesh, max_len=shape.seq_len)
        jitted = jax.jit(step,
                         in_shardings=(shd.named(pspecs, mesh),
                                       shd.named(bspecs, mesh)),
                         out_shardings=(None, shd.named(cspecs, mesh)))
        return jitted.lower(params_abs, batch)

    # decode: one new token against a KV cache of seq_len
    batch = input_specs(arch, shape, abstract=True, batch_override=B)
    bspecs = shd.infer_batch_specs(batch, mesh)
    caches_abs = jax.eval_shape(
        lambda: model.init_caches(B, shape.seq_len))
    cspecs = shd.infer_cache_specs(caches_abs, mesh)
    step = make_decode_step(model, mesh)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step,
                     in_shardings=(shd.named(pspecs, mesh),
                                   shd.named(bspecs, mesh),
                                   shd.named(cspecs, mesh), None),
                     out_shardings=(None, None, shd.named(cspecs, mesh)),
                     donate_argnums=(2,))
    return jitted.lower(params_abs, batch, caches_abs, index)


def lower_matcher(mesh):
    """Dry-run the paper's technique itself on the production mesh."""
    from repro.core.matcher import build_distributed_match
    from repro.core.pso import PSOConfig
    n, m = 128, 128
    axis_names = tuple(mesh.axis_names)
    num_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    cfg = PSOConfig(num_particles=32, epochs=4, inner_steps=12,
                    quantized=True, backend="ref")
    fn = build_distributed_match((n, n), mesh, cfg, axis_names)
    keys = jax.ShapeDtypeStruct((num_shards, 2), jnp.uint32)
    Q = jax.ShapeDtypeStruct((n, n), jnp.uint8)
    G = jax.ShapeDtypeStruct((m, m), jnp.uint8)
    mask = jax.ShapeDtypeStruct((n, m), jnp.uint8)
    carry0 = (jax.ShapeDtypeStruct((n, m), jnp.float32),   # S*
              jax.ShapeDtypeStruct((), jnp.float32),       # f*
              jax.ShapeDtypeStruct((n, m), jnp.float32))   # S̄
    return fn.lower(keys, Q, G, mask, carry0)


def run_probe(arch: str, shape: ShapeConfig, mesh, mesh_name: str,
              k: int) -> dict:
    """Reduced-depth fully-unrolled probe compile: exact per-pattern-unit
    FLOPs/bytes/collectives (XLA counts while bodies once — probes have no
    layer while loops). benchmarks/roofline.py combines k=1,2(,3) probes
    into corrected full-depth terms."""
    t0 = time.time()
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
           "probe": k, "ok": False}
    try:
        cfg = probe_config(arch, k)
        tcfg = get_train_config(arch) if shape.mode == "train" else None
        B = shape.global_batch
        mb = 0
        if shape.mode == "train" and tcfg.microbatches > 1 and \
                parallelism_profile(arch, shape.name) != "fsdp_only":
            B = shape.global_batch // tcfg.microbatches
            mb = 1
        lowered = lower_cell(arch, shape, mesh, cfg=cfg,
                             batch_override=B, microbatch_override=mb)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        rec["collectives"] = collective_bytes(compiled.as_text())
        rec["probe_batch"] = B
        rec["microbatches_full"] = (
            1 if (tcfg is None
                  or parallelism_profile(arch, shape.name) == "fsdp_only")
            else tcfg.microbatches)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def run_cell(arch: str, shape, mesh, mesh_name: str) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": getattr(shape, "name", shape),
           "mesh": mesh_name, "ok": False}
    try:
        if arch == "immsched-matcher":
            lowered = lower_matcher(mesh)
            rec["model_flops"] = 0.0
        else:
            lowered = lower_cell(arch, shape, mesh)
            rec["model_flops"] = model_flops(arch, shape)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ca = compiled.cost_analysis() or {}
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    ma, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
            }
        except Exception:
            rec["memory"] = None
        rec["collectives"] = collective_bytes(compiled.as_text())
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or 'immsched-matcher'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="")
    ap.add_argument("--matcher", action="store_true",
                    help="include the distributed-matcher cell")
    ap.add_argument("--probes", action="store_true",
                    help="also run reduced-depth unrolled probe compiles "
                         "(single-pod mesh) for roofline correction")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, (
        "dry-run needs the 512-device XLA override (run this module "
        "directly, before any other jax init)")

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod-16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pods-2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = ARCHS if args.arch == "all" else [args.arch]
    results = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            if arch == "immsched-matcher":
                rec = run_cell(arch, "matcher_128x128", mesh, mesh_name)
                results.append(rec)
                _report(rec)
                continue
            shapes = arch_shapes(arch)
            if args.shape != "all":
                shapes = [s for s in shapes if s.name == args.shape]
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, mesh_name)
                results.append(rec)
                _report(rec)
                if args.probes and mesh_name == "pod-16x16":
                    cfgm = get_config(arch)
                    ks = [2, 3] + ([5] if cfgm.family == "hybrid" else [])
                    for k in ks:
                        prec = run_probe(arch, shape, mesh, mesh_name, k)
                        results.append(prec)
                        _report_probe(prec)
        if args.arch == "all" or args.matcher:
            rec = run_cell("immsched-matcher", "matcher_128x128", mesh,
                           mesh_name)
            results.append(rec)
            _report(rec)

    n_ok = sum(r["ok"] for r in results if "probe" not in r)
    results_cells = [r for r in results if "probe" not in r]
    results = results_cells + [r for r in results if "probe" in r]
    results, n_total = results, len(results_cells)
    probe_fail = sum(1 for r in results
                     if "probe" in r and not r["ok"])
    print(f"\nDRYRUN {n_ok}/{n_total} cells compiled OK"
          + (f" ({probe_fail} probe failures)" if probe_fail else ""))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if n_ok == n_total else 1


def _report_probe(rec: dict) -> None:
    if rec["ok"]:
        print(f"  [probe k={rec['probe']}] {rec['arch']} {rec['shape']} "
              f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
              f"coll={rec['collectives']['total_bytes']:.3e} "
              f"({rec['wall_s']}s)")
    else:
        print(f"  [probe k={rec['probe']} FAIL] {rec['arch']} "
              f"{rec['shape']} {rec.get('error', '')[:140]}")
    sys.stdout.flush()


def _report(rec: dict) -> None:
    if rec["ok"]:
        mem = rec.get("memory") or {}
        col = rec["collectives"]["total_bytes"]
        print(f"[OK ] {rec['mesh']:14s} {rec['arch']:20s} "
              f"{str(rec['shape']):12s} "
              f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
              f"coll={col:.3e} args={mem.get('argument_bytes', 0):.3e} "
              f"temp={mem.get('temp_bytes', 0):.3e} "
              f"({rec['wall_s']}s)")
    else:
        print(f"[FAIL] {rec['mesh']:14s} {rec['arch']:20s} "
              f"{str(rec['shape']):12s} {rec.get('error', '')[:160]}")
    sys.stdout.flush()


if __name__ == "__main__":
    sys.exit(main())
