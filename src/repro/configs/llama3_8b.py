"""Llama-3-8B [arXiv:2407.21783]: dense GQA, 128k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, kv_heads=8, d_ff=14336, vocab_size=128256,
    rope_theta=500000.0)
