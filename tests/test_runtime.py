"""Runtime-layer tests: optimizers, data pipeline, checkpointing, fault
tolerance, training convergence, sharding-spec inference."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import DataPipeline, SyntheticLMDataset
from repro.models import build_model
from repro.optim import adamw, adafactor
from repro.optim.schedule import warmup_cosine
from repro.runtime.ft import StepWatchdog, elastic_mesh_shape
from repro.runtime.train_loop import (cross_entropy_loss, make_train_state,
                                      make_train_step)

jax.config.update("jax_platform_name", "cpu")


# ----------------------------- optimizers ---------------------------------

def _quad_problem():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((3,))}

    def loss(p):
        pred = p["w"].sum(-1) + p["b"]
        return jnp.sum((pred - target) ** 2)

    return params, loss


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_reduce_loss(opt_name):
    params, loss = _quad_problem()
    opt = adamw(weight_decay=0.0) if opt_name == "adamw" else \
        adafactor(weight_decay=0.0)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(loss(params)) < l0 * 0.01


def test_adamw_bf16_states():
    params, loss = _quad_problem()
    opt = adamw(state_dtype="bfloat16")
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = jax.grad(loss)(params)
    params2, state2 = opt.update(g, state, params, 0.01)
    assert state2["v"]["w"].dtype == jnp.bfloat16
    assert not jnp.allclose(params2["w"], params["w"])


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((64, 32))}
    opt = adafactor()
    st_ = opt.init(params)
    assert st_["f"]["big"]["vr"].shape == (64,)
    assert st_["f"]["big"]["vc"].shape == (32,)


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


# ----------------------------- data pipeline ------------------------------

def test_pipeline_deterministic_and_resumable():
    ds = SyntheticLMDataset(vocab_size=100, seq_len=16, seed=7)
    p1 = DataPipeline(ds, global_batch=8)
    batches = [p1.next() for _ in range(5)]
    p2 = DataPipeline(ds, global_batch=8)
    p2.load_state_dict({"index": 3, "global_batch": 8})
    np.testing.assert_array_equal(p2.next()["tokens"],
                                  batches[3]["tokens"])


def test_pipeline_shards_disjoint_and_cover():
    ds = SyntheticLMDataset(vocab_size=1000, seq_len=8, seed=1)
    full = DataPipeline(ds, global_batch=8, shard=0, num_shards=1).next()
    s0 = DataPipeline(ds, global_batch=8, shard=0, num_shards=2).next()
    s1 = DataPipeline(ds, global_batch=8, shard=1, num_shards=2).next()
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_pipeline_elastic_reshard():
    ds = SyntheticLMDataset(vocab_size=100, seq_len=8, seed=2)
    p = DataPipeline(ds, global_batch=16, shard=0, num_shards=4)
    p.next()
    state = p.state_dict()
    p2 = DataPipeline(ds, global_batch=16, shard=0, num_shards=2)
    p2.load_state_dict(state, shard=1, num_shards=2)
    assert p2.local_batch == 8 and p2.index == 1


# ----------------------------- checkpointing ------------------------------

def test_checkpoint_roundtrip_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step": jnp.int32(7)}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, state, extras={"step": 7, "pipeline": {"index": 3,
                                                       "global_batch": 8}})
    mgr.save(9, state, extras={"step": 9, "pipeline": {"index": 5,
                                                       "global_batch": 8}})
    assert mgr.all_steps() == [7, 9]
    restored, extras = mgr.restore(state)
    assert extras["step"] == 9
    np.testing.assert_allclose(restored["params"]["w"],
                               np.arange(12.0).reshape(3, 4))


def test_checkpoint_gc_keeps_newest(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep=2)
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, extras={})
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, {"x": jnp.ones(4)}, extras={})
    mgr.wait()
    assert mgr.latest_step() == 1


# ----------------------------- fault tolerance ----------------------------

def test_watchdog_flags_straggler():
    wd = StepWatchdog(warmup=5)
    flagged = [wd.observe(0.1) for _ in range(20)]
    assert not any(flagged)
    assert wd.observe(1.0)      # 10x step time → straggler


def test_elastic_mesh_shapes():
    assert elastic_mesh_shape(512) == ((2, 16, 16),
                                       ("pod", "data", "model"))
    shape, axes = elastic_mesh_shape(496)   # lost a host: 480 usable
    assert shape[-1] == 16 and axes[-1] == "model"
    assert np.prod(shape) <= 496
    shape, _ = elastic_mesh_shape(256)
    assert np.prod(shape) == 256


# ----------------------------- loss & training ----------------------------

def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]]])
    labels = jnp.array([[0, 1]])
    loss = cross_entropy_loss(logits, labels, z_loss=0.0)
    manual = -(jax.nn.log_softmax(logits[0, 0])[0]
               + jax.nn.log_softmax(logits[0, 1])[1]) / 2
    np.testing.assert_allclose(loss, manual, rtol=1e-6)


def test_cross_entropy_ignores_negative_labels():
    logits = jnp.zeros((1, 3, 5))
    labels = jnp.array([[1, -1, 2]])
    loss = cross_entropy_loss(logits, labels, z_loss=0.0)
    np.testing.assert_allclose(loss, np.log(5.0), rtol=1e-6)


def test_train_step_reduces_loss_small_model():
    from tests.test_smoke_archs import reduce_config
    cfg = reduce_config(get_config("qwen1.5-0.5b"))
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       microbatches=2)
    step = jax.jit(make_train_step(model, tcfg, mesh=None),
                   donate_argnums=(0,))
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    pipe = DataPipeline(ds, global_batch=8)
    # memorize one repeated batch: loss must drop hard
    batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
    losses = []
    for _ in range(40):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::8]
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_single_batch():
    from tests.test_smoke_archs import reduce_config
    cfg = reduce_config(get_config("llama3-8b"))
    model = build_model(cfg)
    state = make_train_state(model, TrainConfig(microbatches=1),
                             jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    batch = {k: jnp.asarray(v)
             for k, v in DataPipeline(ds, global_batch=8).next().items()}
    outs = {}
    for M in (1, 4):
        tcfg = TrainConfig(microbatches=M, learning_rate=1e-3,
                           z_loss=0.0)
        step = make_train_step(model, tcfg, mesh=None)
        new_state, metrics = step(
            jax.tree.map(lambda x: x, state), batch)
        outs[M] = (float(metrics["loss"]),
                   np.asarray(jax.tree.leaves(new_state["params"])[0]))
    assert abs(outs[1][0] - outs[4][0]) < 5e-3
    np.testing.assert_allclose(outs[1][1], outs[4][1], atol=2e-4)
