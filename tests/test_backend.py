"""Kernel-backend layer: registry/selection precedence, and the parity
sweep — every kernel registered in ``KERNEL_NAMES`` must agree between the
Pallas suite (interpret mode) and the jnp oracle suite across shapes ×
mask dtypes, bitwise for integer outputs and allclose for float ones.
The sweep is driven off the registry itself: registering a kernel without
a parity case fails ``test_every_registered_kernel_has_parity_case``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pso
from repro.kernels import (ENV_VAR, KERNEL_NAMES, KernelBackend,
                           get_backend, register_backend,
                           registered_backends, resolve_backend_name)
from repro.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(1, 8, 16), (2, 40, 72)]
MASK_DTYPES = [jnp.uint8, jnp.int32]


class _Problem:
    """One random matching instance with planted singleton rows (so the
    injectivity half of the fused prune has work to do)."""

    def __init__(self, seed, B, n, m, mask_dtype):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        S = jax.random.uniform(k1, (B, n, m))
        self.S = S / S.sum(-1, keepdims=True)
        self.S_q = ref.quantize_s(self.S)
        Q = jax.random.bernoulli(k2, 0.3, (n, n)).astype(jnp.uint8)
        self.Q = jnp.triu(Q, k=1)                      # DAG
        G = jax.random.bernoulli(k3, 0.4, (m, m)).astype(jnp.uint8)
        self.G = jnp.triu(G, k=1)
        mask = jax.random.bernoulli(k4, 0.8, (n, m))
        mask = mask.at[:, 0].set(True)                 # no empty rows
        # plant singletons: rows 0 and n//2 keep exactly one candidate,
        # claiming their columns from every other row on the first
        # injectivity propagation
        for i, j in ((0, 1), (n // 2, min(3, m - 1))):
            mask = mask.at[i, :].set(False).at[i, j].set(True)
        self.mask = mask.astype(mask_dtype)
        self.Mb = jnp.broadcast_to(self.mask, (B, n, m)
                                   ).astype(mask_dtype)
        self.V = jax.random.normal(k5, (B, n, m)) * 0.1
        self.r = jax.random.uniform(k1, (B, 3))
        # a projected assignment for the feasibility kernel
        self.M_hat = ref.greedy_project(self.S[0], self.mask)
        # fused-epoch inputs: the B axis doubles as the particle axis N,
        # with 3 pre-drawn inner steps and a seeded local-best fitness
        self.f_local = -jnp.sum(self.S * self.S, axis=(1, 2))
        self.r_steps = jnp.stack([self.r * w for w in (0.25, 0.5, 0.75)])

    def epoch_args(self):
        """(S, V, S_local, f_local, S_star, f_star, S_bar, mask, Q, G,
        r_all) for one problem — the ``epoch_fused`` signature."""
        return (self.S, self.V, self.S, self.f_local, self.S[0],
                jnp.float32(-1e6), self.S.mean(0), self.mask, self.Q,
                self.G, self.r_steps)

    def epoch_args_batch(self):
        """Two stacked problems for ``epoch_fused_batch`` (problem 1 is
        the base instance, problem 2 a column-rolled variant)."""
        def two(x, axis=None):
            alt = jnp.roll(x, 1, axis=-1) if axis is not None else x
            return jnp.stack([x, alt])
        S2 = two(self.S, -1)
        return (S2, two(self.V, -1), S2, two(self.f_local),
                two(self.S[0], -1), jnp.full((2,), -1e6, jnp.float32),
                two(self.S.mean(0), -1), two(self.mask, -1), two(self.Q),
                two(self.G), two(self.r_steps))


_HYPER = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)

# Every registered kernel gets one invocation recipe; outputs are compared
# leaf-by-leaf across backends.
KERNEL_CASES = {
    "edge_fitness": lambda bk, p: bk.edge_fitness(p.S, p.Q, p.G),
    "edge_fitness_quantized":
        lambda bk, p: bk.edge_fitness_quantized(p.S_q, p.Q, p.G),
    "pso_update": lambda bk, p: bk.pso_update(
        p.S, p.V, p.S, p.S[0], p.S.mean(0), p.mask, p.r, **_HYPER),
    "ullmann_refine_step":
        lambda bk, p: bk.ullmann_refine_step(p.Mb, p.Q, p.G),
    "greedy_project": lambda bk, p: bk.greedy_project(p.S[0], p.mask),
    "masked_argmax": lambda bk, p: bk.masked_argmax(p.S[0], p.mask),
    "structured_project":
        lambda bk, p: bk.structured_project(p.S[0], p.Q, p.G, p.mask),
    "injectivity_prune": lambda bk, p: bk.injectivity_prune(p.mask),
    "is_feasible": lambda bk, p: bk.is_feasible(p.M_hat, p.Q, p.G),
    "prune_fixpoint": lambda bk, p: bk.prune_fixpoint(p.mask, p.Q, p.G),
    "prune_fixpoint_batch":
        lambda bk, p: bk.prune_fixpoint_batch(p.Mb, p.Q[None].repeat(
            p.Mb.shape[0], 0), p.G[None].repeat(p.Mb.shape[0], 0)),
    # the fused epoch covers both fitness paths across the sweep: the
    # single-problem case runs float, the batched case quantized
    "epoch_fused": lambda bk, p: bk.epoch_fused(*p.epoch_args(), **_HYPER),
    "epoch_fused_batch": lambda bk, p: bk.epoch_fused_batch(
        *p.epoch_args_batch(), quantized=True, **_HYPER),
    "quantize_s": lambda bk, p: bk.quantize_s(p.S),
    "dequantize_s": lambda bk, p: bk.dequantize_s(p.S_q),
    "row_normalize_quantized":
        lambda bk, p: bk.row_normalize_quantized(p.S_q[0], p.mask),
}


def _assert_leaves_match(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape
        if np.issubdtype(w.dtype, np.floating):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)
        else:
            np.testing.assert_array_equal(g, w)


def test_every_registered_kernel_has_parity_case():
    assert set(KERNEL_CASES) == set(KERNEL_NAMES)
    # and every backend actually provides every entry point
    for name in registered_backends():
        bk = get_backend(name)
        for k in KERNEL_NAMES:
            assert callable(getattr(bk, k))


@pytest.mark.parametrize("mask_dtype", MASK_DTYPES)
@pytest.mark.parametrize("B,n,m", SHAPES)
@pytest.mark.parametrize("kernel", sorted(KERNEL_CASES))
def test_backend_parity(kernel, B, n, m, mask_dtype):
    p = _Problem(hash((kernel, B, n, m)) % (2 ** 31), B, n, m, mask_dtype)
    got = KERNEL_CASES[kernel](get_backend("interpret"), p)
    want = KERNEL_CASES[kernel](get_backend("ref"), p)
    _assert_leaves_match(got, want)


# ---------------------- fused prune semantics ------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_prune_matches_legacy_alternation(backend):
    """The fused kernel must reproduce the original loose-jnp fixpoint
    (refine sweep alternating with injectivity prune) exactly, on a mask
    with planted singletons, and report ≥ 1 sweep."""
    p = _Problem(7, 1, 12, 20, jnp.uint8)
    legacy = ref.prune_mask_fixpoint(p.mask, p.Q, p.G)
    got, sweeps = get_backend(backend).prune_fixpoint(p.mask, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))
    assert int(sweeps) >= 1
    # idempotent: a fixpoint re-prunes to itself in one sweep
    again, sweeps2 = get_backend(backend).prune_fixpoint(got, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(got))
    assert int(sweeps2) == 1


def test_fused_prune_sweep_counts_agree_across_backends():
    p = _Problem(11, 1, 10, 16, jnp.uint8)
    _, s_ref = get_backend("ref").prune_fixpoint(p.mask, p.Q, p.G)
    _, s_int = get_backend("interpret").prune_fixpoint(p.mask, p.Q, p.G)
    assert int(s_ref) == int(s_int)


def test_fused_prune_respects_iteration_budget():
    p = _Problem(13, 1, 12, 20, jnp.uint8)
    for bk_name in ("ref", "interpret"):
        bk = get_backend(bk_name)
        one, sweeps = bk.prune_fixpoint(p.mask, p.Q, p.G, max_iters=1)
        want = ref.injectivity_prune(
            ref.ullmann_refine_step(p.mask, p.Q, p.G))
        np.testing.assert_array_equal(np.asarray(one), np.asarray(want))
        assert int(sweeps) <= 1


# ---------------------- fused epoch semantics ------------------------------

def _legacy_run_epoch(carry, key, Q, G, mask, cfg):
    """The pre-fusion ``run_epoch`` inner loop, verbatim: per-step PRNG
    splits inside a ``lax.scan`` over ~6 loose kernel dispatches. The
    fused path must reproduce it bitwise — including the RNG draw order
    and the ``f_star`` trace."""
    from repro.kernels import backend as kernel_backend
    bk = kernel_backend.for_config(cfg)
    S_star, f_star, S_bar = carry
    if cfg.gumbel_tau > 0:
        k_init, k_steps, k_gum = jax.random.split(key, 3)
    else:
        k_init, k_steps = jax.random.split(key)
        k_gum = key
    S, V = pso.init_particles(k_init, cfg.num_particles, mask)
    S_local = S
    f_local = pso._fitness(S, Q, G, cfg)
    best0 = jnp.argmax(f_local)
    better0 = f_local[best0] > f_star
    S_star = jnp.where(better0, S[best0], S_star)
    f_star = jnp.where(better0, f_local[best0], f_star)

    def inner(state, k):
        S, V, S_local, f_local, S_star, f_star = state
        r = jax.random.uniform(k, (cfg.num_particles, 3))
        S, V = bk.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                             omega=cfg.omega, c1=cfg.c1, c2=cfg.c2,
                             c3=cfg.c3, v_max=cfg.v_max)
        S = pso._maybe_requantize(S, mask, cfg)
        f = pso._fitness(S, Q, G, cfg)
        improved = f > f_local
        S_local = jnp.where(improved[:, None, None], S, S_local)
        f_local = jnp.maximum(f, f_local)
        b = jnp.argmax(f_local)
        better = f_local[b] > f_star
        S_star = jnp.where(better, S_local[b], S_star)
        f_star = jnp.where(better, f_local[b], f_star)
        return (S, V, S_local, f_local, S_star, f_star), f_star

    keys = jax.random.split(k_steps, cfg.inner_steps)
    (S, *_, S_star, f_star), f_trace = jax.lax.scan(
        inner, (S, V, S_local, f_local, S_star, f_star), keys)
    return pso._epoch_finish(S, S_star, f_star, f_trace, k_gum,
                             Q, G, mask, cfg)


def _assert_leaves_bitwise(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("gumbel_tau", [0.0, 0.3])
@pytest.mark.parametrize("quantized", [False, True])
def test_run_epoch_bitwise_equals_legacy_scan(quantized, gumbel_tau):
    """The refactored ``run_epoch`` (epoch prologue → fused-epoch seam →
    epilogue) on the ``ref`` backend is BITWISE the pre-fusion inline
    scan: same RNG key consumption, same ``f_star_trace``, same carry."""
    p = _Problem(21, 1, 10, 18, jnp.uint8)
    cfg = pso.PSOConfig(num_particles=6, epochs=1, inner_steps=5,
                        quantized=quantized, gumbel_tau=gumbel_tau,
                        backend="ref")
    key = jax.random.PRNGKey(3)
    carry0 = pso.default_carry(p.mask)
    got = pso.run_epoch(carry0, key, p.Q, p.G, p.mask, cfg)
    want = _legacy_run_epoch(carry0, key, p.Q, p.G, p.mask, cfg)
    _assert_leaves_bitwise(got, want)


@pytest.mark.parametrize("mask_dtype", MASK_DTYPES)
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("B,n,m", SHAPES)
def test_fused_epoch_bitwise_across_backends(B, n, m, quantized,
                                             mask_dtype):
    """The fused kernel's own outputs (S_final, S_star, f_star, f_trace)
    are bitwise-identical between the loose-scan ``ref`` path and the
    Pallas body in interpret mode — stronger than the allclose bar the
    float kernels in the generic sweep get."""
    p = _Problem(hash(("epoch", B, n, m)) % (2 ** 31), B, n, m, mask_dtype)
    args = p.epoch_args_batch()
    got = get_backend("interpret").epoch_fused_batch(
        *args, quantized=quantized, **_HYPER)
    want = get_backend("ref").epoch_fused_batch(
        *args, quantized=quantized, **_HYPER)
    _assert_leaves_bitwise(got, want)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_epoch_f_star_trace_monotone(backend):
    """Property: the in-epoch global best can only improve — the f_star
    trace is non-decreasing step over step, starts no lower than the
    seeded f_star, and ends at the returned f_star (both backends)."""
    p = _Problem(33, 4, 10, 18, jnp.uint8)
    args = p.epoch_args()
    _, _, f_star, f_trace = get_backend(backend).epoch_fused(
        *args, **_HYPER)
    trace = np.asarray(f_trace)
    assert np.all(np.diff(trace) >= 0)
    assert trace[0] >= float(args[5])     # seeded f_star lower-bounds it
    assert trace[-1] == np.asarray(f_star)


def test_epoch_rng_draws_match_scan_consumption():
    """Property: hoisting the per-step uniforms out of the scan (the
    ``r_all`` the fused kernel consumes) yields value-identical draws in
    the same order as splitting inside the loop — the RNG-consumption
    contract the bitwise parity above rests on."""
    k_steps = jax.random.PRNGKey(17)
    K, N = 6, 5
    keys = jax.random.split(k_steps, K)
    hoisted = jax.vmap(lambda k: jax.random.uniform(k, (N, 3)))(keys)
    _, scanned = jax.lax.scan(
        lambda c, k: (c, jax.random.uniform(k, (N, 3))), None, keys)
    np.testing.assert_array_equal(np.asarray(hoisted), np.asarray(scanned))
    # and _epoch_start feeds exactly these draws to the fused kernel
    p = _Problem(5, 1, 8, 16, jnp.uint8)
    cfg = pso.PSOConfig(num_particles=N, inner_steps=K, backend="ref")
    _, k_steps2 = jax.random.split(jax.random.PRNGKey(17))
    *_, r_all, _ = pso._epoch_start(
        pso.default_carry(p.mask), jax.random.PRNGKey(17),
        p.Q, p.G, p.mask, cfg)
    want = jax.vmap(lambda k: jax.random.uniform(k, (N, 3)))(
        jax.random.split(k_steps2, K))
    np.testing.assert_array_equal(np.asarray(r_all), np.asarray(want))


# ---------------------- registry + selection precedence --------------------

def test_selection_precedence(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # 4. platform default (CPU → ref)
    assert resolve_backend_name() == "ref"
    assert resolve_backend_name(config=pso.PSOConfig()) == "ref"
    # 3. env override beats the default (and "auto" configs)
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert resolve_backend_name() == "interpret"
    assert resolve_backend_name(config=pso.PSOConfig(backend="auto")) \
        == "interpret"
    # 2. an explicit config beats the env
    assert resolve_backend_name(config=pso.PSOConfig(backend="ref")) == "ref"
    # 1. an explicit argument beats everything
    assert resolve_backend_name(
        "pallas", config=pso.PSOConfig(backend="ref")) == "pallas"
    assert get_backend("interpret").name == "interpret"


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(KeyError, match="registered"):
        get_backend("no-such-backend")


def test_register_custom_backend_roundtrip():
    class Custom(KernelBackend):
        pass

    try:
        register_backend(Custom("custom-test", ops_backend="ref"))
        assert "custom-test" in registered_backends()
        bk = get_backend("custom-test")
        assert isinstance(bk, Custom)
        p = _Problem(3, 1, 8, 16, jnp.uint8)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("custom-test", None)


def test_register_custom_backend_defaults_and_casing():
    """The documented recipe must work as written: a suite registered
    with no ops_backend runs its inherited kernels on the platform
    default path, and mixed-case names resolve through every selection
    route (names are normalized)."""
    try:
        register_backend(KernelBackend("MySuite"))
        bk = get_backend("MySuite")          # arg path, caller's casing
        assert bk.name == "mysuite"
        assert get_backend(config=pso.PSOConfig(backend="MySuite")) is bk
        p = _Problem(5, 1, 8, 16, jnp.uint8)
        # inherited kernel: platform default ("auto" → ref on CPU)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("mysuite", None)
    # an explicit dispatch tag the ops layer cannot honour fails loudly
    with pytest.raises(ValueError, match="dispatch tag"):
        KernelBackend("broken", ops_backend="no-such-tag")


# ---------------------- the seam end-to-end --------------------------------

@pytest.mark.slow
def test_match_runs_on_interpret_backend():
    """The whole Algorithm-1 program compiles and solves a planted
    instance with every kernel routed through the Pallas-interpret
    suite — the seam reaches every call site, not just the leaf tests."""
    from repro.core import graphs
    key = jax.random.PRNGKey(0)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 4, 0.4)
    g = graphs.embed_query_in_target(kt, q, 8)
    Q, G, mask = graphs.as_device_graphs(q, g)
    cfg = pso.PSOConfig(num_particles=4, epochs=1, inner_steps=2,
                        refine_iters=2, backend="interpret")
    outs = pso.match(key, Q, G, mask, cfg)
    ref_cfg = cfg.replace(backend="ref")
    outs_ref = pso.match(key, Q, G, mask, ref_cfg)
    # same pruned search space, same sweep count, and both find the
    # planted embedding
    assert int(outs["prune_sweeps"]) == int(outs_ref["prune_sweeps"])
    assert bool(np.asarray(outs["feasible"]).any())
    assert bool(np.asarray(outs_ref["feasible"]).any())
