"""End-to-end matcher tests: Algorithm 1 finds planted subgraph matchings,
agrees with the serial Ullmann baseline and the exhaustive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs, pso, ullmann
from repro.core.matcher import IMMSchedMatcher

jax.config.update("jax_platform_name", "cpu")


def _planted(seed, n, m, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _check_mapping(mapping, q, g):
    assert mapping is not None
    M = np.asarray(mapping, dtype=np.int64)
    assert (M.sum(axis=1) == 1).all()
    assert (M.sum(axis=0) <= 1).all()
    covered = M @ g.adj.astype(np.int64) @ M.T
    assert (covered >= q.adj).all()


@pytest.mark.slow
@pytest.mark.parametrize("seed,n,m", [(0, 6, 12), (1, 8, 16), (2, 10, 24)])
def test_matcher_finds_planted_match(seed, n, m):
    q, g = _planted(seed, n, m)
    # planted instances can have a UNIQUE monomorphism — give the
    # swarm a realistic budget (the paper runs 128 engines × particles)
    cfg = pso.PSOConfig(num_particles=96, epochs=6, inner_steps=10)
    res = IMMSchedMatcher(cfg).match(q, g, key=jax.random.PRNGKey(seed))
    assert res.found, f"no feasible mapping found (f*={res.f_star})"
    _check_mapping(res.mapping, q, g)


@pytest.mark.slow
def test_matcher_quantized_mode_finds_match():
    q, g = _planted(3, 8, 16)
    cfg = pso.PSOConfig(num_particles=48, epochs=4, inner_steps=10,
                        quantized=True)
    res = IMMSchedMatcher(cfg).match(q, g, key=jax.random.PRNGKey(3))
    assert res.found
    _check_mapping(res.mapping, q, g)


def test_serial_ullmann_agrees_with_oracle():
    q, g = _planted(4, 6, 10)
    mask = graphs.compatibility_mask(q, g)
    sols = ullmann.serial_ullmann(q.adj, g.adj, mask, max_solutions=5)
    assert sols, "serial Ullmann must find the planted match"
    for M in sols:
        _check_mapping(M, q, g)
    # oracle agreement on feasibility existence
    assert ullmann.count_monomorphisms(q.adj, g.adj, mask, limit=10) > 0


def test_serial_ullmann_rejects_impossible():
    # query = triangle-ish chain longer than the target path
    q = graphs.line_graph(5)
    g = graphs.line_graph(3)
    mask = np.ones((5, 3), dtype=np.uint8)
    assert ullmann.serial_ullmann(q.adj, g.adj, mask) == []
    assert ullmann.count_monomorphisms(q.adj, g.adj) == 0


def test_matcher_reports_infeasible():
    q = graphs.line_graph(6)
    g = graphs.line_graph(4)
    cfg = pso.PSOConfig(num_particles=16, epochs=2, inner_steps=6)
    res = IMMSchedMatcher(cfg).match(q, g)
    assert not res.found


def test_fitness_trace_monotone():
    """The global-best trace must be non-decreasing within an epoch
    (stability property the continuous relaxation buys — Fig. 2b)."""
    q, g = _planted(5, 8, 16)
    Q, G, mask = graphs.as_device_graphs(q, g)
    cfg = pso.PSOConfig(num_particles=32, epochs=3, inner_steps=8)
    outs = pso.match(jax.random.PRNGKey(0), Q, G, mask, cfg)
    trace = np.asarray(outs["f_star_trace"])  # (T, K)
    for t in range(trace.shape[0]):
        assert (np.diff(trace[t]) >= -1e-5).all()


def test_gumbel_projection_restores_diversity_after_collapse():
    """Deterministic projection maps similar particles to few distinct
    assignments; the Gumbel-perturbed structured projection explores
    strictly more of the assignment space from the same swarm."""
    q, g = _planted(10, 10, 24)
    Q, G, mask = graphs.as_device_graphs(q, g)
    cfg = pso.PSOConfig(num_particles=16, epochs=1, inner_steps=8,
                        prune_mask=False)
    carry = pso.default_carry(mask)

    def distinct_projections(tau):
        _, outs = pso.run_epoch(carry, jax.random.PRNGKey(0), Q, G, mask,
                                cfg.replace(gumbel_tau=tau))
        maps = np.asarray(outs["mappings"])
        return len({m.tobytes() for m in maps})

    det, gum = distinct_projections(0.0), distinct_projections(0.35)
    assert gum > det, (det, gum)


@pytest.mark.slow
def test_gumbel_projection_unstalls_nonpruned_quantized_instance():
    """Regression for the ROADMAP quantized-diversity open item: on this
    non-pruned planted instance the deterministic projection stalls (the
    fractional optimum beats the best integral solution and every
    consensus-collapsed particle projects to the same near-miss), while
    the Gumbel-perturbed projection finds the planted match."""
    q, g = _planted(10, 10, 24)
    cfg = pso.PSOConfig(num_particles=48, epochs=6, inner_steps=8,
                        prune_mask=False, quantized=True)
    key = jax.random.PRNGKey(3000)
    det = IMMSchedMatcher(cfg).match(q, g, key=key)
    assert not det.found          # deterministic projection stalls here
    gum = IMMSchedMatcher(cfg.replace(gumbel_tau=0.35)).match(q, g, key=key)
    assert gum.found
    _check_mapping(gum.mapping, q, g)


def test_masked_entries_never_assigned():
    q, g = _planted(6, 8, 16)
    mask = graphs.compatibility_mask(q, g)
    cfg = pso.PSOConfig(num_particles=32, epochs=3, inner_steps=8)
    res = IMMSchedMatcher(cfg).match(q, g, key=jax.random.PRNGKey(1))
    if res.found:
        assert (np.asarray(res.mapping) <= mask).all()
