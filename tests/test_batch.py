"""Batched-problem matcher: ``pso.match_batch`` equivalence with
independent calls, per-problem early exit, service request coalescing
(submit/drain/match_many), batch padding + occupancy accounting, and
compile-LRU eviction under many shape buckets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs, pso
from repro.core.service import MatcherService

jax.config.update("jax_platform_name", "cpu")

CFG = pso.PSOConfig(num_particles=24, epochs=3, inner_steps=8,
                    early_exit=True)


def _planted(seed, n, m, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _stack_problems(pairs):
    Qs, Gs, masks = [], [], []
    for q, g in pairs:
        Q, G, mask = graphs.as_device_graphs(q, g)
        Qs.append(Q)
        Gs.append(G)
        masks.append(mask)
    return jnp.stack(Qs), jnp.stack(Gs), jnp.stack(masks)


# ---------------------------------------------------------------------------
# pso.match_batch
# ---------------------------------------------------------------------------

def test_match_batch_equals_independent_calls():
    """B stacked problems must return the same feasibility/fitness per
    problem as B independent ``match`` calls with the same keys."""
    pairs = [_planted(s, 6, 12) for s in range(4)]
    Qb, Gb, maskb = _stack_problems(pairs)
    keys = jnp.stack([np.asarray(jax.random.PRNGKey(100 + i))
                      for i in range(4)])
    outs_b = pso.match_batch(keys, Qb, Gb, maskb, CFG)
    for b in range(4):
        outs_1 = pso.match(jax.random.PRNGKey(100 + b),
                           Qb[b], Gb[b], maskb[b], CFG)
        np.testing.assert_array_equal(
            np.asarray(outs_b["feasible"])[:, b],
            np.asarray(outs_1["feasible"]))
        np.testing.assert_allclose(
            np.asarray(outs_b["fitness"])[:, b],
            np.asarray(outs_1["fitness"]), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(outs_b["f_star"])[b],
            np.asarray(outs_1["f_star"]), rtol=1e-6)
        assert int(np.asarray(outs_b["epochs_run"])[b]) == \
            int(np.asarray(outs_1["epochs_run"]))


def test_match_batch_per_problem_early_exit():
    """An easy problem exits after its first feasible epoch even when a
    hard (infeasible) neighbour keeps the batch running all T epochs."""
    easy_q, easy_g = _planted(2, 6, 12)
    hard_q, hard_g = graphs.line_graph(6), graphs.line_graph(4)
    # pad the infeasible line problem into the easy problem's shapes
    from repro.core.preemptible_dag import pad_problem
    from repro.core.graphs import compatibility_mask
    Qe, Ge, me = graphs.as_device_graphs(easy_q, easy_g)
    mask_h = compatibility_mask(hard_q, hard_g)
    Qh, Gh, mh = pad_problem(hard_q.adj, hard_g.adj, mask_h,
                             Qe.shape[0], Ge.shape[0])
    Qb = jnp.stack([Qe, jnp.asarray(Qh)])
    Gb = jnp.stack([Ge, jnp.asarray(Gh)])
    maskb = jnp.stack([me, jnp.asarray(mh)])
    keys = jnp.stack([np.asarray(jax.random.PRNGKey(0)),
                      np.asarray(jax.random.PRNGKey(1))])
    outs = pso.match_batch(keys, Qb, Gb, maskb, CFG)
    epochs = np.asarray(outs["epochs_run"])
    assert epochs[0] < CFG.epochs          # easy: early exit
    assert epochs[1] == CFG.epochs         # infeasible: full budget
    feas = np.asarray(outs["feasible"])
    assert feas[:, 0].any()
    assert not feas[:, 1].any()


def test_match_batch_warm_carry_roundtrip():
    """Stacked warm-start carries feed back per problem."""
    pairs = [_planted(s, 6, 12) for s in (0, 2)]
    Qb, Gb, maskb = _stack_problems(pairs)
    keys = jnp.stack([np.asarray(jax.random.PRNGKey(i)) for i in (5, 6)])
    cold = pso.match_batch(keys, Qb, Gb, maskb, CFG)
    carry = (cold["S_star"], cold["f_star"], cold["S_bar"])
    warm = pso.match_batch(keys, Qb, Gb, maskb, CFG, carry0=carry)
    assert (np.asarray(warm["f_star"])
            >= np.asarray(cold["f_star"]) - 1e-6).all()


# ---------------------------------------------------------------------------
# MatcherService coalescing
# ---------------------------------------------------------------------------

def test_match_many_coalesces_one_launch():
    probs = [_planted(s, 6, 12) for s in range(3)]
    svc = MatcherService(CFG)
    res = svc.match_many([(q, g) for q, g in probs],
                         keys=[jax.random.PRNGKey(i) for i in range(3)])
    assert len(res) == 3
    for r in res:
        assert r.coalesced and r.batch_size == 3
        assert r.bucket == (8, 16)
    # same latency charged once across the batch
    assert len({r.latency_s for r in res}) == 1
    s = svc.stats_dict()
    assert s["batch_launches"] == 1
    assert s["coalesced_requests"] == 3
    assert s["batch_problems"] == 3
    assert s["batch_slots"] == 4            # padded to class 4
    assert s["batch_occupancy"] == pytest.approx(0.75)
    assert s["calls"] == 3


def test_match_many_matches_sequential_per_problem():
    """Batched results must match sequential results problem-for-problem
    (same found flags and identical best mappings)."""
    probs = [_planted(s, 6, 12) for s in range(4)]
    keys = [jax.random.PRNGKey(40 + i) for i in range(4)]
    svc_b = MatcherService(CFG)
    batched = svc_b.match_many([(q, g) for q, g in probs], keys=keys)
    svc_s = MatcherService(CFG)
    for i, (q, g) in enumerate(probs):
        seq = svc_s.match(q, g, key=keys[i])
        assert seq.found == batched[i].found
        assert seq.feasible_count == batched[i].feasible_count
        if seq.found:
            np.testing.assert_array_equal(np.asarray(seq.mapping),
                                          np.asarray(batched[i].mapping))


def test_match_many_mixed_buckets_submission_order():
    """Requests spanning two shape buckets come back in submission order,
    grouped into one launch per bucket."""
    qa, ga = _planted(0, 6, 12)     # bucket (8, 16)
    qb, gb = _planted(2, 10, 24)    # bucket (16, 32)
    qc, gc = _planted(1, 8, 16)     # bucket (8, 16)
    svc = MatcherService(CFG)
    res = svc.match_many([(qa, ga), (qb, gb), (qc, gc)])
    assert [r.bucket for r in res] == [(8, 16), (16, 32), (8, 16)]
    assert res[0].batch_size == 2 and res[2].batch_size == 2
    assert res[1].batch_size == 1 and not res[1].coalesced
    assert svc.stats_dict()["batch_launches"] == 2


def test_submit_drain_warm_start_scatter():
    """Per-problem warm carries are gathered/scattered at the batch
    boundary: a second drain of the same problems warm-hits them all."""
    probs = [_planted(s, 6, 12) for s in (0, 1, 2)]
    svc = MatcherService(CFG)
    for i, (q, g) in enumerate(probs):
        svc.submit(q, g, workload_key=f"wl{i}")
    cold = svc.drain()
    assert svc.pending == 0
    assert not any(r.warm_hit for r in cold)
    for i, (q, g) in enumerate(probs):
        svc.submit(q, g, workload_key=f"wl{i}")
    warm = svc.drain()
    assert all(r.warm_hit for r in warm)
    for c, w in zip(cold, warm):
        assert w.f_star >= c.f_star - 1e-6
        assert w.epochs_run <= c.epochs_run
    s = svc.stats_dict()
    assert s["warm_hits"] == 3 and s["warm_misses"] == 3
    # the second drain batch-revalidates all three stored carries in ONE
    # Tier-0 launch; only revalidation misses fall through to a swarm
    # sized to the miss subset (never the full batch again)
    assert s["tier0_launches"] == 1 and s["tier0_checked"] == 3
    t2_warm = s["tier2_checked"] - 3          # cold drain swarmed all 3
    assert s["tier0_hits"] + t2_warm == 3
    # compiles: cold swarm class + Tier-0 revalidation class (+ at most
    # one smaller swarm class for the revalidation misses)
    assert 2 <= s["compile_cache_misses"] <= 3


def test_drain_empty_is_noop():
    svc = MatcherService(CFG)
    assert svc.drain() == []
    assert svc.stats_dict()["batch_launches"] == 0


def test_oversize_burst_splits_into_class_chunks():
    """More requests than the largest batch class split into multiple
    launches, all slots accounted."""
    probs = [_planted(s, 6, 12) for s in range(5)]
    svc = MatcherService(CFG, batch_classes=(1, 2, 4))
    res = svc.match_many([(q, g) for q, g in probs])
    assert len(res) == 5
    s = svc.stats_dict()
    assert s["batch_launches"] == 2          # 4 + 1
    assert s["batch_problems"] == 5
    assert s["batch_slots"] == 5             # class 4 + class 1
    assert res[0].batch_size == 4 and res[4].batch_size == 1


# ---------------------------------------------------------------------------
# compile-LRU under many shape buckets
# ---------------------------------------------------------------------------

def test_lru_eviction_many_buckets_stats_consistent():
    """Cycling more (bucket, batch-class) executables than the cache
    holds: evicted buckets recompile, and hit/miss counters stay
    consistent with the number of lookups."""
    cfg = CFG
    problems = {
        (8, 16): _planted(0, 6, 12),
        (16, 32): _planted(2, 10, 24),
        (8, 32): _planted(3, 5, 26),
    }
    svc = MatcherService(cfg, cache_capacity=2)
    buckets = list(problems)
    # first pass: 3 cold compiles into a capacity-2 LRU -> 1 eviction
    for b in buckets:
        q, g = problems[b]
        r = svc.match(q, g)
        assert r.bucket == b, (r.bucket, b)
    s = svc.stats_dict()
    assert s["compile_cache_misses"] == 3
    assert svc.stats.compile_evictions == 1
    assert len(svc._compiled) == 2

    # the oldest bucket was evicted -> recompile; the newest still hits
    q, g = problems[buckets[0]]
    r = svc.match(q, g)
    assert not r.compile_cache_hit
    q, g = problems[buckets[2]]
    r = svc.match(q, g)
    assert r.compile_cache_hit

    # batched launches share the same LRU under (bucket, class) keys
    q, g = problems[buckets[0]]
    svc.match_many([(q, g), (q, g)])
    s = svc.stats_dict()
    assert len(svc._compiled) == 2
    # 6 executable lookups: 5 single + 1 batched (a coalesced launch pays
    # ONE lookup for its whole batch)
    assert s["compile_cache_hits"] + s["compile_cache_misses"] == 6
    # every miss inserts an executable; what isn't resident was evicted
    assert svc.stats.compile_evictions == \
        s["compile_cache_misses"] - len(svc._compiled)
    assert s["calls"] == 7
