"""DeepSeek-V2-236B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE
(2 shared + 160 routed, top-6); first layer dense."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, kv_heads=128, d_ff=12288, vocab_size=102400,
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                  shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    param_dtype="bfloat16")
