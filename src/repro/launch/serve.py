"""Serving driver: prefill + batched greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --reduced --batch 4 --prompt-len 64 --gen 32

Demonstrates the serve path the decode_* dry-run cells lower: one prefill
step, then token-at-a-time decode against donated cache buffers.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import reduced_config
from repro.models import build_model
from repro.runtime.serve_loop import make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, d_model=256, layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(2,))

    key = jax.random.PRNGKey(args.seed + 1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model), jnp.float32)
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    pos = args.prompt_len + (8 if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.gen - 1):
        step_batch = {"tokens": tok[:, None]}
        if cfg.mrope:
            p = jnp.full((3, args.batch, 1), pos + i, jnp.int32)
            step_batch["positions3"] = p
        tok, logits, caches = decode(params, step_batch, caches,
                                     jnp.int32(pos + i))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {t_prefill * 1e3:.0f} ms, "
          f"decode {t_decode * 1e3:.0f} ms ({tps:.1f} tok/s)")
    print("sample generation (token ids):", gen[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
