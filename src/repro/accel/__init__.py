from repro.accel.platform import EDGE, CLOUD, Platform, get_platform
from repro.accel.target_graph import free_engine_graph, target_graph
from repro.accel.energy import CostModel
