"""Schedule two of the framework's OWN LM architectures side-by-side on
the Cloud engine array: lower both configs to tile DAGs (Layer
Concatenate-and-Split + DAG-to-Pipeline), match the merged preemptible DAG
with the parallel matcher, and emit + validate the ILP schedule tensors
X ∈ {0,1}^{D×I×N×T×P}, Y ∈ {0,1}^{D×I×K×T×L}.

    PYTHONPATH=src python examples/schedule_multi_dnn.py
"""
import numpy as np

from repro.accel import CLOUD
from repro.accel.target_graph import free_engine_graph
from repro.configs import get_config
from repro.core import ilp, preemptible_dag
from repro.core.matcher import IMMSchedMatcher
from repro.core.pso import PSOConfig
from repro.workloads.zoo import lm_workload_from_config


def main():
    wl_a = lm_workload_from_config(get_config("qwen2.5-3b"), block_group=2)
    wl_b = lm_workload_from_config(get_config("llama3-8b"), block_group=2)
    cap = CLOUD.engine_tile_capacity_macs()
    pdag = preemptible_dag.build_preemptible_dag(
        [(0, wl_a, 0), (1, wl_b, 0)], tile_capacity_macs=cap,
        window_stages=3)
    print(f"merged preemptible DAG: {pdag.n} tiles "
          f"({ {k: len(v) for k, v in pdag.task_tiles.items()} } per task)")

    target = free_engine_graph(CLOUD, [True] * CLOUD.engines)
    cfg = PSOConfig(num_particles=64, epochs=4, inner_steps=10)
    res = IMMSchedMatcher(cfg).match(pdag.graph, target)
    assert res.found, "no feasible co-schedule found"
    print(f"feasible co-schedules found: {res.feasible_count}")

    st = ilp.build_schedule_tensors(pdag, np.asarray(res.mapping), CLOUD)
    errs = ilp.validate_schedule(st, pdag)
    print(f"ILP tensors: X{st.X.shape} Y{st.Y.shape} "
          f"violations: {errs or 'none'}")
    busy = st.X.sum(axis=(0, 1, 3, 4)) > 0
    print(f"engines used: {int(busy.sum())}/{CLOUD.engines}")


if __name__ == "__main__":
    main()
