"""Drain-pipeline benchmark: pipelined vs serial host-sync discipline.

Measures what the device-resident drain pipeline actually buys on warm
multi-bucket traffic — the workload the paper's scheduling-overhead
claim is about. Two arms run the *same* warm workload:

  * **pipelined** (default ``MatcherService``): every bucket group's
    Tier-0 launch is dispatched before anything blocks; the whole drain
    pays ONE batched device→host fetch.
  * **serial** (``pipelined=False``): the legacy discipline this PR
    replaced — warm carries staged through host numpy (a blocking
    ``np.asarray`` round trip per stored carry part) and each launch
    blocking on its own fetch before the next is built, so the device
    idles while the host decides.

Both arms must return bitwise-identical results (asserted per repeat);
the JSON decomposes drain wall time into the host-stall census the
service counts (``host_syncs``, ``host_sync_wall_s``,
``host_bytes_transferred``) so the ratio is attributable, not vibes.

Outputs ``BENCH_pipeline.json`` (see ``bench_report.py``) with the
headline ``pipelined_over_serial_ratio`` plus the regression flags CI
gates on: ``bitwise_equal``, ``pipelined_leq_serial_ok``, and the warm
``host_syncs_per_drain`` budget (1 sync per all-warm drain).

Usage::

    PYTHONPATH=src python -m benchmarks.bench_pipeline --out BENCH_pipeline.json
    PYTHONPATH=src python -m benchmarks.bench_pipeline --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import graphs, pso
from repro.core.service import MatcherService

# one planted problem per distinct (n_pad, m_pad) bucket: warm drains
# then carry one Tier-0 revalidation launch per bucket, which is the
# many-launches/little-host-work regime where serial per-launch syncs
# dominate
BUCKET_CANDS: Tuple[Tuple[int, int], ...] = (
    (4, 8), (4, 20), (4, 36), (4, 52),
    (10, 12), (10, 28), (10, 44), (10, 60),
    (18, 20), (18, 36),
)


def _planted(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


class _Workload:
    """A fixed roster of planted warm problems, one per bucket, with
    problem/key arrays cached so repeated drains measure the service,
    not problem generation."""

    def __init__(self, cands, max_seeds: int = 16):
        self.cands = tuple(cands)
        self.max_seeds = max_seeds
        self._probs: Dict[Tuple[int, int, int], tuple] = {}
        self._keys: Dict[int, jax.Array] = {}
        self.specs: List[Tuple[int, int, int]] = []

    def prob(self, s: int, n: int, m: int):
        if (s, n, m) not in self._probs:
            self._probs[(s, n, m)] = _planted(s, n, m)
        return self._probs[(s, n, m)]

    def key(self, s: int) -> jax.Array:
        if s not in self._keys:
            self._keys[s] = jax.random.PRNGKey(s)
        return self._keys[s]

    def warm(self, svc: MatcherService) -> List[Tuple[int, int, int]]:
        """Drain each bucket's candidates cold then warm, and keep the
        first seed per bucket that revalidates (Tier-0 hit + found).
        Returns the roster (also cached on ``self.specs``)."""
        specs = []
        for n, m in self.cands:
            cands = [(s, n, m) for s in range(self.max_seeds)]
            for _ in range(2):
                for s, n_, m_ in cands:
                    q, g = self.prob(s, n_, m_)
                    svc.submit(q, g, key=self.key(s),
                               workload_key=(f"{n_}x{m_}", s))
                warm = svc.drain()
            good = [c for c, r in zip(cands, warm)
                    if r.tier == 0 and r.found]
            if not good:      # pragma: no cover - seed-dependent
                raise RuntimeError(f"no warm candidate for bucket {n}x{m}")
            specs.append(good[0])
        self.specs = specs
        return specs

    def drain_once(self, svc: MatcherService):
        """Submit the warm roster (untimed) and time one drain."""
        for s, n, m in self.specs:
            q, g = self.prob(s, n, m)
            svc.submit(q, g, key=self.key(s),
                       workload_key=(f"{n}x{m}", s))
        t0 = time.perf_counter()
        results = svc.drain()
        return time.perf_counter() - t0, results


def _fingerprint(results) -> tuple:
    """Bitwise identity of a drain's results: mapping bytes + scalars."""
    return tuple((np.asarray(r.mapping).tobytes(), bool(r.found),
                  int(r.tier), float(r.f_star), int(r.epochs_run))
                 for r in results)


def _census_delta(svc: MatcherService, before: Dict[str, float]
                  ) -> Dict[str, float]:
    sd = svc.stats_dict()
    return {k: sd[k] - before.get(k, 0)
            for k in ("drains", "host_syncs", "host_bytes_transferred",
                      "host_sync_wall_s", "donated_launches")}


def bench_warm_drain(cfg: pso.PSOConfig, repeats: int) -> dict:
    """Headline experiment: the same all-warm multi-bucket drain through
    both arms, medians over ``repeats``, bitwise parity per repeat."""
    wl = _Workload(BUCKET_CANDS)
    pipe = MatcherService(cfg)
    serial = MatcherService(cfg, pipelined=False)
    wl.warm(pipe)
    specs_serial = _Workload(wl.cands)
    specs_serial._probs, specs_serial._keys = wl._probs, wl._keys
    specs_serial.warm(serial)
    if specs_serial.specs != wl.specs:  # pragma: no cover - determinism
        raise RuntimeError("arms warmed onto different rosters")

    wl.drain_once(pipe)
    wl.drain_once(serial)           # one untimed settle drain per arm
    census_p0, census_s0 = pipe.stats_dict(), serial.stats_dict()

    pipe_s, serial_s = [], []
    bitwise = True
    all_warm = True
    for _ in range(repeats):
        tp, rp = wl.drain_once(pipe)
        ts, rs = wl.drain_once(serial)
        pipe_s.append(tp)
        serial_s.append(ts)
        bitwise &= _fingerprint(rp) == _fingerprint(rs)
        all_warm &= all(r.tier == 0 and r.found for r in rp)

    pm, sm = statistics.median(pipe_s), statistics.median(serial_s)
    cp = _census_delta(pipe, census_p0)
    cs = _census_delta(serial, census_s0)
    out = {
        "buckets": len(BUCKET_CANDS),
        "problems_per_drain": len(wl.specs),
        "repeats": repeats,
        "pipelined_median_s": pm,
        "serial_median_s": sm,
        "pipelined_over_serial_ratio": pm / max(sm, 1e-12),
        "pipelined_host_syncs_per_drain": cp["host_syncs"]
        / max(cp["drains"], 1),
        "serial_host_syncs_per_drain": cs["host_syncs"]
        / max(cs["drains"], 1),
        "pipelined_host_stall_frac": cp["host_sync_wall_s"]
        / max(sum(pipe_s), 1e-12),
        "serial_host_stall_frac": cs["host_sync_wall_s"]
        / max(sum(serial_s), 1e-12),
        "host_bytes_per_drain": cp["host_bytes_transferred"]
        / max(cp["drains"], 1),
        "donated_launches": cp["donated_launches"],
        "all_tier0": bool(all_warm),
        "bitwise_equal": bool(bitwise),
        "pipelined_leq_serial_ok": bool(pm <= sm * 1.02),
        "warm_single_sync_ok": bool(
            cp["host_syncs"] / max(cp["drains"], 1) <= 1.0),
    }
    out["pool"] = {k: pipe.stats_dict()[k]
                   for k in ("pool_puts", "pool_gathers", "pool_live_rows")}
    return out


def bench_cold_drain(cfg: pso.PSOConfig, repeats: int) -> dict:
    """Secondary arm comparison on cold (all-swarm) drains: parity must
    hold there too, and the single-sync budget grows to one per tier
    stage, not per launch."""
    wl = _Workload(BUCKET_CANDS[:4])
    pipe = MatcherService(cfg, warm_start=False)
    serial = MatcherService(cfg, warm_start=False, pipelined=False)
    wl.specs = [(0, n, m) for n, m in wl.cands]
    wl.drain_once(pipe)
    wl.drain_once(serial)           # compile
    census_p0, census_s0 = pipe.stats_dict(), serial.stats_dict()
    pipe_s, serial_s, bitwise = [], [], True
    for _ in range(repeats):
        tp, rp = wl.drain_once(pipe)
        ts, rs = wl.drain_once(serial)
        pipe_s.append(tp)
        serial_s.append(ts)
        bitwise &= _fingerprint(rp) == _fingerprint(rs)
    pm, sm = statistics.median(pipe_s), statistics.median(serial_s)
    cp = _census_delta(pipe, census_p0)
    cs = _census_delta(serial, census_s0)
    return {
        "buckets": len(wl.cands),
        "repeats": repeats,
        "pipelined_median_s": pm,
        "serial_median_s": sm,
        "pipelined_over_serial_ratio": pm / max(sm, 1e-12),
        "pipelined_host_syncs_per_drain": cp["host_syncs"]
        / max(cp["drains"], 1),
        "serial_host_syncs_per_drain": cs["host_syncs"]
        / max(cs["drains"], 1),
        "bitwise_equal": bool(bitwise),
        # cold drains are swarm-compute-bound; the dispatch discipline is
        # in the noise there, so this is informational, not a gate
        "pipelined_leq_serial_diagnostic": bool(pm <= sm * 1.10),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + few repeats (CI gate)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()

    if args.smoke:
        cfg = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)
        repeats = args.repeats or 7
    else:
        cfg = pso.PSOConfig(num_particles=32, epochs=2, inner_steps=8)
        repeats = args.repeats or 41

    report = {
        "bench": "pipeline",
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "config": {"num_particles": cfg.num_particles, "epochs": cfg.epochs,
                   "inner_steps": cfg.inner_steps},
        "warm_drain": bench_warm_drain(cfg, repeats),
        "cold_drain": bench_cold_drain(cfg, max(repeats // 3, 3)),
    }

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)

    for name in ("warm_drain", "cold_drain"):
        r = report[name]
        print(f"{name},pipelined_us,{r['pipelined_median_s'] * 1e6:.1f}")
        print(f"{name},serial_us,{r['serial_median_s'] * 1e6:.1f}")
        print(f"{name},ratio,{r['pipelined_over_serial_ratio']:.3f}")
        print(f"{name},bitwise_equal,{r['bitwise_equal']}")
    wd = report["warm_drain"]
    print(f"warm_drain,host_syncs_per_drain,"
          f"{wd['pipelined_host_syncs_per_drain']:.2f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
