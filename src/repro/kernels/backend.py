"""Pluggable kernel-backend layer: ONE seam between algorithm and kernels.

Everything in core/ (PSO epochs, the distributed matcher, the online
service) used to hand-wire its kernel calls — ``ref.structured_project``
here, ``ops.pso_update(backend=...)`` there — so adding an optimized
kernel meant touching every call site. This module replaces that with a
registry of :class:`KernelBackend` suites:

  * ``ref``       — jit'd pure-jnp oracles (kernels/ref.py). CPU default.
  * ``pallas``    — compiled Pallas TPU kernels (MXU-padded via ops.py).
  * ``interpret`` — the Pallas kernels in interpret mode (CPU validation).

Core code resolves a backend ONCE per (static) config —
``bk = backend.for_config(cfg)`` at trace time — and calls kernel entry
points on the suite; no ``ref.*`` / ``*_pallas`` import appears outside
``kernels/``.

**Selection precedence** (first match wins):

  1. explicit name passed to :func:`get_backend`,
  2. ``PSOConfig.backend`` when it is not ``"auto"``,
  3. the ``REPRO_KERNEL_BACKEND`` environment variable,
  4. the platform default (``pallas`` on TPU, else ``ref``).

The env override is read at *trace* time (backends are resolved where
jit-compiled programs are built), so set it before the first match call
of the process — it exists for deployments that cannot thread a config
through (benchmarks, smoke jobs, canaries).

**Registering a new kernel** is one step, not another hand-wired pair:
implement the reference path as a :class:`KernelBackend` method (append
its name to ``KERNEL_NAMES`` so the parity sweep in
``tests/test_backend.py`` refuses to pass until every backend agrees),
and route the optimized path through the same method — exactly how the
fused ``prune_fixpoint`` landed. Custom suites (a new accelerator, an
instrumented shim) subclass :class:`KernelBackend`, override what they
optimize, and call :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, Optional, Tuple

import jax

from repro.kernels import ops, ref

#: Canonical kernel entry points every backend must provide. The parity
#: test sweep iterates THIS tuple — adding a kernel without extending the
#: sweep fails tests, so the list cannot silently rot.
KERNEL_NAMES: Tuple[str, ...] = (
    "edge_fitness",
    "edge_fitness_quantized",
    "pso_update",
    "ullmann_refine_step",
    "greedy_project",
    "masked_argmax",
    "structured_project",
    "injectivity_prune",
    "is_feasible",
    "prune_fixpoint",
    "prune_fixpoint_batch",
    "epoch_fused",
    "epoch_fused_batch",
    "epoch_finish",
    "epoch_finish_batch",
    "quantize_s",
    "dequantize_s",
    "row_normalize_quantized",
)

ENV_VAR = "REPRO_KERNEL_BACKEND"

#: Dispatch tags the padding/dispatch layer (kernels/ops.py) understands.
_OPS_TAGS = ("ref", "pallas", "interpret", "auto")

#: Buffer-donation metadata for the service's executable calling
#: conventions, keyed by executable kind (see
#: ``MatcherService._resolve_executable``). The value is the argnums of
#: the stacked warm-carry pytree that is safe to donate: the batched
#: kinds receive freshly gathered/stacked carry arrays that nothing else
#: references, so XLA may update particle/controller state in place
#: (halving peak carry memory per launch). The single-problem ``match``
#: kind donates nothing — its carry input can alias a stored CarryStore
#: entry, and donating it would invalidate the store.
SERVICE_DONATABLE_ARGNUMS: Dict[str, Tuple[int, ...]] = {
    "match": (),            # fn(key,  Q,  G,  mask,  carry0)
    "batch": (4,),          # fn(keys, Qb, Gb, maskb, carry0)
    "reval": (3,),          # fn(Qb, Gb, maskb, carry0)
}


def donate_argnums_for(kind: str) -> Tuple[int, ...]:
    """Donatable argnums for one service-executable kind (empty tuple
    for unknown kinds — unknown calling conventions never donate)."""
    return SERVICE_DONATABLE_ARGNUMS.get(kind, ())


class KernelBackend:
    """One kernel suite: every matcher kernel behind a uniform surface.

    ``name`` is the registry key (normalized to lowercase — selection via
    config/env lowercases too, so any casing resolves); ``ops_backend``
    the dispatch tag handed to the padding/dispatch layer (kernels/ops.py)
    for the kernels that have a Pallas implementation. A custom suite
    that omits it inherits the platform default path (``"auto"``) for
    every kernel it does not override. Kernels without a Pallas
    implementation (the host-shaped constructive projection, feasibility,
    quantization helpers) run the shared jnp path on every backend —
    overriding them in a subclass is how an optimized version would land.

    Shapes are *logical* (unpadded); MXU-alignment padding happens inside
    the ops layer. Per-particle kernels are batched over a leading B axis
    exactly like ops.py; per-problem kernels (projection, feasibility,
    prune) take a single problem unless suffixed ``_batch``.
    """

    def __init__(self, name: str, ops_backend: Optional[str] = None):
        self.name = name.strip().lower()
        if ops_backend is None:
            ops_backend = self.name if self.name in _OPS_TAGS else "auto"
        if ops_backend not in _OPS_TAGS:
            raise ValueError(
                f"ops_backend {ops_backend!r} is not a dispatch tag the "
                f"ops layer understands ({_OPS_TAGS}); custom suites "
                f"should pick the tag their non-overridden kernels run "
                f"on (or omit it for the platform default)")
        self._ops = ops_backend

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"KernelBackend({self.name!r})"

    # -- fitness -----------------------------------------------------------

    def edge_fitness(self, S, Q, G):
        """Batched float fitness -||Q - S G Sᵀ||². S: (B, n, m) → (B,)."""
        return ops.edge_fitness(S, Q, G, backend=self._ops)

    def edge_fitness_quantized(self, S_q, Q, G, scale: int = 255):
        """Fixed-point fitness (uint8 S, int32 MACs). → (B,) f32."""
        return ops.edge_fitness_quantized(S_q, Q, G, scale=scale,
                                          backend=self._ops)

    # -- swarm update ------------------------------------------------------

    def pso_update(self, S, V, S_local, S_star, S_bar, mask, r, *,
                   omega, c1, c2, c3, v_max=1.0):
        """Fused velocity/position/mask/normalize step, batched."""
        return ops.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                              omega=omega, c1=c1, c2=c2, c3=c3,
                              v_max=v_max, backend=self._ops)

    # -- refinement / pruning ----------------------------------------------

    def ullmann_refine_step(self, M, Q, G):
        """One refinement sweep, batched. M: (B, n, m) → (B, n, m)."""
        return ops.ullmann_refine_step(M, Q, G, backend=self._ops)

    def injectivity_prune(self, M):
        """All-different propagation on one (n, m) candidate matrix."""
        return ref.injectivity_prune(M)

    def prune_fixpoint(self, mask, Q, G, max_iters: int = 0):
        """Fused pre-prune of ONE (n, m) mask to fixpoint.

        Returns ``(pruned_mask, sweeps)`` — sweeps is the int32 number of
        fused (refine + injectivity) iterations executed.
        """
        out, sweeps = self.prune_fixpoint_batch(
            mask[None], Q[None], G[None], max_iters=max_iters)
        return out[0], sweeps[0]

    def prune_fixpoint_batch(self, maskb, Qb, Gb, max_iters: int = 0):
        """Fused pre-prune, batched over problems with per-problem Q/G."""
        return ops.prune_fixpoint(maskb, Qb, Gb, max_iters=max_iters,
                                  backend=self._ops)

    # -- fused epoch loop --------------------------------------------------

    def epoch_fused(self, S, V, S_local, f_local, S_star, f_star, S_bar,
                    mask, Q, G, r_all, *, omega, c1, c2, c3, v_max,
                    quantized: bool = False):
        """The entire K-step epoch inner loop for ONE problem.

        Particle state ``S/V/S_local`` (N, n, m) + ``f_local`` (N,)
        stays device-resident (VMEM on the fused path) across all K
        steps; ``r_all`` (K, N, 3) holds the pre-drawn per-step uniform
        randoms (same values, same order as drawing inside the loop).
        Returns ``(S_final, S_star, f_star, f_trace (K,), f_last (N,))``
        — ``f_last`` is the last inner step's per-particle fitness,
        threaded into the fused tail so the epilogue never recomputes
        it.
        """
        outs = self.epoch_fused_batch(
            S[None], V[None], S_local[None], f_local[None], S_star[None],
            f_star[None], S_bar[None], mask[None], Q[None], G[None],
            r_all[None], omega=omega, c1=c1, c2=c2, c3=c3, v_max=v_max,
            quantized=quantized)
        return tuple(x[0] for x in outs)

    def epoch_fused_batch(self, S, V, S_local, f_local, S_star, f_star,
                          S_bar, mask, Q, G, r_all, *, omega, c1, c2, c3,
                          v_max, quantized: bool = False):
        """Fused epoch loop batched over a leading problem axis P (the
        ``match_batch``/``revalidate_batch`` layout) — one kernel grid
        over problems, NOT a vmap of the single-problem entry point."""
        return ops.epoch_fused(S, V, S_local, f_local, S_star, f_star,
                               S_bar, mask, Q, G, r_all, omega=omega,
                               c1=c1, c2=c2, c3=c3, v_max=v_max,
                               quantized=quantized, backend=self._ops)

    # -- fused epoch tail --------------------------------------------------

    def epoch_finish(self, S, f_final, gum, mask, Q, G, *, gumbel_tau,
                     refine_threshold, refine_iters, elite_k,
                     consensus_temp):
        """The entire epoch epilogue for ONE problem, fused.

        (Gumbel-perturbed) structured projection, greedy projection +
        Ullmann candidate refinement, per-particle feasibility and the
        elite consensus in one body. ``S``: (N, n, m) final swarm;
        ``f_final``: (N,) the fused epoch kernel's last-step fitness
        (threaded through instead of recomputed); ``gum``: (N, n, m)
        pre-drawn Gumbel noise or ``None`` when ``gumbel_tau == 0``.
        Returns ``(M_hat (N, n, m) uint8, feasible (N,) bool,
        S_bar (n, m) f32)``.
        """
        outs = self.epoch_finish_batch(
            S[None], f_final[None], None if gum is None else gum[None],
            mask[None], Q[None], G[None], gumbel_tau=gumbel_tau,
            refine_threshold=refine_threshold, refine_iters=refine_iters,
            elite_k=elite_k, consensus_temp=consensus_temp)
        return tuple(x[0] for x in outs)

    def epoch_finish_batch(self, S, f_final, gum, mask, Q, G, *,
                           gumbel_tau, refine_threshold, refine_iters,
                           elite_k, consensus_temp):
        """Fused epoch tail batched over a leading problem axis P — one
        kernel grid over problems, so an epoch of ``run_epoch_batch``
        is exactly two launches (``epoch_fused_batch`` → this)."""
        return ops.epoch_finish(S, f_final, gum, mask, Q, G,
                                gumbel_tau=gumbel_tau,
                                refine_threshold=refine_threshold,
                                refine_iters=refine_iters,
                                elite_k=elite_k,
                                consensus_temp=consensus_temp,
                                backend=self._ops)

    def ullmann_refine_candidates(self, S, M_proj, Q, G, mask, *,
                                  refine_threshold, refine_iters):
        """Candidate refinement of paper line 20 for ONE problem,
        batched over particles: threshold ∪ projection candidate set,
        ``refine_iters`` sweeps through :meth:`ullmann_refine_step`,
        structured re-projection with an empty-row fallback to
        ``M_proj``. Returns ``(M_hat uint8, cand uint8)``. Composed
        from this suite's own sweep/projection kernels so a subclass
        overriding those automatically refines through them.
        """
        import jax
        import jax.numpy as jnp
        rowmax = S.max(axis=-1, keepdims=True)
        cand = ((S >= refine_threshold * rowmax) | (M_proj > 0))
        cand = (cand & (mask[None] > 0)).astype(jnp.uint8)

        def sweep(_, c):
            return self.ullmann_refine_step(c, Q, G)

        cand = jax.lax.fori_loop(0, refine_iters, sweep, cand)
        S_restricted = S * cand.astype(S.dtype)
        M_hat = jax.vmap(lambda s, c: self.structured_project(s, Q, G, c))(
            S_restricted, cand)
        empty_rows = cand.sum(-1, keepdims=True) == 0
        M_hat = jnp.where(empty_rows, M_proj, M_hat)
        return M_hat.astype(jnp.uint8), cand

    def elite_consensus(self, S_all, f_all, *, elite_k, consensus_temp):
        """S̄: softmax-weighted average of the ``elite_k`` fittest
        particles (paper line 24). Returns ``(weighted, weight_total,
        w)`` so the distributed matcher can psum the parts across
        devices before dividing. The fused tail computes the same
        reduction in-kernel; this standalone entry point serves the
        mesh builders and any caller outside the epoch hot path."""
        from repro.kernels.finish_fused import elite_consensus_reference
        return elite_consensus_reference(S_all, f_all, elite_k=elite_k,
                                         consensus_temp=consensus_temp)

    # -- projection / verification -----------------------------------------

    def greedy_project(self, S, mask):
        """Greedy argmax projection of one relaxed (n, m) S → uint8 M̂."""
        return ops.greedy_project(S, mask, backend=self._ops)

    def masked_argmax(self, X, mask):
        """Masked global argmax → (value, flat index)."""
        return ops.masked_argmax(X, mask, backend=self._ops)

    def structured_project(self, S, Q, G, mask):
        """Adjacency-guided constructive projection (one problem)."""
        return ref.structured_project(S, Q, G, mask)

    def is_feasible(self, M, Q, G):
        """Injective-assignment + edge-cover feasibility of one mapping."""
        return ref.is_feasible(M, Q, G)

    # -- quantization helpers ----------------------------------------------

    def quantize_s(self, S, scale: int = 255):
        """Quantize relaxed mappings S ∈ [0,1] to uint8 (× ``scale``)."""
        return ref.quantize_s(S, scale)

    def dequantize_s(self, S_q, scale: int = 255):
        """Inverse of :meth:`quantize_s`: uint8 S_q → float32 / scale."""
        return ref.dequantize_s(S_q, scale)

    def row_normalize_quantized(self, S_q, mask, scale: int = 255):
        """Divide-free row renormalization of a quantized (n, m) S_q
        (reciprocal-multiply model of the accelerator datapath)."""
        return ref.row_normalize_quantized(S_q, mask, scale)


# ---------------------------------------------------------------------------
# Registry + selection
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, KernelBackend] = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    """Register (or replace) a backend under ``backend.name``."""
    _REGISTRY[backend.name] = backend
    return backend


def registered_backends() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


for _name in ("ref", "pallas", "interpret"):
    register_backend(KernelBackend(_name))
del _name


def _platform_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def resolve_backend_name(name: Optional[str] = None,
                         config=None) -> str:
    """Resolve the selection precedence to a concrete registry name.

    ``name``: explicit request (highest precedence). ``config``: anything
    with a ``backend`` attribute (``PSOConfig``); its value counts unless
    it is ``"auto"``/empty. Then the ``REPRO_KERNEL_BACKEND`` env var,
    then the platform default.
    """
    for cand in (name,
                 getattr(config, "backend", None),
                 os.environ.get(ENV_VAR)):
        if cand:
            cand = str(cand).strip().lower()
            if cand and cand != "auto":
                return cand
    return _platform_default()


def get_backend(name: Optional[str] = None, *, config=None) -> KernelBackend:
    """Look up the selected :class:`KernelBackend` (see precedence above)."""
    resolved = resolve_backend_name(name, config)
    try:
        return _REGISTRY[resolved]
    except KeyError:
        raise KeyError(
            f"unknown kernel backend {resolved!r}; registered: "
            f"{sorted(_REGISTRY)} (register custom suites via "
            f"repro.kernels.backend.register_backend)") from None


def for_config(cfg) -> KernelBackend:
    """The backend a (static) ``PSOConfig`` selects — the one call core/
    makes at trace time."""
    return get_backend(config=cfg)


def config_digest(cfg, *, extra: Tuple = ()) -> str:
    """Stable content digest of everything that shapes a compiled kernel.

    The on-disk AOT executable cache and the service snapshots both need
    a key that changes whenever a recompiled program could differ or a
    stored carry could stop being meaningful. This digest covers:

      * the **resolved backend suite name** (the full selection
        precedence, so flipping ``REPRO_KERNEL_BACKEND`` or
        ``PSOConfig.backend`` invalidates cached executables),
      * every field of the (frozen dataclass) config, sorted by name —
        any ``PSOConfig`` knob that alters the traced program changes
        the digest,
      * caller-supplied ``extra`` components (the service adds its shape
        bucketing parameters, jax version, and target platform).

    Returns a 16-hex-char prefix of the SHA-1 — collision-safe at cache
    sizes (dozens of executables), short enough for file names. Configs
    that are not dataclasses fall back to ``repr`` (stable for the
    ``PSOConfig``-like objects this repo passes)."""
    name = resolve_backend_name(config=cfg)
    if dataclasses.is_dataclass(cfg):
        fields = sorted(dataclasses.asdict(cfg).items())
    else:  # pragma: no cover - non-dataclass configs
        fields = repr(cfg)
    payload = repr((name, fields, tuple(extra)))
    return hashlib.sha1(payload.encode("utf-8")).hexdigest()[:16]
