"""Shared model primitives: norms, RoPE/M-RoPE, initializers, dtype policy.

Parameters are plain nested dicts of jnp arrays (pytrees); every module is
an ``init_*`` returning params and an ``apply``-style pure function. Layer
stacks hold *stacked* params (leading layer axis) consumed by ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-ish, standard for LMs)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        math.prod(shape[a] for a in in_axis)
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (1.0 / math.sqrt(d))).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 500000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: Tuple[int, int, int],
                theta: float = 1000000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions3: (3, B, S) — temporal/h/w
    position streams (equal for pure text). The head dim is partitioned
    into ``sections`` (t, h, w) frequency bands, each rotated by its own
    position stream."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, d)
    freqs = rope_freqs(d, theta)                       # (half,)
    # select the position stream per frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)      # (half,)
    # gather: angle[b, s, f] = positions3[sec_id[f], b, s] * freqs[f]
    p = positions3.astype(jnp.float32)                 # (3, B, S)
    pos_f = p[sec_id]                                  # (half, B, S)
    angles = jnp.moveaxis(pos_f, 0, -1) * freqs        # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """(q_len, kv_len) bool mask; q_offset = absolute position of query 0
    (int or traced scalar)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return q_pos >= kv_pos


def stack_init(init_fn, key, n: int):
    """vmap an init over a leading layer axis (stacked params for scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
