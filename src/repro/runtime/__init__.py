from repro.runtime import sharding
from repro.runtime.train_loop import make_train_step, make_train_state
from repro.runtime.serve_loop import make_prefill_step, make_decode_step
