from repro.core import graphs, ilp, interrupts, preemptible_dag, pso, ullmann
from repro.core.matcher import IMMSchedMatcher, MatchResult, \
    build_distributed_match
from repro.core.pso import PSOConfig
