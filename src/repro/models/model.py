"""Model assembly: per-family builders exposing a uniform API.

    build_model(cfg) -> BuiltModel with
        .init(key)                                  -> params
        .train_logits(params, batch)                -> (B, S, V) logits
        .prefill(params, batch, max_len)            -> (logits, caches)
        .decode(params, batch, caches, index)       -> (logits, caches)
        .init_caches(batch_size, max_len)           -> caches pytree

Families: dense (llama3/qwen*), moe (+MLA for deepseek-v2, +dense residual
for arctic), ssm (xlstm), hybrid (zamba2), encdec (seamless), vlm
(qwen2-vl text backbone + stub patch embeddings).

Layer stacks are ``lax.scan`` over stacked params (compile-time O(1) in
depth); per-block remat policy via ``jax.checkpoint``. Caches carry a
leading layer axis and ride the same scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ffn, moe, ssm
from repro.models.common import dense_init, embed_init

CACHE_DTYPE = jnp.bfloat16


@dataclasses.dataclass
class BuiltModel:
    cfg: ModelConfig
    init: Callable
    train_logits: Callable
    prefill: Callable
    decode: Callable
    init_caches: Callable
    num_params: Callable


# ---------------------------------------------------------------------------
# Transformer decoder block (dense / moe / mla variants)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    k_attn, k_ffn = jax.random.split(key)
    p = {"ln1": common.init_rmsnorm(cfg.d_model, dtype),
         "ln2": common.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.mla is not None:
        p["attn"] = attention.init_mla(k_attn, cfg)
    else:
        p["attn"] = attention.init_gqa(k_attn, cfg)
    if cfg.moe is not None:
        p["ffn"] = moe.init_moe(k_ffn, cfg)
    else:
        p["ffn"] = ffn.init_mlp(k_ffn, cfg.d_model, cfg.d_ff, dtype)
    return p


def _apply_block(p, cfg: ModelConfig, x, positions, cache, cache_index,
                 dense_override: bool = False):
    h = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = attention.mla_attention(p["attn"], cfg, h, positions,
                                               cache, cache_index)
    else:
        a, new_cache = attention.gqa_attention(p["attn"], cfg, h, positions,
                                               cache, cache_index)
    x = x + a
    h = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None and not dense_override:
        f = moe.moe_ffn(p["ffn"], cfg, h)
    else:
        f = ffn.mlp(p["ffn"], h, common.dt(cfg.compute_dtype))
    return x + f, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "full" else
              jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _scan_stack(block_fn, stacked_params, x, caches, unroll: bool = False):
    """Scan a block over stacked layer params (+ optional stacked caches).
    ``unroll=True`` (dry-run probes) emits straight-line code so XLA's cost
    analysis sees every layer."""
    kw = dict(unroll=True) if unroll else {}
    if caches is None:
        def body(carry, p_l):
            y, _ = block_fn(p_l, carry, None)
            return y, None
        x, _ = jax.lax.scan(body, x, stacked_params, **kw)
        return x, None

    def body(carry, inp):
        p_l, cache_l = inp
        y, new_cache = block_fn(p_l, carry, cache_l)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked_params, caches), **kw)
    return x, new_caches


# ---------------------------------------------------------------------------
# Shared embedding / head
# ---------------------------------------------------------------------------

def _init_embed_head(key, cfg: ModelConfig) -> dict:
    dtype = common.dt(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"embed": embed_init(k1, cfg.vocab_size, cfg.d_model, dtype),
         "final_ln": common.init_rmsnorm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def _embed(p, cfg, tokens):
    cd = common.dt(cfg.compute_dtype)
    return p["embed"].astype(cd)[tokens]


def _head(p, cfg, x):
    cd = common.dt(cfg.compute_dtype)
    x = common.rmsnorm(p["final_ln"], x, cfg.norm_eps)
    w = (p["embed"].T if cfg.tie_embeddings else p["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x.astype(cd), w.astype(cd))


def _default_positions(batch):
    tokens = batch["tokens"]
    if "positions" in batch:
        return batch["positions"]
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


# ---------------------------------------------------------------------------
# Dense / MoE / VLM decoder-only family
# ---------------------------------------------------------------------------

def _build_decoder_only(cfg: ModelConfig) -> BuiltModel:
    first_dense = 1 if (cfg.moe is not None and cfg.name.startswith(
        "deepseek")) else 0
    n_scanned = cfg.num_layers - first_dense

    def init(key):
        k_eh, k_first, k_stack, k_fe = jax.random.split(key, 4)
        p = _init_embed_head(k_eh, cfg)
        if first_dense:
            dense_cfg = cfg.replace(moe=None, d_ff=cfg.d_ff or
                                    4 * cfg.d_model)
            p["block0"] = _init_block(k_first, dense_cfg)
        p["blocks"] = common.stack_init(
            lambda k: _init_block(k, cfg), k_stack, n_scanned)
        if cfg.frontend == "vision":
            p["patch_proj"] = dense_init(k_fe, (cfg.d_model, cfg.d_model),
                                         common.dt(cfg.param_dtype))
        return p

    def _assemble_x(p, batch):
        x = _embed(p, cfg, batch["tokens"])
        if cfg.frontend == "vision" and "patches" in batch:
            cd = common.dt(cfg.compute_dtype)
            pe = jnp.einsum("bsd,dk->bsk", batch["patches"].astype(cd),
                            p["patch_proj"].astype(cd))
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def _run(p, batch, caches, cache_index):
        x = _assemble_x(p, batch)
        positions = batch.get("positions3") if cfg.mrope else None
        if positions is None:
            if cfg.mrope:
                B, S = x.shape[:2]
                pos = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None], (B, S))
                positions = jnp.broadcast_to(pos[None], (3, B, S))
            else:
                B, S = x.shape[:2]
                base = jnp.arange(S, dtype=jnp.int32)[None] + (
                    cache_index if cache_index is not None else 0)
                positions = jnp.broadcast_to(base, (B, S))

        if first_dense:
            cache0 = None if caches is None else \
                jax.tree.map(lambda c: c[0], caches["block0"])
            dense_cfg = cfg.replace(moe=None)
            x, new_c0 = _apply_block(p["block0"], dense_cfg, x, positions,
                                     cache0, cache_index)

        def block_fn(p_l, x_l, cache_l):
            return _apply_block(p_l, cfg, x_l, positions, cache_l,
                                cache_index)

        block_fn = _maybe_remat(block_fn, cfg)
        stack_caches = None if caches is None else caches["blocks"]
        x, new_stack = _scan_stack(block_fn, p["blocks"], x, stack_caches,
                                   unroll=cfg.unroll)

        new_caches = None
        if caches is not None:
            new_caches = {"blocks": new_stack}
            if first_dense:
                new_caches["block0"] = jax.tree.map(
                    lambda c: c[None], new_c0)
        return x, new_caches

    def train_logits(p, batch):
        x, _ = _run(p, batch, None, None)
        return _head(p, cfg, x)

    def init_caches(batch_size: int, max_len: int):
        if cfg.mla is not None:
            proto = attention.init_mla_cache(cfg, batch_size, max_len,
                                             CACHE_DTYPE)
        else:
            proto = attention.init_gqa_cache(cfg, batch_size, max_len,
                                             CACHE_DTYPE)
        caches = {"blocks": jax.tree.map(
            lambda c: jnp.zeros((n_scanned,) + c.shape, c.dtype), proto)}
        if first_dense:
            caches["block0"] = jax.tree.map(
                lambda c: jnp.zeros((1,) + c.shape, c.dtype), proto)
        return caches

    def prefill(p, batch, max_len: int):
        caches = init_caches(batch["tokens"].shape[0], max_len)
        x, new_caches = _run(p, batch, caches, 0)
        logits = _head(p, cfg, x[:, -1:])
        return logits, new_caches

    def decode(p, batch, caches, index):
        x, new_caches = _run(p, batch, caches, index)
        return _head(p, cfg, x), new_caches

    def num_params(p):
        return sum(x.size for x in jax.tree.leaves(p))

    return BuiltModel(cfg=cfg, init=init, train_logits=train_logits,
                      prefill=prefill, decode=decode,
                      init_caches=init_caches, num_params=num_params)


# ---------------------------------------------------------------------------
# xLSTM family (mLSTM groups + periodic sLSTM)
# ---------------------------------------------------------------------------

def _build_xlstm(cfg: ModelConfig) -> BuiltModel:
    period = cfg.ssm.slstm_period
    assert cfg.num_layers % period == 0, "xlstm layers % period"
    groups = cfg.num_layers // period
    m_per_group = period - 1

    def init(key):
        k_eh, k_m, k_s = jax.random.split(key, 3)
        p = _init_embed_head(k_eh, cfg)
        p["mlstm"] = common.stack_init(
            lambda k: common.stack_init(
                lambda kk: {"ln": common.init_rmsnorm(
                    cfg.d_model, common.dt(cfg.param_dtype)),
                    "core": ssm.init_mlstm(kk, cfg)}, k, m_per_group),
            k_m, groups)
        p["slstm"] = common.stack_init(
            lambda k: {"ln": common.init_rmsnorm(
                cfg.d_model, common.dt(cfg.param_dtype)),
                "core": ssm.init_slstm(k, cfg)}, k_s, groups)
        return p

    def _run(p, batch, caches):
        x = _embed(p, cfg, batch["tokens"])

        def mlstm_fn(p_l, x_l, cache_l):
            h = common.rmsnorm(p_l["ln"], x_l, cfg.norm_eps)
            out, new_cache = ssm.mlstm_block(p_l["core"], cfg, h, cache_l)
            return x_l + out, new_cache

        def slstm_fn(p_l, x_l, cache_l):
            h = common.rmsnorm(p_l["ln"], x_l, cfg.norm_eps)
            out, new_cache = ssm.slstm_block(p_l["core"], cfg, h, cache_l)
            return x_l + out, new_cache

        mlstm_fn = _maybe_remat(mlstm_fn, cfg)

        def group_fn(p_g, x_g, cache_g):
            mc = None if cache_g is None else cache_g["mlstm"]
            x_g, new_mc = _scan_stack(mlstm_fn, p_g["m"], x_g, mc,
                                      unroll=cfg.unroll)
            sc = None if cache_g is None else cache_g["slstm"]
            x_g, new_sc = slstm_fn(p_g["s"], x_g, sc)
            new_cache = None if cache_g is None else \
                {"mlstm": new_mc, "slstm": new_sc}
            return x_g, new_cache

        stacked = {"m": p["mlstm"], "s": p["slstm"]}
        x, new_caches = _scan_stack(group_fn, stacked, x, caches,
                                    unroll=cfg.unroll)
        return x, new_caches

    def train_logits(p, batch):
        x, _ = _run(p, batch, None)
        return _head(p, cfg, x)

    def init_caches(batch_size: int, max_len: int):
        mc = ssm.init_mlstm_cache(cfg, batch_size, CACHE_DTYPE)
        sc = ssm.init_slstm_cache(cfg, batch_size)
        return {
            "mlstm": jax.tree.map(
                lambda c: jnp.zeros((groups, m_per_group) + c.shape,
                                    c.dtype), mc),
            "slstm": jax.tree.map(
                lambda c: jnp.zeros((groups,) + c.shape, c.dtype), sc),
        }

    def prefill(p, batch, max_len: int):
        caches = init_caches(batch["tokens"].shape[0], max_len)
        x, new_caches = _run(p, batch, caches)
        return _head(p, cfg, x[:, -1:]), new_caches

    def decode(p, batch, caches, index):
        x, new_caches = _run(p, batch, caches)
        return _head(p, cfg, x), new_caches

    def num_params(p):
        return sum(x.size for x in jax.tree.leaves(p))

    return BuiltModel(cfg=cfg, init=init, train_logits=train_logits,
                      prefill=prefill, decode=decode,
                      init_caches=init_caches, num_params=num_params)


# ---------------------------------------------------------------------------
# Zamba2-style hybrid (mamba2 stacks + one *shared* attention block)
# ---------------------------------------------------------------------------

def _build_hybrid(cfg: ModelConfig) -> BuiltModel:
    period = cfg.ssm.shared_attn_period
    groups = cfg.num_layers // period
    tail = cfg.num_layers - groups * period

    def init(key):
        ks = jax.random.split(key, 5)
        p = _init_embed_head(ks[0], cfg)
        dtype = common.dt(cfg.param_dtype)
        p["mamba"] = common.stack_init(
            lambda k: common.stack_init(
                lambda kk: {"ln": common.init_rmsnorm(cfg.d_model, dtype),
                            "core": ssm.init_mamba2(kk, cfg)}, k, period),
            ks[1], groups)
        if tail:
            p["mamba_tail"] = common.stack_init(
                lambda kk: {"ln": common.init_rmsnorm(cfg.d_model, dtype),
                            "core": ssm.init_mamba2(kk, cfg)}, ks[2], tail)
        # the shared transformer block (weights reused at every period)
        p["shared_attn"] = _init_block(ks[3], cfg.replace(moe=None))
        return p

    def _run(p, batch, caches, cache_index):
        x = _embed(p, cfg, batch["tokens"])
        B, S = batch["tokens"].shape
        base = jnp.arange(S, dtype=jnp.int32)[None] + (
            cache_index if cache_index is not None else 0)
        positions = jnp.broadcast_to(base, (B, S))

        def mamba_fn(p_l, x_l, cache_l):
            h = common.rmsnorm(p_l["ln"], x_l, cfg.norm_eps)
            out, new_cache = ssm.mamba2_block(p_l["core"], cfg, h, cache_l)
            return x_l + out, new_cache

        mamba_fn = _maybe_remat(mamba_fn, cfg)

        def group_fn(p_g, x_g, cache_g):
            mcache = None if cache_g is None else cache_g["mamba"]
            x_g, new_m = _scan_stack(mamba_fn, p_g, x_g, mcache,
                                     unroll=cfg.unroll)
            # shared attention block (same weights every group — closure)
            acache = None if cache_g is None else cache_g["attn"]
            x_g, new_a = _apply_block(p["shared_attn"], cfg, x_g, positions,
                                      acache, cache_index)
            new_cache = None if cache_g is None else \
                {"mamba": new_m, "attn": new_a}
            return x_g, new_cache

        x, new_caches = _scan_stack(group_fn, p["mamba"], x, caches if
                                    caches is None else caches["groups"],
                                    unroll=cfg.unroll)
        new_tail = None
        if tail:
            tcache = None if caches is None else caches["tail"]
            x, new_tail = _scan_stack(mamba_fn, p["mamba_tail"], x, tcache,
                                      unroll=cfg.unroll)
        out_caches = None
        if caches is not None:
            out_caches = {"groups": new_caches}
            if tail:
                out_caches["tail"] = new_tail
        return x, out_caches

    def train_logits(p, batch):
        x, _ = _run(p, batch, None, None)
        return _head(p, cfg, x)

    def init_caches(batch_size: int, max_len: int):
        mc = ssm.init_mamba2_cache(cfg, batch_size, CACHE_DTYPE)
        ac = attention.init_gqa_cache(cfg, batch_size, max_len, CACHE_DTYPE)
        caches = {"groups": {
            "mamba": jax.tree.map(
                lambda c: jnp.zeros((groups, period) + c.shape, c.dtype),
                mc),
            "attn": jax.tree.map(
                lambda c: jnp.zeros((groups,) + c.shape, c.dtype), ac),
        }}
        if tail:
            caches["tail"] = jax.tree.map(
                lambda c: jnp.zeros((tail,) + c.shape, c.dtype), mc)
        return caches

    def prefill(p, batch, max_len: int):
        caches = init_caches(batch["tokens"].shape[0], max_len)
        x, new_caches = _run(p, batch, caches, 0)
        return _head(p, cfg, x[:, -1:]), new_caches

    def decode(p, batch, caches, index):
        x, new_caches = _run(p, batch, caches, index)
        return _head(p, cfg, x), new_caches

    def num_params(p):
        return sum(x.size for x in jax.tree.leaves(p))

    return BuiltModel(cfg=cfg, init=init, train_logits=train_logits,
                      prefill=prefill, decode=decode,
                      init_caches=init_caches, num_params=num_params)


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t text decoder over stub audio encodings)
# ---------------------------------------------------------------------------

def _init_encdec_block(key, cfg: ModelConfig, cross: bool) -> dict:
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"ln1": common.init_rmsnorm(cfg.d_model, dtype),
         "attn": attention.init_gqa(ks[0], cfg),
         "ln2": common.init_rmsnorm(cfg.d_model, dtype),
         "ffn": ffn.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)}
    if cross:
        p["ln_x"] = common.init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = attention.init_gqa(ks[2], cfg)
    return p


def _build_encdec(cfg: ModelConfig) -> BuiltModel:

    def init(key):
        ks = jax.random.split(key, 4)
        p = _init_embed_head(ks[0], cfg)
        dtype = common.dt(cfg.param_dtype)
        p["frame_proj"] = dense_init(ks[3], (cfg.d_model, cfg.d_model),
                                     dtype)
        p["enc"] = common.stack_init(
            lambda k: _init_encdec_block(k, cfg, cross=False), ks[1],
            cfg.encoder_layers)
        p["dec"] = common.stack_init(
            lambda k: _init_encdec_block(k, cfg, cross=True), ks[2],
            cfg.num_layers)
        return p

    def _encode(p, batch):
        cd = common.dt(cfg.compute_dtype)
        x = jnp.einsum("bsd,dk->bsk", batch["frames"].astype(cd),
                       p["frame_proj"].astype(cd))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def enc_fn(p_l, x_l, _):
            h = common.rmsnorm(p_l["ln1"], x_l, cfg.norm_eps)
            a, _ = attention.gqa_attention(p_l["attn"], cfg, h, positions,
                                           causal=False)
            x_l = x_l + a
            h = common.rmsnorm(p_l["ln2"], x_l, cfg.norm_eps)
            return x_l + ffn.mlp(p_l["ffn"], h, cd), None

        enc_fn = _maybe_remat(enc_fn, cfg)
        x, _ = _scan_stack(enc_fn, p["enc"], x, None, unroll=cfg.unroll)
        return x

    def _decode_stack(p, tokens, memory, caches, cache_index):
        cd = common.dt(cfg.compute_dtype)
        x = _embed(p, cfg, tokens)
        B, S = tokens.shape
        base = jnp.arange(S, dtype=jnp.int32)[None] + (
            cache_index if cache_index is not None else 0)
        positions = jnp.broadcast_to(base, (B, S))

        def dec_fn(p_l, x_l, cache_l):
            h = common.rmsnorm(p_l["ln1"], x_l, cfg.norm_eps)
            a, new_self = attention.gqa_attention(
                p_l["attn"], cfg, h, positions, cache_l, cache_index)
            x_l = x_l + a
            h = common.rmsnorm(p_l["ln_x"], x_l, cfg.norm_eps)
            c, _ = attention.gqa_attention(p_l["cross"], cfg, h, positions,
                                           kv_source=memory, causal=False)
            x_l = x_l + c
            h = common.rmsnorm(p_l["ln2"], x_l, cfg.norm_eps)
            return x_l + ffn.mlp(p_l["ffn"], h, cd), new_self

        dec_fn = _maybe_remat(dec_fn, cfg)
        return _scan_stack(dec_fn, p["dec"], x, caches,
                           unroll=cfg.unroll)

    def train_logits(p, batch):
        memory = _encode(p, batch)
        x, _ = _decode_stack(p, batch["tokens"], memory, None, None)
        return _head(p, cfg, x)

    def init_caches(batch_size: int, max_len: int):
        proto = attention.init_gqa_cache(cfg, batch_size, max_len,
                                         CACHE_DTYPE)
        self_caches = jax.tree.map(
            lambda c: jnp.zeros((cfg.num_layers,) + c.shape, c.dtype),
            proto)
        # encoder memory cached at prefill (bf16)
        mem = jnp.zeros((batch_size, max_len, cfg.d_model), CACHE_DTYPE)
        return {"self": self_caches, "memory": mem}

    def prefill(p, batch, max_len: int):
        memory = _encode(p, batch)
        caches = init_caches(batch["tokens"].shape[0], max_len)
        # store encoder memory (pad/crop to max_len frames)
        S_enc = memory.shape[1]
        mem_buf = jax.lax.dynamic_update_slice_in_dim(
            caches["memory"], memory.astype(CACHE_DTYPE)[:, :max_len], 0,
            axis=1)
        x, new_self = _decode_stack(p, batch["tokens"], memory,
                                    caches["self"], 0)
        return (_head(p, cfg, x[:, -1:]),
                {"self": new_self, "memory": mem_buf})

    def decode(p, batch, caches, index):
        memory = caches["memory"].astype(common.dt(cfg.compute_dtype))
        x, new_self = _decode_stack(p, batch["tokens"], memory,
                                    caches["self"], index)
        return _head(p, cfg, x), {"self": new_self,
                                  "memory": caches["memory"]}

    def num_params(p):
        return sum(x.size for x in jax.tree.leaves(p))

    return BuiltModel(cfg=cfg, init=init, train_logits=train_logits,
                      prefill=prefill, decode=decode,
                      init_caches=init_caches, num_params=num_params)


# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> BuiltModel:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_decoder_only(cfg)
    if cfg.family == "ssm":
        return _build_xlstm(cfg)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg)
    if cfg.family in ("encdec", "audio"):
        return _build_encdec(cfg)
    raise ValueError(f"unknown family {cfg.family}")
