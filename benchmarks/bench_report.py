"""Aggregate every ``BENCH_*.json`` into one summary report.

Each benchmark in this suite writes a standalone JSON artifact
(``BENCH_epoch.json``, ``BENCH_prune.json``, …, with a ``_smoke``
suffix under CI). Reading six artifacts to answer "did anything
regress?" does not scale, so this module walks all of them and distills
the cross-cutting signals into one table and one machine-readable
``BENCH_report.json``:

* **ratios** — any numeric leaf whose key names a ratio, speedup, or
  utilization (``fused_over_loose_ratio``, ``coalesced_speedup``,
  ``mxu_utilization_vs_v5e``, …), reported under its JSON path so the
  same metric from different benches stays distinguishable;
* **parity / pass flags** — any boolean leaf whose key indicates a
  correctness gate (``parity_ok``, ``pass``, ``found_flags_match``, …),
  AND-folded into a single ``all_flags_ok`` verdict. Leaves whose key
  contains ``diagnostic`` are informational probes, not gates (e.g.
  the fused tail's strict-equality check, gated on allclose), and are
  skipped.

The walk is schema-tolerant on purpose: benches evolve, and the report
should pick up a new ratio or flag the day it is added rather than
silently dropping it. CI runs this after the smoke benches and uploads
``BENCH_report.json`` with the per-bench artifacts; a false flag fails
the step.

Usage: PYTHONPATH=src python -m benchmarks.bench_report
           [--dir DIR] [--out FILE] [--fail-on-flag]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Tuple

_RATIO_MARKERS = ("ratio", "speedup", "utilization", "occupancy",
                  "hit_rate", "per_drain")
_FLAG_MARKERS = ("parity", "_ok", "pass", "match", "bitwise", "allclose",
                 "feasible", "equal")
# Leaves a bench marks as informational, not a gate (e.g. the tail's
# strict-equality probe, whose gate is the allclose flag): never folded
# into ``all_flags_ok``.
_DIAGNOSTIC_MARKER = "diagnostic"


def _kind(path: str) -> str:
    """BENCH_epoch_smoke.json -> 'epoch' (the bench that wrote it)."""
    stem = os.path.basename(path)[len("BENCH_"):-len(".json")]
    return stem[:-len("_smoke")] if stem.endswith("_smoke") else stem


def _walk(node, prefix: str, ratios: List[Tuple[str, float]],
          flags: List[Tuple[str, bool]]) -> None:
    """Collect ratio-like numbers and correctness booleans recursively."""
    if isinstance(node, dict):
        for k, v in node.items():
            _walk(v, f"{prefix}.{k}" if prefix else str(k), ratios, flags)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk(v, f"{prefix}[{i}]", ratios, flags)
    elif isinstance(node, bool):
        key = prefix.rsplit(".", 1)[-1].lower()
        if _DIAGNOSTIC_MARKER in key:
            return
        if any(mark in key for mark in _FLAG_MARKERS):
            flags.append((prefix, node))
    elif isinstance(node, (int, float)):
        key = prefix.rsplit(".", 1)[-1].lower()
        if any(mark in key for mark in _RATIO_MARKERS):
            ratios.append((prefix, float(node)))


def collect(directory: str = ".") -> Dict[str, dict]:
    """Parse every BENCH_*.json in ``directory`` into summary blocks."""
    report: Dict[str, dict] = {}
    for path in sorted(glob.glob(os.path.join(directory,
                                              "BENCH_*.json"))):
        if os.path.basename(path) == "BENCH_report.json":
            continue
        with open(path) as f:
            try:
                data = json.load(f)
            except json.JSONDecodeError:
                report[_kind(path)] = {"file": path, "error": "unparsable"}
                continue
        ratios: List[Tuple[str, float]] = []
        flags: List[Tuple[str, bool]] = []
        _walk(data, "", ratios, flags)
        report[_kind(path)] = {
            "file": path,
            "smoke": bool(data.get("smoke", False))
            if isinstance(data, dict) else False,
            "ratios": dict(ratios),
            "flags": dict(flags),
            "flags_ok": all(v for _, v in flags),
        }
    return report


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json artifacts")
    ap.add_argument("--out", default="BENCH_report.json")
    ap.add_argument("--fail-on-flag", action="store_true",
                    help="exit nonzero if any correctness flag is false")
    args = ap.parse_args()

    report = collect(args.dir)
    all_ok = all(blk.get("flags_ok", True) for blk in report.values())
    payload = {"benches": report, "all_flags_ok": all_ok}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)

    if not report:
        print(f"no BENCH_*.json artifacts under {args.dir}")
    for kind, blk in sorted(report.items()):
        if "error" in blk:
            print(f"[{kind}] {blk['file']}: {blk['error']}")
            continue
        tag = "smoke" if blk["smoke"] else "full"
        verdict = "OK" if blk["flags_ok"] else "FLAG FAILED"
        print(f"[{kind}] ({tag}) {len(blk['ratios'])} ratios, "
              f"{len(blk['flags'])} flags -> {verdict}")
        for name, val in sorted(blk["ratios"].items()):
            print(f"    {name} = {val:.4g}")
        for name, val in sorted(blk["flags"].items()):
            if not val:
                print(f"    FAILED: {name}")
    print(f"all_flags_ok,{all_ok}")
    print(f"wrote {args.out}")
    if args.fail_on_flag and not all_ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()
