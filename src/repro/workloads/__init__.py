from repro.workloads.layers import LayerKind, LayerSpec, WorkloadGraph
from repro.workloads.zoo import (WORKLOAD_ZOO, get_workload,
                                 workload_complexity_class)
