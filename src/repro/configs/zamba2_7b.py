"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with a *shared*
full-attention block applied every 6 layers. Sub-quadratic -> long_500k."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, kv_heads=32, d_ff=14336, vocab_size=32000,
    rope_theta=10000.0,
    ssm=SSMConfig(kind="mamba2", state_dim=64, expand=2, conv_dim=4,
                  chunk=256, shared_attn_period=6),
    sub_quadratic=True)
