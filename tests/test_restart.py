"""Warm-restart persistence: AOT executable cache, snapshot round trips,
digest validation, restart events in the simulator, and the codec layer."""
import json
import os

import jax
import numpy as np
import pytest

from repro.accel import EDGE
from repro.checkpoint.manager import CheckpointManager
from repro.core import graphs, persist, pso
from repro.core.service import MatcherService
from repro.kernels import backend as kernel_backend
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.metrics import warm_restart_stats
from repro.sched.tasks import make_restart_scenario, make_scenario

jax.config.update("jax_platform_name", "cpu")

CFG = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)


def _planted(seed, n=6, m=12, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _warm_service(tmp, seeds=(1, 2, 3), persist_dir=True):
    """Service that has served (cold) and re-served (warm) a burst, so
    both the batch and revalidate executables exist and every problem
    has a stored carry."""
    svc = MatcherService(CFG, persist_dir=str(tmp) if persist_dir else None)
    probs = [_planted(s) for s in seeds]
    wks = [f"wl/{s}" for s in seeds]
    cold = svc.match_many(probs, workload_keys=wks)
    warm = svc.match_many(probs, workload_keys=wks)
    return svc, probs, wks, cold, warm


# ---------------------------------------------------------------------------
# codec layer
# ---------------------------------------------------------------------------

def test_key_codec_roundtrip():
    keys = [
        ("wl/1", 8, 16, "abcd"),
        (("mobilenetv2", b"\x01\x02\xff"), 8, 16, "ff" * 20),
        ("plain", None, 1.5, True),
        ("digest", (8, 16), b""),
    ]
    for k in keys:
        assert persist.decode_key(persist.encode_key(k)) == k


def test_key_codec_rejects_unencodable():
    with pytest.raises(TypeError):
        persist.encode_key((object(),))


def test_carry_leaves_roundtrip():
    rng = np.random.default_rng(0)
    carries = [(rng.random((4, 8), dtype=np.float32),
                np.float32(i), rng.random((4, 8), dtype=np.float32))
               for i in range(3)]
    leaves = persist.carry_leaves("x", carries)
    back = persist.carries_from_leaves("x", leaves, 3)
    for a, b in zip(carries, back):
        for u, v in zip(a, b):
            assert np.array_equal(np.asarray(u), np.asarray(v))


def test_config_digest_sensitivity():
    d0 = kernel_backend.config_digest(CFG)
    assert d0 == kernel_backend.config_digest(
        pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4))
    assert d0 != kernel_backend.config_digest(CFG.replace(epochs=3))
    assert d0 != kernel_backend.config_digest(CFG.replace(backend="ref")) \
        or kernel_backend.resolve_backend_name(config=CFG) == "ref"
    assert d0 != kernel_backend.config_digest(CFG, extra=("x",))


# ---------------------------------------------------------------------------
# snapshot round trips
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_bitwise_identical(tmp_path):
    svc1, probs, wks, _, warm = _warm_service(tmp_path)
    step = svc1.save_snapshot(extra={"who": "test"})
    assert step == 0 and svc1.stats.snapshot_saves == 1

    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    extra = svc2.restore_snapshot()
    assert extra == {"who": "test"}
    assert svc2.stats.restored_carries == len(probs)
    again = svc2.match_many(probs, workload_keys=wks)
    for a, b in zip(warm, again):
        assert a.found == b.found
        if a.found:
            assert np.array_equal(np.asarray(a.mapping),
                                  np.asarray(b.mapping))
    # every found problem was served without a swarm epoch
    assert all(r.tier <= 1 for r in again if r.found)


def test_snapshot_preserves_lru_recency(tmp_path):
    svc = MatcherService(CFG, persist_dir=str(tmp_path), warm_capacity=8)
    seeds = (1, 2, 3, 4)
    for s in seeds:
        q, g = _planted(s)
        svc.match(q, g, workload_key=f"wl/{s}")
    exact_before, _ = svc._carries.export_state()
    svc.save_snapshot()

    svc2 = MatcherService(CFG, persist_dir=str(tmp_path), warm_capacity=8)
    assert svc2.restore_snapshot() == {}
    exact_after, _ = svc2._carries.export_state()
    assert [k for k, _ in exact_before] == [k for k, _ in exact_after]


def test_stale_digest_snapshot_rejected_cleanly(tmp_path):
    svc1, *_ = _warm_service(tmp_path)
    svc1.save_snapshot()
    drifted = MatcherService(CFG.replace(epochs=3),
                             persist_dir=str(tmp_path))
    assert drifted.restore_snapshot() is None
    assert drifted.stats.snapshot_stale_skipped == 1
    assert drifted.stats.restored_carries == 0
    assert len(drifted._carries) == 0


def test_future_format_version_rejected(tmp_path):
    svc1, *_ = _warm_service(tmp_path)
    svc1.save_snapshot()
    # doctor the committed extras to a future format version
    ckpt_dir = os.path.join(str(tmp_path), "snapshots", "step_000000000")
    with open(os.path.join(ckpt_dir, "extras.json")) as f:
        extras = json.load(f)
    extras["format_version"] = persist.SNAPSHOT_VERSION + 1
    with open(os.path.join(ckpt_dir, "extras.json"), "w") as f:
        json.dump(extras, f)
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    assert svc2.restore_snapshot() is None
    assert svc2.stats.snapshot_stale_skipped == 1


def test_empty_store_snapshot_roundtrip(tmp_path):
    svc = MatcherService(CFG, persist_dir=str(tmp_path))
    svc.save_snapshot(extra={"empty": True})
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    assert svc2.restore_snapshot() == {"empty": True}
    assert svc2.stats.restored_carries == 0


def test_restore_with_no_snapshot_is_none(tmp_path):
    svc = MatcherService(CFG, persist_dir=str(tmp_path))
    assert svc.restore_snapshot() is None
    assert svc.stats.snapshot_stale_skipped == 0


def test_snapshot_requires_persist_dir():
    svc = MatcherService(CFG)
    with pytest.raises(RuntimeError):
        svc.save_snapshot()
    with pytest.raises(RuntimeError):
        svc.restore_snapshot()


def test_persist_dir_false_overrides_env(tmp_path, monkeypatch):
    """persist_dir=False must force persistence OFF even under
    REPRO_PERSIST_DIR — cold-restart baselines depend on it."""
    monkeypatch.setenv(persist.ENV_PERSIST_DIR, str(tmp_path))
    off = MatcherService(CFG, persist_dir=False)
    assert off.persist_dir is None and off._aot is None
    via_env = MatcherService(CFG)
    assert via_env.persist_dir == str(tmp_path)


def test_scheduler_workload_keys_with_bytes_sig_snapshot(tmp_path):
    """The scheduler keys warm entries by (name, engine-signature bytes);
    those keys must survive the JSON codec."""
    svc = MatcherService(CFG, persist_dir=str(tmp_path))
    q, g = _planted(5)
    sig = b"\xf0\x0d"
    svc.match(q, g, workload_key=("wl", sig), engine_sig=sig)
    svc.save_snapshot()
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    svc2.restore_snapshot()
    assert svc2.stats.restored_carries == 1
    r = svc2.match(q, g, workload_key=("wl", sig), engine_sig=sig)
    assert r.warm_hit


# ---------------------------------------------------------------------------
# AOT executable cache
# ---------------------------------------------------------------------------

def test_aot_cache_restarted_service_runs_zero_traces(tmp_path):
    svc1, probs, wks, _, warm = _warm_service(tmp_path)
    assert svc1.stats.jit_traces > 0
    assert svc1.stats.aot_exports > 0
    svc1.save_snapshot()

    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    svc2.restore_snapshot()
    served = [r for r in svc2.match_many(probs, workload_keys=wks)
              if r.found]
    assert served, "warm burst should serve at least one problem"
    assert all(r.tier <= 1 for r in served)
    if all(r.found for r in warm):
        # fully revalidatable burst: the whole drain is AOT-served
        assert svc2.stats.jit_traces == 0
    assert svc2.stats.aot_cache_hits >= 1


def test_aot_single_match_path_zero_traces(tmp_path):
    q, g = _planted(7)
    svc1 = MatcherService(CFG, persist_dir=str(tmp_path))
    r1 = svc1.match(q, g, workload_key="wl/7")
    svc1.save_snapshot()
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    svc2.restore_snapshot()
    r2 = svc2.match(q, g, workload_key="wl/7")
    assert svc2.stats.jit_traces == 0
    assert svc2.stats.aot_cache_hits == 1
    assert r2.warm_hit and r1.found == r2.found


def test_aot_disabled_still_works(tmp_path):
    svc1, probs, wks, *_ = _warm_service(tmp_path)
    svc1.save_snapshot()
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path), aot_cache=False)
    svc2.restore_snapshot()
    res = svc2.match_many(probs, workload_keys=wks)
    assert svc2.stats.aot_cache_hits == 0
    assert svc2.stats.jit_traces > 0          # live traces instead
    assert [r.found for r in res]


def test_aot_corrupt_blob_degrades_to_live_trace(tmp_path):
    svc1, probs, wks, *_ = _warm_service(tmp_path)
    aot_dir = os.path.join(str(tmp_path), "aot")
    blobs = sorted(os.listdir(aot_dir))
    assert blobs
    for name in blobs:
        with open(os.path.join(aot_dir, name), "wb") as f:
            f.write(b"not a serialized module")
    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    res = svc2.match_many(probs, workload_keys=wks)
    assert len(res) == len(probs)             # served despite corruption
    assert svc2.stats.jit_traces > 0


def test_aot_key_includes_config_digest(tmp_path):
    svc1, *_ = _warm_service(tmp_path)
    svc2 = MatcherService(CFG.replace(inner_steps=5),
                          persist_dir=str(tmp_path))
    q, g = _planted(1)
    svc2.match(q, g)
    # drifted config never loads the old blobs
    assert svc2.stats.aot_cache_hits == 0
    assert svc1.config_digest != svc2.config_digest


# ---------------------------------------------------------------------------
# checkpoint manager flat restore
# ---------------------------------------------------------------------------

def test_restore_flat_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    arrays = {"a.0.S": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.int32(7)}
    mgr.save(3, arrays, extras={"meta": 1})
    back, extras = mgr.restore_flat()
    assert extras == {"meta": 1}
    assert set(back) == set(arrays)
    assert np.array_equal(back["a.0.S"], arrays["a.0.S"])
    assert back["b"] == 7


def test_restore_flat_empty_store(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    arrays, extras = mgr.restore_flat()
    assert arrays is None and extras is None


def test_restore_flat_rejects_nested(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(0, {"outer": {"inner": np.zeros(2)}})
    with pytest.raises(ValueError):
        mgr.restore_flat()


# ---------------------------------------------------------------------------
# simulator restart events
# ---------------------------------------------------------------------------

def test_restart_scenario_shape():
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    assert sc.restarts and sc.restarts[0] > 0.2
    base = make_scenario("simple", rate_hz=30, horizon=0.2,
                         burst_size=4, burst_frac=0.6, seed=3)
    assert len(sc.tasks) == 2 * len(base.tasks)
    names = [t.name for t in sc.tasks]
    assert names[:len(base.tasks)] == names[len(base.tasks):]


def test_sim_restart_cold_clears_predictor_state():
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    r = Simulator(SimConfig(platform=EDGE),
                  get_scheduler("immsched")).run(sc)
    st = warm_restart_stats(r)
    assert st["restart_count"] == 1
    assert st["restart_snapshots_saved"] == 0
    assert st["snapshot_restores"] == 0
    assert r.finished == r.total


def test_sim_restart_warm_restores_predictor_state(tmp_path):
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    cfg = SimConfig(platform=EDGE, persist_dir=str(tmp_path))
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    st = warm_restart_stats(r)
    assert st["restart_count"] == 1
    assert st["restart_snapshots_saved"] == 1
    assert st["snapshot_restores"] == 1
    assert st["restart_restored_state_sigs"] > 0
    assert r.finished == r.total


def test_sim_boot_restore_counted_separately_from_restart(tmp_path):
    """A second run over the same persist dir warm-boots from the first
    run's snapshot: that restore shows up as ``restart_boot_restores``,
    NOT as a ``restart_restored_*`` count (there was no in-run
    restart-event restore yet when the run began)."""
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    cfg = SimConfig(platform=EDGE, persist_dir=str(tmp_path))
    r1 = Simulator(cfg, get_scheduler("immsched")).run(sc)
    assert warm_restart_stats(r1)["restart_boot_restores"] == 0
    r2 = Simulator(cfg, get_scheduler("immsched")).run(sc)
    st2 = warm_restart_stats(r2)
    assert st2["restart_boot_restores"] == 1
    # in-run restart restores are still attributed normally
    assert st2["restart_count"] == 1
    assert st2["restart_restored_state_sigs"] > 0


def test_sim_restart_isosched_flushes_memo():
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    r = Simulator(SimConfig(platform=EDGE),
                  get_scheduler("isosched")).run(sc)
    assert r.matcher_stats["restart_count"] == 1
    assert r.finished == r.total


@pytest.mark.slow
def test_sim_restart_real_mode_warm(tmp_path):
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=3)
    cfg = SimConfig(platform=EDGE, matcher_mode="real", pso_cfg=CFG,
                    window_stages=2, persist_dir=str(tmp_path))
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    st = warm_restart_stats(r)
    assert st["restart_count"] == 1
    assert st["snapshot_restores"] == 1
    assert st["restart_restored_carries"] >= 0  # real launches may or may
    assert r.finished == r.total                # not store carries here
