"""Int8 error-feedback gradient compression for data-parallel reduction.

Scheme (1-bit-SGD lineage, adapted to int8 + psum):
  * carry a per-parameter error buffer e;
  * quantize (g + e) to int8 with a per-tensor scale chosen so that the
    *sum over D replicas* cannot overflow int8 (scale = max|x|·D/127 — the
    psum wire dtype stays int8, giving 4× fewer bytes on the DP axis than
    f32 and 2× fewer than bf16);
  * new error e' = (g + e) − dequant(quant(g + e)).

Error feedback makes the quantization noise telescoping: what is lost this
step is re-injected next step, which is why aggressive D-scaled int8
still converges. Used by the explicit-DP train-step variant
(``runtime.train_loop.make_train_step(..., grad_compression=True)``) via
``shard_map``; §Perf measures the collective-byte reduction on the wire.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: jax.Array


def init_compression(params):
    return jax.tree.map(
        lambda p: CompressionState(jnp.zeros(p.shape, jnp.float32)), params,
        is_leaf=lambda x: hasattr(x, "shape"))


def compressed_psum(g: jax.Array, err: jax.Array, axis_name,
                    num_devices: int):
    """One tensor: error-feedback int8 psum over ``axis_name``.
    Returns (mean-reduced g, new error). Must run inside shard_map.

    All replicas must quantize with the SAME scale (otherwise dequantizing
    the int8 sum with an averaged scale injects O(q·Δscale) error), so the
    scale is agreed via a scalar pmax first — negligible wire cost."""
    x = g.astype(jnp.float32) + err
    local_amax = jnp.max(jnp.abs(x))
    amax = jax.lax.pmax(local_amax, axis_name)          # shared scale
    scale = jnp.maximum(amax * num_devices / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    # int8 on the wire; values are D-scaled so the sum fits int8
    summed = jax.lax.psum(q, axis_name)
    mean = summed.astype(jnp.float32) * scale / num_devices
    return mean.astype(g.dtype), new_err


def compressed_psum_tree(grads, comp_state, axis_name, num_devices):
    """Apply compressed_psum leaf-wise; returns (grads, new comp state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = [l.error for l in jax.tree.leaves(
        comp_state, is_leaf=lambda x: isinstance(x, CompressionState))]
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gg, ee = compressed_psum(g, e, axis_name, num_devices)
        out_g.append(gg)
        out_e.append(CompressionState(ee))
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))
