"""Event-driven multi-DNN accelerator simulator.

Models the engine array executing a timed stream of DNN tasks under a
pluggable scheduler. Work accounting per task uses two buckets derived from
the cost model for the scheduler's paradigm (TSS/LTS):

  * a *parallel* bucket in engine-seconds (compute; drains at a rate equal
    to the number of allocated engines, capped by the task's parallelism),
  * a *serial* bucket in seconds (DRAM round-trips for LTS, residual NoC
    serialization for TSS; drains at rate 1 while the task holds engines).

Scheduling itself has latency and energy (the paper's subject): a decision
made at time t with scheduling latency L delays the task's start to t+L
(an *activation* event); at activation the scheduler dispatches without
further cost. Serial CPU schedulers additionally contend for the single
host CPU via their own ``cpu_free_at`` bookkeeping.

Arrival events are *coalesced*: every task arriving at the same instant
(compound-Poisson bursts) is delivered to the scheduler in ONE
``on_event(trigger="arrival", arrived=[...])`` call, so batching-aware
schedulers (IMMSched's coalesced matcher launches) can make one decision
for the whole burst and pay its latency once. Latency within a burst is
*per-tier*: the scheduler may charge different members of one event
different delays (IMMSched charges revalidated Tier-0/1 decisions the
cheap projection cost and only the hard residue a swarm launch), which
``_apply`` honours per task via the decision's ``delay`` map.

Energy: execution energy is charged pro-rata with drained work (preemption
context-motion costs are folded into the task's buckets and energy);
idle-engine leakage and scheduling energy are integrated on top.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.accel.energy import CostModel
from repro.accel.platform import Platform
from repro.core.pso import PSOConfig
from repro.sched.tasks import Scenario, TaskSpec

_EPS = 1e-12


@dataclasses.dataclass
class SimConfig:
    platform: Platform
    matcher_mode: str = "analytic"     # "analytic" | "real"
    pso_cfg: PSOConfig = dataclasses.field(
        default_factory=lambda: PSOConfig(num_particles=32, epochs=2,
                                          inner_steps=8))
    window_stages: int = 4
    seed: int = 0
    # Warm-restart persistence root for schedulers that keep host state
    # (IMMSched's matcher service + tier predictor). None = a scenario
    # restart event is a COLD restart (all host state lost); a directory
    # enables snapshot-before-kill + restore-after (and the service's
    # on-disk AOT executable cache) — the warm-restart arm.
    persist_dir: Optional[str] = None


@dataclasses.dataclass
class TaskState:
    spec: TaskSpec
    par_es: float                  # engine-seconds remaining
    ser_s: float                   # serial seconds remaining
    par_cap: int
    energy_total: float            # execution energy (grows w/ preemptions)
    work_total: float              # par_es + ser_s incl. added costs
    engines: List[int] = dataclasses.field(default_factory=list)
    status: str = "pending"        # pending|ready|running|done
    ready_at: float = 0.0
    finish: float = -1.0
    sched_time: float = 0.0        # accumulated scheduling latency it saw
    live_bytes: float = 0.0

    @property
    def done(self) -> bool:
        return self.status == "done"

    def remaining_time(self, engines: int) -> float:
        if engines <= 0:
            return float("inf")
        rate = min(engines, self.par_cap)
        return self.par_es / rate + self.ser_s

    def add_cost(self, dt: float, de: float) -> None:
        self.ser_s += dt
        self.work_total += dt
        self.energy_total += de


@dataclasses.dataclass
class SimResult:
    scheduler: str
    platform: str
    finished: int
    total: int
    deadline_met: int
    urgent_total: int
    urgent_met: int
    avg_total_latency: float       # mean (finish - arrival) over finished
    avg_sched_time: float
    total_energy: float            # J (exec + sched + idle)
    sched_energy: float
    exec_energy: float
    idle_energy: float
    sim_horizon: float
    # online matcher-service counters (compile-cache / warm-start hits,
    # epochs saved by early exit); empty for schedulers without a service
    matcher_stats: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def urgent_hit_rate(self) -> float:
        return self.urgent_met / max(self.urgent_total, 1)

    @property
    def all_hit_rate(self) -> float:
        return self.deadline_met / max(self.total, 1)

    @property
    def tasks_per_joule(self) -> float:
        return self.finished / max(self.total_energy, 1e-12)

    @property
    def met_per_joule(self) -> float:
        """Deadline-meeting throughput per joule — the paper's energy
        efficiency: queries that *count* (served within their latency
        bound) per unit energy. A floor of 1/4 task avoids div-by-zero
        for baselines that miss every deadline at saturating load."""
        return max(self.deadline_met, 0.25) / max(self.total_energy, 1e-12)

    @property
    def work_energy_per_task(self) -> float:
        """Exec + scheduling energy per finished task (paper's energy
        metric: the per-query cost, excluding array idle leakage)."""
        return (self.exec_energy + self.sched_energy) / max(self.finished, 1)


class Simulator:
    def __init__(self, cfg: SimConfig, scheduler):
        self.cfg = cfg
        self.platform = cfg.platform
        self.scheduler = scheduler
        self.cost = CostModel(cfg.platform)

    # ------------------------------------------------------------------
    def run(self, scenario: Scenario) -> SimResult:
        sched = self.scheduler
        sched.reset(self)
        tasks = [self._admit(spec) for spec in scenario.tasks]
        arrivals = [(t.spec.arrival, i) for i, t in enumerate(tasks)]
        heapq.heapify(arrivals)
        restarts = deque(getattr(scenario, "restarts", ()))
        now = 0.0
        busy_integral = 0.0
        sched_energy = 0.0
        exec_energy = 0.0
        horizon = scenario.horizon * 4 + 1.0

        def running():
            return [t for t in tasks if t.status == "running"]

        def next_completion():
            best, who = float("inf"), None
            for t in running():
                rt = t.remaining_time(len(t.engines))
                if now + rt < best:
                    best, who = now + rt, t
            return best, who

        def next_activation():
            best = float("inf")
            for t in tasks:
                if t.status == "ready" and t.ready_at > now + _EPS:
                    best = min(best, t.ready_at)
            return best

        for _ in range(500_000):
            t_arr = arrivals[0][0] if arrivals else float("inf")
            t_done, done_task = next_completion()
            t_act = next_activation()
            t_res = restarts[0] if restarts else float("inf")
            t_next = min(t_arr, t_done, t_act, t_res)
            if t_next == float("inf") or t_next > horizon:
                break
            # ---- advance time, drain work, integrate energy ----
            dt = t_next - now
            if dt > 0:
                for t in running():
                    rate = min(len(t.engines), t.par_cap)
                    drain_par = min(t.par_es, rate * dt)
                    t.par_es -= drain_par
                    left = dt - drain_par / max(rate, 1)
                    drain_ser = min(t.ser_s, max(left, 0.0))
                    t.ser_s -= drain_ser
                    exec_energy += t.energy_total * (
                        drain_par + drain_ser) / max(t.work_total, _EPS)
                    busy_integral += len(t.engines) * dt
                now = t_next

            if t_res <= min(t_arr, t_done, t_act):
                # scheduler-process kill/restart: host state dies (or is
                # snapshot-restored under cfg.persist_dir); tasks running
                # on the accelerator are unaffected. Restarts outrank
                # same-instant arrivals so those arrivals hit the
                # restarted (worst-case cold) scheduler.
                restarts.popleft()
                sched.on_restart(self, now)
                continue
            if t_done <= min(t_arr, t_act) and done_task is not None:
                done_task.par_es = max(done_task.par_es, 0.0)
                done_task.ser_s = max(done_task.ser_s, 0.0)
                done_task.status = "done"
                done_task.finish = now
                done_task.engines = []
                dec = sched.on_event(self, now, tasks, trigger="completion")
            elif t_arr <= min(t_done, t_act):
                # one event delivers ALL tasks that became schedulable at
                # this instant (burst arrivals coalesce into one decision)
                arrived = []
                while arrivals and arrivals[0][0] <= now + _EPS:
                    _, idx = heapq.heappop(arrivals)
                    t = tasks[idx]
                    t.status = "ready"
                    t.ready_at = now
                    arrived.append(t)
                dec = sched.on_event(self, now, tasks, trigger="arrival",
                                     arrived=arrived)
            else:
                dec = sched.on_event(self, now, tasks, trigger="activate")
            sched_energy += self._apply(dec, tasks, now)

        finished = [t for t in tasks if t.done]
        met = [t for t in finished if t.finish <= t.spec.deadline]
        urgent = [t for t in tasks if t.spec.urgent]
        urgent_met = [t for t in urgent
                      if t.done and t.finish <= t.spec.deadline]
        idle_energy = (self.platform.engines * now - busy_integral) \
            * self.cost.engine_idle_watts
        total_energy = exec_energy + sched_energy + max(idle_energy, 0.0)
        lat = [t.finish - t.spec.arrival for t in finished]
        st = [t.sched_time for t in finished]
        return SimResult(
            scheduler=sched.name, platform=self.platform.name,
            finished=len(finished), total=len(tasks),
            deadline_met=len(met), urgent_total=len(urgent),
            urgent_met=len(urgent_met),
            avg_total_latency=float(np.mean(lat)) if lat else float("inf"),
            avg_sched_time=float(np.mean(st)) if st else 0.0,
            total_energy=total_energy, sched_energy=sched_energy,
            exec_energy=exec_energy, idle_energy=max(idle_energy, 0.0),
            sim_horizon=now,
            matcher_stats=sched.matcher_stats())

    # ------------------------------------------------------------------
    def _admit(self, spec: TaskSpec) -> TaskState:
        wl = spec.workload
        paradigm = self.scheduler.paradigm
        p = self.platform
        per_engine = p.macs_per_engine * p.clock_hz * self.cost.engine_util_dnn
        par_es = wl.total_macs / per_engine
        if paradigm == "tss":
            _, e = self.cost.exec_tss(wl, max(p.engines // 2, 1))
            ser = wl.total_bytes * self.cost.avg_hops / (
                p.noc_link_bw_bytes * max(p.engines // 2, 1))
        else:
            overlap = getattr(self.scheduler, "overlap", 0.0)
            _, e = self.cost.exec_lts(wl, p.engines, overlap)
            ser = 2.0 * wl.total_bytes / p.dram_bw_bytes * (1.0 - overlap)
        depth = max(len(wl.layers) // 8, 1)
        par_cap = int(np.clip(len(wl.layers) / depth * 4, 1, p.engines))
        live = np.mean([l.bytes_moved for l in wl.layers]) * 4
        return TaskState(spec=spec, par_es=par_es, ser_s=ser,
                         par_cap=par_cap, energy_total=e,
                         work_total=par_es + ser, live_bytes=float(live))

    def _apply(self, decision, tasks, now) -> float:
        if decision is None:
            return 0.0
        for tid in decision.get("preempt", []):
            t = tasks[tid]
            if t.status == "running":
                t.status = "ready"
                t.engines = []
                dt, de = (self.cost.preemption_cost_tss(t.live_bytes)
                          if self.scheduler.paradigm == "tss" else
                          self.cost.preemption_cost_lts(t.live_bytes))
                t.add_cost(dt, de)
        # delays first: a delayed task cannot start in the same decision
        for tid, delay in decision.get("delay", {}).items():
            t = tasks[tid]
            if delay > 0:
                t.ready_at = max(t.ready_at, now + delay)
                t.sched_time += delay
        claimed: set = set()
        for tid, engines in decision.get("alloc", {}).items():
            t = tasks[tid]
            engines = [e for e in engines if e not in claimed]
            if t.status == "ready" and engines and now >= t.ready_at - _EPS:
                t.status = "running"
                t.engines = list(engines)
                claimed.update(engines)
        return decision.get("energy", 0.0)
