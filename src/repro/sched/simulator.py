"""Event-driven multi-DNN accelerator simulator.

Models the engine array executing a timed stream of DNN tasks under a
pluggable scheduler. Work accounting per task uses two buckets derived from
the cost model for the scheduler's paradigm (TSS/LTS):

  * a *parallel* bucket in engine-seconds (compute; drains at a rate equal
    to the number of allocated engines, capped by the task's parallelism),
  * a *serial* bucket in seconds (DRAM round-trips for LTS, residual NoC
    serialization for TSS; drains at rate 1 while the task holds engines).

Scheduling itself has latency and energy (the paper's subject): a decision
made at time t with scheduling latency L delays the task's start to t+L
(an *activation* event); at activation the scheduler dispatches without
further cost. Serial CPU schedulers additionally contend for the single
host CPU via their own ``cpu_free_at`` bookkeeping.

Arrival events are *coalesced*: every task arriving at the same instant
(compound-Poisson bursts) is delivered to the scheduler in ONE
``on_event(trigger="arrival", arrived=[...])`` call, so batching-aware
schedulers (IMMSched's coalesced matcher launches) can make one decision
for the whole burst and pay its latency once. Latency within a burst is
*per-tier*: the scheduler may charge different members of one event
different delays (IMMSched charges revalidated Tier-0/1 decisions the
cheap projection cost and only the hard residue a swarm launch), which
``_apply`` honours per task via the decision's ``delay`` map.

Energy: execution energy is charged pro-rata with drained work (preemption
context-motion costs are folded into the task's buckets and energy);
idle-engine leakage and scheduling energy are integrated on top.

Streaming event loop
--------------------
``Simulator.run`` consumes ``scenario.arrivals_iter()`` with one-spec
lookahead, so a :class:`~repro.sched.tasks.StreamScenario` replays
millions of arrivals while the simulator only ever holds the *live*
tasks (ready + running) in a :class:`TaskTable`. Event sources and their
per-event cost:

  * **arrival** — the buffered head of the arrival stream (the generator
    is the sorted queue);
  * **activation** — a lazy-deletion min-heap fed by ``_apply`` whenever
    a decision delays a task (stale entries — task finished, re-delayed,
    or already past — are discarded at peek time);
  * **completion** — recomputed each event over the running set, which
    the global-occupancy invariant bounds by the engine count. A heap of
    stored completion *timestamps* would be wrong twice over: every
    elapsed ``dt`` drains work from every running task (invalidating all
    entries anyway), and a stored ``t_alloc + remaining`` differs
    *bitwise* from the legacy loop's per-event
    ``now + remaining_time(...)`` recomputation under float rounding;
  * **restart** — a deque of scenario kill/restart instants.

This replaces the legacy loop's per-iteration O(n)-in-all-tasks
``next_completion`` / ``next_activation`` scans with per-event work
bounded by the engine count, independent of scenario length. The legacy
full-scan loop is retained as :meth:`Simulator.run_legacy` (list
scenarios only) purely as an equivalence oracle — `tests/test_scale.py`
asserts both loops produce bitwise-identical ``SimResult``\\ s.
"""
from __future__ import annotations

import dataclasses
import heapq
from array import array
from collections import deque
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.accel.energy import CostModel
from repro.accel.platform import Platform
from repro.core.pso import PSOConfig
from repro.sched.tasks import Scenario, TaskSpec

_EPS = 1e-12


@dataclasses.dataclass
class SimConfig:
    platform: Platform
    matcher_mode: str = "analytic"     # "analytic" | "real"
    pso_cfg: PSOConfig = dataclasses.field(
        default_factory=lambda: PSOConfig(num_particles=32, epochs=2,
                                          inner_steps=8))
    window_stages: int = 4
    seed: int = 0
    # Warm-restart persistence root for schedulers that keep host state
    # (IMMSched's matcher service + tier predictor). None = a scenario
    # restart event is a COLD restart (all host state lost); a directory
    # enables snapshot-before-kill + restore-after (and the service's
    # on-disk AOT executable cache) — the warm-restart arm.
    persist_dir: Optional[str] = None
    # Event budget: a run that still has events pending when the budget
    # is exhausted stops and sets ``SimResult.truncated`` instead of
    # silently reading as complete. None = unbounded.
    max_events: Optional[int] = 500_000
    # Pay for per-event invariant checks (engine occupancy disjoint,
    # finish >= arrival, busy_integral <= engines * now) — property
    # tests run with this on; benchmarks leave it off.
    validate: bool = False


@dataclasses.dataclass
class TaskState:
    spec: TaskSpec
    par_es: float                  # engine-seconds remaining
    ser_s: float                   # serial seconds remaining
    par_cap: int
    energy_total: float            # execution energy (grows w/ preemptions)
    work_total: float              # par_es + ser_s incl. added costs
    engines: List[int] = dataclasses.field(default_factory=list)
    status: str = "pending"        # pending|ready|running|done
    ready_at: float = 0.0
    finish: float = -1.0
    sched_time: float = 0.0        # accumulated scheduling latency it saw
    live_bytes: float = 0.0

    @property
    def done(self) -> bool:
        return self.status == "done"

    def remaining_time(self, engines: int) -> float:
        if engines <= 0:
            return float("inf")
        rate = min(engines, self.par_cap)
        return self.par_es / rate + self.ser_s

    def add_cost(self, dt: float, de: float) -> None:
        self.ser_s += dt
        self.work_total += dt
        self.energy_total += de


class TaskTable:
    """Live-task view handed to schedulers by the streaming loop.

    Holds only arrived-and-unfinished tasks, keyed by ``task_id``, in
    insertion (= arrival = id) order — so scheduler-side iteration and
    ``tasks[tid]`` indexing behave exactly like the legacy full task
    list, minus the pending/done entries schedulers have no business
    reading. Finished tasks are removed right after their completion
    event, which is what keeps memory bounded by the number of live
    tasks rather than the scenario length.
    """

    def __init__(self):
        self._by_id: Dict[int, TaskState] = {}

    def add(self, t: TaskState) -> None:
        self._by_id[t.spec.task_id] = t

    def pop(self, tid: int) -> TaskState:
        return self._by_id.pop(tid)

    def get(self, tid: int) -> Optional[TaskState]:
        return self._by_id.get(tid)

    def __getitem__(self, tid: int) -> TaskState:
        return self._by_id[tid]

    def __contains__(self, tid: int) -> bool:
        return tid in self._by_id

    def __iter__(self) -> Iterator[TaskState]:
        return iter(self._by_id.values())

    def __len__(self) -> int:
        return len(self._by_id)


@dataclasses.dataclass
class SimResult:
    scheduler: str
    platform: str
    finished: int
    total: int
    deadline_met: int
    urgent_total: int
    urgent_met: int
    avg_total_latency: float       # mean (finish - arrival) over finished
    avg_sched_time: float
    total_energy: float            # J (exec + sched + idle)
    sched_energy: float
    exec_energy: float
    idle_energy: float
    sim_horizon: float
    # online matcher-service counters (compile-cache / warm-start hits,
    # epochs saved by early exit); empty for schedulers without a service
    matcher_stats: Dict[str, float] = dataclasses.field(default_factory=dict)
    # True when the run stopped on SimConfig.max_events with events still
    # pending — numbers below are then a PREFIX of the scenario, not a
    # completed run. Benchmarks must refuse to report truncated results.
    truncated: bool = False
    events: int = 0                # simulator events processed
    # engines the simulator refused to hand out because a running task
    # already held them (scheduler decision bug; see Simulator._apply)
    alloc_conflicts: int = 0
    busy_integral: float = 0.0     # engine-seconds of occupied engines
    peak_live_tasks: int = 0       # max simultaneously live (ready+running)
    # latency_p50/p99/p999 + sched_p50/p99/p999 over finished tasks
    # (seconds); empty when nothing finished
    percentiles: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def urgent_hit_rate(self) -> float:
        return self.urgent_met / max(self.urgent_total, 1)

    @property
    def all_hit_rate(self) -> float:
        return self.deadline_met / max(self.total, 1)

    @property
    def tasks_per_joule(self) -> float:
        return self.finished / max(self.total_energy, 1e-12)

    @property
    def met_per_joule(self) -> float:
        """Deadline-meeting throughput per joule — the paper's energy
        efficiency: queries that *count* (served within their latency
        bound) per unit energy. A floor of 1/4 task avoids div-by-zero
        for baselines that miss every deadline at saturating load."""
        return max(self.deadline_met, 0.25) / max(self.total_energy, 1e-12)

    @property
    def work_energy_per_task(self) -> float:
        """Exec + scheduling energy per finished task (paper's energy
        metric: the per-query cost, excluding array idle leakage)."""
        return (self.exec_energy + self.sched_energy) / max(self.finished, 1)


def _finish_percentiles(lat: np.ndarray, st: np.ndarray) -> Dict[str, float]:
    """p50/p99/p999 of total latency and scheduling time (seconds)."""
    if lat.size == 0:
        return {}
    out: Dict[str, float] = {}
    for name, arr in (("latency", lat), ("sched", st)):
        for q, tag in ((50.0, "p50"), (99.0, "p99"), (99.9, "p999")):
            out[f"{name}_{tag}"] = float(np.percentile(arr, q))
    return out


class Simulator:
    def __init__(self, cfg: SimConfig, scheduler):
        self.cfg = cfg
        self.platform = cfg.platform
        self.scheduler = scheduler
        self.cost = CostModel(cfg.platform)
        self._alloc_conflicts = 0

    # ------------------------------------------------------------------
    def run(self, scenario) -> SimResult:
        """Streaming heap-scheduled event loop.

        Accepts any scenario exposing ``arrivals_iter()`` / ``horizon``
        (list-based :class:`Scenario` and generator-backed
        :class:`StreamScenario` alike); per-event cost is bounded by the
        engine count, memory by the live-task count. Bitwise-equivalent
        to :meth:`run_legacy` on list scenarios.
        """
        sched = self.scheduler
        sched.reset(self)
        self._alloc_conflicts = 0
        stream = scenario.arrivals_iter()
        next_spec: Optional[TaskSpec] = next(stream, None)
        table = TaskTable()
        running_ids: set = set()
        act_heap: List[Tuple[float, int]] = []
        restarts = deque(getattr(scenario, "restarts", ()))
        now = 0.0
        busy_integral = 0.0
        sched_energy = 0.0
        exec_energy = 0.0
        horizon = scenario.horizon * 4 + 1.0
        max_events = self.cfg.max_events
        validate = self.cfg.validate
        admitted = 0
        urgent_total = 0
        n_finished = 0
        deadline_met = 0
        urgent_met = 0
        peak_live = 0
        events = 0
        truncated = False
        # compact per-finished-task stats (8 bytes/entry, not a TaskState)
        fin_ids = array("q")
        fin_lat = array("d")
        fin_st = array("d")

        while True:
            t_arr = next_spec.arrival if next_spec is not None \
                else float("inf")
            # completion: recompute over the engine-bounded running set in
            # id order — strict < keeps the earliest id on ties, exactly
            # like the legacy full scan (and unlike a stored-timestamp
            # heap, recomputation matches its float rounding bitwise)
            t_done, done_task = float("inf"), None
            for tid in sorted(running_ids):
                t = table[tid]
                rt = t.remaining_time(len(t.engines))
                if now + rt < t_done:
                    t_done, done_task = now + rt, t
            # activation: lazy-deletion heap; entries are (ready_at, tid)
            # pushed by _apply at delay time. Stale when the task is gone
            # or no longer ready, was re-delayed past this entry, or the
            # instant is not in the future (<= now+eps never activates —
            # such tasks dispatch on the next ordinary event instead,
            # matching the legacy scan's `ready_at > now + eps` filter).
            t_act = float("inf")
            while act_heap:
                when, tid = act_heap[0]
                t = table.get(tid)
                if (t is None or t.status != "ready"
                        or when != t.ready_at or when <= now + _EPS):
                    heapq.heappop(act_heap)
                    continue
                t_act = when
                break
            t_res = restarts[0] if restarts else float("inf")
            t_next = min(t_arr, t_done, t_act, t_res)
            if t_next == float("inf") or t_next > horizon:
                break
            if max_events is not None and events >= max_events:
                truncated = True
                break
            events += 1
            # ---- advance time, drain work, integrate energy ----
            dt = t_next - now
            if dt > 0:
                for tid in sorted(running_ids):
                    t = table[tid]
                    rate = min(len(t.engines), t.par_cap)
                    drain_par = min(t.par_es, rate * dt)
                    t.par_es -= drain_par
                    left = dt - drain_par / max(rate, 1)
                    drain_ser = min(t.ser_s, max(left, 0.0))
                    t.ser_s -= drain_ser
                    exec_energy += t.energy_total * (
                        drain_par + drain_ser) / max(t.work_total, _EPS)
                    busy_integral += len(t.engines) * dt
                now = t_next

            if t_res <= min(t_arr, t_done, t_act):
                # scheduler-process kill/restart: host state dies (or is
                # snapshot-restored under cfg.persist_dir); tasks running
                # on the accelerator are unaffected. Restarts outrank
                # same-instant arrivals so those arrivals hit the
                # restarted (worst-case cold) scheduler.
                restarts.popleft()
                sched.on_restart(self, now)
                continue
            completed: Optional[TaskState] = None
            if t_done <= min(t_arr, t_act) and done_task is not None:
                done_task.par_es = max(done_task.par_es, 0.0)
                done_task.ser_s = max(done_task.ser_s, 0.0)
                done_task.status = "done"
                done_task.finish = now
                done_task.engines = []
                running_ids.discard(done_task.spec.task_id)
                completed = done_task
                n_finished += 1
                if done_task.finish <= done_task.spec.deadline:
                    deadline_met += 1
                    if done_task.spec.urgent:
                        urgent_met += 1
                fin_ids.append(done_task.spec.task_id)
                fin_lat.append(done_task.finish - done_task.spec.arrival)
                fin_st.append(done_task.sched_time)
                if validate:
                    assert done_task.finish >= done_task.spec.arrival, \
                        f"task {done_task.spec.task_id} finished before " \
                        f"arriving"
                dec = sched.on_event(self, now, table, trigger="completion")
            elif t_arr <= min(t_done, t_act):
                # one event delivers ALL tasks that became schedulable at
                # this instant (burst arrivals coalesce into one decision)
                arrived = []
                while next_spec is not None \
                        and next_spec.arrival <= now + _EPS:
                    next_spec.task_id = admitted
                    ts = self._admit(next_spec)
                    ts.status = "ready"
                    ts.ready_at = now
                    table.add(ts)
                    admitted += 1
                    if next_spec.urgent:
                        urgent_total += 1
                    arrived.append(ts)
                    next_spec = next(stream, None)
                peak_live = max(peak_live, len(table))
                dec = sched.on_event(self, now, table, trigger="arrival",
                                     arrived=arrived)
            else:
                dec = sched.on_event(self, now, table, trigger="activate")
            sched_energy += self._apply(dec, table, now, act_heap=act_heap)
            # reconcile the running set with what the decision did
            if dec:
                for tid in dec.get("preempt", []):
                    t = table.get(tid)
                    if t is None or t.status != "running":
                        running_ids.discard(tid)
                for tid in dec.get("alloc", {}):
                    t = table.get(tid)
                    if t is not None and t.status == "running":
                        running_ids.add(tid)
            if completed is not None:
                table.pop(completed.spec.task_id)
            if validate:
                seen: set = set()
                for tid in running_ids:
                    es = set(table[tid].engines)
                    assert not (seen & es), \
                        f"engines {seen & es} double-booked at t={now}"
                    seen |= es
                assert busy_integral <= \
                    self.platform.engines * now + 1e-9, \
                    "busy_integral exceeds engines*now"

        idle_energy = (self.platform.engines * now - busy_integral) \
            * self.cost.engine_idle_watts
        total_energy = exec_energy + sched_energy + max(idle_energy, 0.0)
        # order finished-task stats by task id so float summation order
        # (np.mean pairwise over the array) matches the legacy loop's
        # id-ordered list bitwise
        order = np.argsort(np.asarray(fin_ids, dtype=np.int64),
                           kind="stable")
        lat = np.asarray(fin_lat, dtype=np.float64)[order]
        st = np.asarray(fin_st, dtype=np.float64)[order]
        result = SimResult(
            scheduler=sched.name, platform=self.platform.name,
            finished=n_finished, total=admitted,
            deadline_met=deadline_met, urgent_total=urgent_total,
            urgent_met=urgent_met,
            avg_total_latency=float(np.mean(lat)) if lat.size
            else float("inf"),
            avg_sched_time=float(np.mean(st)) if st.size else 0.0,
            total_energy=total_energy, sched_energy=sched_energy,
            exec_energy=exec_energy, idle_energy=max(idle_energy, 0.0),
            sim_horizon=now,
            matcher_stats=sched.matcher_stats(),
            truncated=truncated, events=events,
            alloc_conflicts=self._alloc_conflicts,
            busy_integral=busy_integral, peak_live_tasks=peak_live,
            percentiles=_finish_percentiles(lat, st))
        self._check_invariants(sched, result)
        return result

    # ------------------------------------------------------------------
    def run_legacy(self, scenario: Scenario) -> SimResult:
        """Legacy full-scan event loop (equivalence oracle).

        Materializes the whole task list and rescans it per event — the
        pre-streaming implementation, kept verbatim (plus the shared
        occupancy/truncation fixes) so tests can assert the streaming
        loop reproduces it bitwise on list scenarios. Requires a
        list-based :class:`Scenario`; O(n·events) — do not benchmark it.
        """
        sched = self.scheduler
        sched.reset(self)
        self._alloc_conflicts = 0
        tasks = [self._admit(spec) for spec in scenario.tasks]
        arrivals = [(t.spec.arrival, i) for i, t in enumerate(tasks)]
        heapq.heapify(arrivals)
        restarts = deque(getattr(scenario, "restarts", ()))
        now = 0.0
        busy_integral = 0.0
        sched_energy = 0.0
        exec_energy = 0.0
        horizon = scenario.horizon * 4 + 1.0
        max_events = self.cfg.max_events
        events = 0
        truncated = False
        peak_live = 0

        def running():
            return [t for t in tasks if t.status == "running"]

        def next_completion():
            best, who = float("inf"), None
            for t in running():
                rt = t.remaining_time(len(t.engines))
                if now + rt < best:
                    best, who = now + rt, t
            return best, who

        def next_activation():
            best = float("inf")
            for t in tasks:
                if t.status == "ready" and t.ready_at > now + _EPS:
                    best = min(best, t.ready_at)
            return best

        while True:
            t_arr = arrivals[0][0] if arrivals else float("inf")
            t_done, done_task = next_completion()
            t_act = next_activation()
            t_res = restarts[0] if restarts else float("inf")
            t_next = min(t_arr, t_done, t_act, t_res)
            if t_next == float("inf") or t_next > horizon:
                break
            if max_events is not None and events >= max_events:
                truncated = True
                break
            events += 1
            # ---- advance time, drain work, integrate energy ----
            dt = t_next - now
            if dt > 0:
                for t in running():
                    rate = min(len(t.engines), t.par_cap)
                    drain_par = min(t.par_es, rate * dt)
                    t.par_es -= drain_par
                    left = dt - drain_par / max(rate, 1)
                    drain_ser = min(t.ser_s, max(left, 0.0))
                    t.ser_s -= drain_ser
                    exec_energy += t.energy_total * (
                        drain_par + drain_ser) / max(t.work_total, _EPS)
                    busy_integral += len(t.engines) * dt
                now = t_next

            if t_res <= min(t_arr, t_done, t_act):
                restarts.popleft()
                sched.on_restart(self, now)
                continue
            if t_done <= min(t_arr, t_act) and done_task is not None:
                done_task.par_es = max(done_task.par_es, 0.0)
                done_task.ser_s = max(done_task.ser_s, 0.0)
                done_task.status = "done"
                done_task.finish = now
                done_task.engines = []
                dec = sched.on_event(self, now, tasks, trigger="completion")
            elif t_arr <= min(t_done, t_act):
                arrived = []
                while arrivals and arrivals[0][0] <= now + _EPS:
                    _, idx = heapq.heappop(arrivals)
                    t = tasks[idx]
                    t.status = "ready"
                    t.ready_at = now
                    arrived.append(t)
                peak_live = max(peak_live, sum(
                    1 for t in tasks if t.status in ("ready", "running")))
                dec = sched.on_event(self, now, tasks, trigger="arrival",
                                     arrived=arrived)
            else:
                dec = sched.on_event(self, now, tasks, trigger="activate")
            sched_energy += self._apply(dec, tasks, now)

        finished = [t for t in tasks if t.done]
        met = [t for t in finished if t.finish <= t.spec.deadline]
        urgent = [t for t in tasks if t.spec.urgent]
        urgent_met = [t for t in urgent
                      if t.done and t.finish <= t.spec.deadline]
        idle_energy = (self.platform.engines * now - busy_integral) \
            * self.cost.engine_idle_watts
        total_energy = exec_energy + sched_energy + max(idle_energy, 0.0)
        lat = np.asarray([t.finish - t.spec.arrival for t in finished],
                         dtype=np.float64)
        st = np.asarray([t.sched_time for t in finished],
                        dtype=np.float64)
        result = SimResult(
            scheduler=sched.name, platform=self.platform.name,
            finished=len(finished), total=len(tasks),
            deadline_met=len(met), urgent_total=len(urgent),
            urgent_met=len(urgent_met),
            avg_total_latency=float(np.mean(lat)) if lat.size
            else float("inf"),
            avg_sched_time=float(np.mean(st)) if st.size else 0.0,
            total_energy=total_energy, sched_energy=sched_energy,
            exec_energy=exec_energy, idle_energy=max(idle_energy, 0.0),
            sim_horizon=now,
            matcher_stats=sched.matcher_stats(),
            truncated=truncated, events=events,
            alloc_conflicts=self._alloc_conflicts,
            busy_integral=busy_integral, peak_live_tasks=peak_live,
            percentiles=_finish_percentiles(lat, st))
        self._check_invariants(sched, result)
        return result

    # ------------------------------------------------------------------
    def _check_invariants(self, sched, result: SimResult) -> None:
        """End-of-run scheduler cross-checks under ``cfg.validate``.

        Dispatches to the scheduler's ``check_invariants(result)`` hook
        (see :class:`~repro.sched.schedulers.SchedulerBase`) on the
        finished result, from BOTH event loops — so heap and legacy
        runs are held to identical accounting invariants. Schedulers
        without the hook (ad-hoc test doubles) are skipped."""
        if not self.cfg.validate:
            return
        check = getattr(sched, "check_invariants", None)
        if check is not None:
            check(result)

    # ------------------------------------------------------------------
    def _admit(self, spec: TaskSpec) -> TaskState:
        wl = spec.workload
        paradigm = self.scheduler.paradigm
        p = self.platform
        per_engine = p.macs_per_engine * p.clock_hz * self.cost.engine_util_dnn
        par_es = wl.total_macs / per_engine
        if paradigm == "tss":
            _, e = self.cost.exec_tss(wl, max(p.engines // 2, 1))
            ser = wl.total_bytes * self.cost.avg_hops / (
                p.noc_link_bw_bytes * max(p.engines // 2, 1))
        else:
            overlap = getattr(self.scheduler, "overlap", 0.0)
            _, e = self.cost.exec_lts(wl, p.engines, overlap)
            ser = 2.0 * wl.total_bytes / p.dram_bw_bytes * (1.0 - overlap)
        depth = max(len(wl.layers) // 8, 1)
        par_cap = int(np.clip(len(wl.layers) / depth * 4, 1, p.engines))
        live = np.mean([l.bytes_moved for l in wl.layers]) * 4
        return TaskState(spec=spec, par_es=par_es, ser_s=ser,
                         par_cap=par_cap, energy_total=e,
                         work_total=par_es + ser, live_bytes=float(live))

    def _apply(self, decision, tasks, now, act_heap=None) -> float:
        """Apply a scheduler decision. ``tasks`` is indexable by task id
        and iterable over TaskStates (legacy list or TaskTable).

        Decision ``delay`` entries are the ONLY sanctioned way to move a
        task's ``ready_at`` into the future — the streaming loop's
        activation heap is fed here, so a scheduler mutating ``ready_at``
        directly would never get its activation event.
        """
        if decision is None:
            return 0.0
        for tid in decision.get("preempt", []):
            t = tasks[tid]
            if t.status == "running":
                t.status = "ready"
                t.engines = []
                dt, de = (self.cost.preemption_cost_tss(t.live_bytes)
                          if self.scheduler.paradigm == "tss" else
                          self.cost.preemption_cost_lts(t.live_bytes))
                t.add_cost(dt, de)
                if act_heap is not None and t.ready_at > now + _EPS:
                    heapq.heappush(act_heap, (t.ready_at, tid))
        # delays first: a delayed task cannot start in the same decision
        for tid, delay in decision.get("delay", {}).items():
            t = tasks[tid]
            if delay > 0:
                t.ready_at = max(t.ready_at, now + delay)
                t.sched_time += delay
                if act_heap is not None:
                    heapq.heappush(act_heap, (t.ready_at, tid))
        # global occupancy: engines held by running tasks are never
        # re-granted — a scheduler decision that tries is a bug we
        # surface via the alloc_conflicts counter instead of silently
        # double-booking the engine (ROADMAP invariant)
        occupied: set = set()
        for t in tasks:
            if t.status == "running":
                occupied.update(t.engines)
        claimed: set = set(occupied)
        for tid, engines in decision.get("alloc", {}).items():
            t = tasks[tid]
            self._alloc_conflicts += sum(1 for e in engines
                                         if e in occupied)
            engines = [e for e in engines if e not in claimed]
            if t.status == "ready" and engines and now >= t.ready_at - _EPS:
                t.status = "running"
                t.engines = list(engines)
                claimed.update(engines)
        return decision.get("energy", 0.0)
