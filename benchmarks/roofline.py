"""Roofline analysis: combine dry-run cell + probe records into the
three-term roofline table (EXPERIMENTS.md §Roofline).

Methodology (see EXPERIMENTS.md §Dry-run for the caveat this fixes): XLA's
HLO cost analysis counts a while-loop body ONCE, so scanned layer stacks
under-report FLOPs/bytes/collectives by ~the layer count. The dry-run
therefore also compiles reduced-depth *fully-unrolled probes* (k=2 and k=3
pattern units; +tail probe for zamba2) whose cost deltas give exact
per-pattern-unit terms:

    unit      = probe(3) - probe(2)
    base      = probe(2) - 2·unit
    corrected = (base + units·unit + tail·tail_unit) × microbatches

Two inner while-loops survive inside a pattern unit and are added back
analytically (they cannot be unrolled at 32k–512k sequence length):
  * the chunked-GLA state scan of Mamba2/mLSTM (state-carry einsums per
    chunk), and
  * the sLSTM time scan (per-step recurrent matmul).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
Terms are per-chip seconds (cost analysis of the SPMD module is
per-device; collective bytes are per-device wire bytes).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def _key(r):
    return (r["arch"], str(r["shape"]))


def load(path: str):
    with open(path) as f:
        recs = json.load(f)
    cells = {}
    probes = {}
    for r in recs:
        if r["mesh"] != "pod-16x16":
            continue
        if "probe" in r:
            probes.setdefault(_key(r), {})[r["probe"]] = r
        else:
            cells[_key(r)] = r
    return cells, probes


def _gla_addback(arch: str, shape_name: str, mode: str) -> Dict[str, float]:
    """Analytic inner-scan terms (global; divided by CHIPS by caller)."""
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES
    cfg = get_config(arch)
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    if cfg.ssm is None or shape.mode == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    B, S = shape.global_batch, shape.seq_len
    L = cfg.ssm.chunk
    N = S // L
    flops = bytes_ = 0.0
    mult = 3.0 if mode == "train" else 1.0   # fwd + bwd + remat fwd
    if cfg.family == "hybrid":               # mamba2
        H = cfg.num_heads
        Dk = cfg.ssm.state_dim
        Dv = cfg.ssm.expand * cfg.d_model // H
        n_layers = cfg.num_layers
        body_flops = 2.0 * B * L * H * Dk * Dv + 3.0 * B * H * Dk * Dv
        state_bytes = B * H * Dk * Dv * 4 * 2
        flops = (N - 1) * body_flops * n_layers * mult
        bytes_ = (N - 1) * state_bytes * n_layers * mult
    elif cfg.family == "ssm":                # xlstm
        H = cfg.num_heads
        d_in = cfg.ssm.expand * cfg.d_model
        Dh = d_in // H
        n_mlstm = cfg.num_layers - cfg.num_layers // cfg.ssm.slstm_period
        n_slstm = cfg.num_layers // cfg.ssm.slstm_period
        body_flops = 2.0 * B * L * H * Dh * (Dh + 1) + 3.0 * B * H * Dh * (
            Dh + 1)
        state_bytes = B * H * Dh * (Dh + 1) * 4 * 2
        flops += (N - 1) * body_flops * n_mlstm * mult
        bytes_ += (N - 1) * state_bytes * n_mlstm * mult
        # sLSTM: recurrent matmul per step
        Dh_s = cfg.d_model // H
        step_flops = 2.0 * B * H * Dh_s * 4 * Dh_s + 30.0 * B * H * Dh_s
        step_bytes = B * H * Dh_s * 4 * 4 * 2
        flops += (S - 1) * step_flops * n_slstm * mult
        bytes_ += (S - 1) * step_bytes * n_slstm * mult
    return {"flops": flops, "bytes": bytes_}


def corrected_terms(arch: str, shape_name: str, cell: dict,
                    probes: Dict[int, dict]) -> Optional[dict]:
    """Probe-corrected per-device (flops, bytes, collective wire bytes)."""
    from repro.launch import dryrun as dr
    if not (2 in probes and 3 in probes
            and probes[2]["ok"] and probes[3]["ok"]):
        return None
    counts = dr.pattern_counts(arch)
    M = probes[2].get("microbatches_full", 1)

    def term(field):
        if field == "coll":
            p2 = probes[2]["collectives"]["total_bytes"]
            p3 = probes[3]["collectives"]["total_bytes"]
            p5 = probes.get(5, {}).get("collectives", {}).get("total_bytes")
        else:
            p2, p3 = probes[2][field], probes[3][field]
            p5 = probes.get(5, {}).get(field)
        unit = max(p3 - p2, 0.0)
        base = max(p2 - 2 * unit, 0.0)
        tail_unit = max((p5 - p2), 0.0) if (
            p5 is not None and counts["tail"]) else 0.0
        tot = base + counts["units"] * unit + counts["tail"] * tail_unit
        return tot * M

    mode = ("train" if shape_name == "train_4k" else
            "prefill" if shape_name == "prefill_32k" else "decode")
    add = _gla_addback(arch, shape_name, mode)
    return {
        "flops": term("hlo_flops") + add["flops"] / CHIPS,
        "bytes": term("hlo_bytes") + add["bytes"] / CHIPS,
        "coll": term("coll"),
    }


def roofline_row(arch: str, shape_name: str, cell: dict,
                 probes) -> dict:
    corr = corrected_terms(arch, shape_name, cell, probes or {})
    raw = {"flops": cell["hlo_flops"], "bytes": cell["hlo_bytes"],
           "coll": cell["collectives"]["total_bytes"]}
    use = corr or raw
    t_compute = use["flops"] / PEAK_FLOPS
    t_memory = use["bytes"] / HBM_BW
    t_coll = use["coll"] / ICI_BW
    bound = max(t_compute, t_memory, t_coll)
    which = ("compute" if bound == t_compute else
             "memory" if bound == t_memory else "collective")
    model_flops_dev = cell.get("model_flops", 0.0) / CHIPS
    t_model = model_flops_dev / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape_name,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": which,
        "model_flops_ratio": (model_flops_dev / use["flops"]
                              if use["flops"] else 0.0),
        "roofline_fraction": (t_model / bound) if bound else 0.0,
        "corrected": corr is not None,
        "mem_temp_bytes": (cell.get("memory") or {}).get("temp_bytes", 0),
        "mem_args_bytes": (cell.get("memory") or {}).get(
            "argument_bytes", 0),
    }


def build_table(path: str):
    cells, probes = load(path)
    rows = []
    for (arch, shape_name), cell in sorted(cells.items()):
        if not cell["ok"] or arch == "immsched-matcher":
            continue
        rows.append(roofline_row(arch, shape_name, cell,
                                 probes.get((arch, shape_name))))
    return rows


def main(path: str = "dryrun.json"):
    rows = build_table(path)
    hdr = (f"{'arch':20s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'bound':>10s} {'useful/HLO':>10s}"
           f" {'roofline%':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:20s} {r['shape']:12s} "
              f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:10.4f} {r['bottleneck']:>10s} "
              f"{r['model_flops_ratio']:10.3f} "
              f"{100 * r['roofline_fraction']:8.1f}%"
              + ("" if r["corrected"] else "  (raw)"))
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun.json")
