from repro.sched.tasks import (TaskSpec, Scenario, make_burst_scenario,
                               make_mixed_burst_scenario, make_scenario)
from repro.sched.simulator import Simulator, SimConfig, SimResult
from repro.sched.schedulers import (SCHEDULERS, IMMSchedScheduler,
                                    IsoSchedScheduler, LTSScheduler,
                                    get_scheduler)
from repro.sched.metrics import (latency_bound_throughput,
                                 pipeline_tier_rates, speedup_table,
                                 energy_efficiency)
