"""Warm-restart persistence: on-disk AOT executables + snapshot codecs.

A restarted ``MatcherService`` process used to pay the full cold path on
its very first arrival — a Python-level jit trace (seconds), an XLA
compile, and a cold :class:`~repro.core.service.CarryStore` — exactly the
unpredictable-arrival case the paper bounds scheduling latency for. This
module removes both cold components:

  * **AOT executable cache** (:class:`AOTCache`) — every single-device
    service executable (swarm match, batched match, batched revalidate)
    is exported via ``jax.export`` on its first trace and serialized to
    ``<dir>/<kind>-<shapes>-<digest>.jaxexp``. A restarted process
    deserializes the blob and calls the compiled program **without ever
    tracing Python** (the ``jit_traces`` counter stays 0). The file key
    includes :func:`repro.kernels.backend.config_digest` — resolved
    kernel suite + every ``PSOConfig`` field — plus jax version and
    platform, so a config or toolchain drift is a clean cache miss, never
    a wrong program.
  * **XLA compile cache fallback** (:func:`enable_jax_compilation_cache`)
    — mesh-sharded executables (``build_distributed_*``) cannot be
    exported portably (the serialized module pins device counts; the
    builders mark themselves ``aot_exportable = False``); for those, and
    for the residual XLA compile of deserialized modules, JAX's
    persistent compilation cache is pointed at ``<persist_dir>/xla``.
  * **Snapshot codecs** (:func:`encode_key` / :func:`decode_key`,
    :func:`carry_leaves` / :func:`carries_from_leaves`) — the service's
    snapshot (``MatcherService.save_snapshot``) stores warm-start carries
    as flat numpy leaf dicts through
    :class:`repro.checkpoint.manager.CheckpointManager` (atomic commit,
    versioned, digest-validated); these helpers round-trip the store keys
    (tuples containing str/int/float/bytes/None) through JSON.

Environment knobs (all optional — constructor args win):

  * ``REPRO_PERSIST_DIR`` — default persistence root for services built
    without an explicit ``persist_dir``.
  * ``REPRO_AOT_CACHE=0`` — disable the executable cache (snapshots
    stay on).
  * ``REPRO_JAX_CACHE=0`` — do not touch JAX's persistent compilation
    cache config even when a persist dir is set.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import export as jax_export

#: Bump when the snapshot layout changes incompatibly; restores of any
#: other version are skipped cleanly (``snapshot_stale_skipped``).
SNAPSHOT_VERSION = 1

ENV_PERSIST_DIR = "REPRO_PERSIST_DIR"
ENV_AOT_CACHE = "REPRO_AOT_CACHE"
ENV_JAX_CACHE = "REPRO_JAX_CACHE"

_AOT_SUFFIX = ".jaxexp"


def default_persist_dir() -> Optional[str]:
    """Persistence root from the environment (None = persistence off)."""
    d = os.environ.get(ENV_PERSIST_DIR, "").strip()
    return d or None


def aot_cache_enabled() -> bool:
    """False when ``REPRO_AOT_CACHE=0`` opts the process out of AOT."""
    return os.environ.get(ENV_AOT_CACHE, "1").strip() != "0"


_jax_cache_dir: List[str] = []     # process-global: first enable wins


def enable_jax_compilation_cache(directory: str) -> bool:
    """Point JAX's persistent XLA compilation cache at ``directory``.

    Covers what ``jax.export`` cannot: the XLA compile of a deserialized
    module, and mesh-sharded executables that are never exported. The
    min-compile-time/entry-size floors are zeroed so the service's small
    revalidation programs qualify.

    The cache dir is **process-global JAX state**, so the first enabled
    directory wins for the process lifetime: a second service with a
    different persist root returns False and leaves the existing cache
    in place (re-pointing mid-process would scatter one service's
    compiles across another's tree). Also returns False when the
    running JAX build lacks the knobs or ``REPRO_JAX_CACHE=0``."""
    if os.environ.get(ENV_JAX_CACHE, "1").strip() == "0":
        return False
    if _jax_cache_dir:
        return _jax_cache_dir[0] == directory
    try:
        jax.config.update("jax_compilation_cache_dir", directory)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:  # pragma: no cover - older/newer jax knob drift
        return False
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:  # pragma: no cover
        pass
    _jax_cache_dir.append(directory)
    return True


# ---------------------------------------------------------------------------
# AOT executable cache
# ---------------------------------------------------------------------------

class AOTCache:
    """On-disk cache of ``jax.export``-serialized service executables.

    One file per executable key; keys are built by the service from
    (kind, shape bucket, batch class, config digest). All load/export
    failures degrade to the plain jit path — a corrupt or incompatible
    blob can slow a restart down but never break or change a result.

    ``stats`` is the owning service's ``ServiceStats``; this class bumps
    its ``aot_*`` and ``jit_traces`` counters so the zero-trace warm
    restart is assertable (``stats.jit_traces == 0``).
    """

    def __init__(self, directory: str, stats=None):
        self.dir = directory
        self.stats = stats
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.dir, key + _AOT_SUFFIX)

    def entries(self) -> List[str]:
        """Keys of every serialized executable currently on disk."""
        return sorted(n[:-len(_AOT_SUFFIX)] for n in os.listdir(self.dir)
                      if n.endswith(_AOT_SUFFIX))

    def _bump(self, field: str, by: int = 1) -> None:
        if self.stats is not None:
            setattr(self.stats, field, getattr(self.stats, field) + by)

    def load(self, key: str, build: Callable[[], Callable]
             ) -> Optional[Callable]:
        """Deserialized executable for ``key``, or None on a cache miss.

        The returned callable runs the serialized program with **no
        Python trace**. ``build`` is the lazy fallback: if a later call
        hits an input-signature mismatch (the exported module is exact
        about shapes/dtypes), the wrapper silently rebuilds the live jit
        function — counted in ``aot_call_fallbacks``/``jit_traces`` —
        instead of failing the request."""
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                blob = f.read()
            exported = jax_export.deserialize(bytearray(blob))
        except Exception:
            return None
        fallback: List[Callable] = []

        def call(*args):
            if fallback:
                return fallback[0](*args)
            try:
                return exported.call(*args)
            except Exception:
                self._bump("aot_call_fallbacks")
                self._bump("jit_traces")
                fallback.append(build())
                return fallback[0](*args)

        return call

    def wrap_exporting(self, key: str, fn: Callable) -> Callable:
        """Wrap a fresh jit function so its first call also exports it.

        The first invocation traces (counted in ``jit_traces``), exports
        the traced program with the concrete argument avals, and writes
        the serialized blob under ``key`` (atomic rename); subsequent
        calls run the exported module. Functions marked
        ``aot_exportable = False`` (the mesh builders in
        ``core/matcher.py``) and export failures fall through to plain
        jit, counted in ``aot_export_failures``."""
        if not getattr(fn, "aot_exportable", True):
            return fn
        state: List[Callable] = []

        def call(*args):
            if state:
                return state[0](*args)
            self._bump("jit_traces")
            try:
                exported = jax_export.export(fn)(*args)
                blob = exported.serialize()
            except Exception:
                self._bump("aot_export_failures")
                state.append(fn)
                return fn(*args)
            try:
                tmp = self._path(key) + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(bytes(blob))
                os.replace(tmp, self._path(key))
                self._bump("aot_exports")
            except OSError:  # pragma: no cover - disk full etc.
                pass
            state.append(exported.call)
            return exported.call(*args)

        return call


# ---------------------------------------------------------------------------
# Snapshot codecs
# ---------------------------------------------------------------------------

def encode_key(key: Any) -> Any:
    """JSON-safe encoding of a warm-store key.

    Keys are tuples nesting str/int/float/bool/None/bytes/tuples (the
    service's warm keys and the scheduler's ``(name, signature)``
    workload keys). Bytes become ``{"__b": hex}``, tuples
    ``{"__t": [...]}`` so :func:`decode_key` reconstructs the exact
    (hashable) original. Raises ``TypeError`` for anything else — the
    snapshot writer skips (and counts) such entries instead of storing a
    key that would never match again."""
    if key is None or isinstance(key, (str, int, float, bool)):
        return key
    if isinstance(key, bytes):
        return {"__b": key.hex()}
    if isinstance(key, tuple):
        return {"__t": [encode_key(k) for k in key]}
    raise TypeError(f"unsnapshotable key component: {type(key)!r}")


def decode_key(obj: Any) -> Any:
    """Inverse of :func:`encode_key`."""
    if isinstance(obj, dict):
        if "__b" in obj:
            return bytes.fromhex(obj["__b"])
        if "__t" in obj:
            return tuple(decode_key(k) for k in obj["__t"])
        raise ValueError(f"unknown key encoding: {sorted(obj)}")
    return obj


def carry_leaves(prefix: str, carries: Sequence[tuple]
                 ) -> Dict[str, np.ndarray]:
    """Flatten a list of ``(S_star, f_star, S_bar)`` carries to a flat
    ``{leaf-name: np.ndarray}`` dict (the shape CheckpointManager's
    per-leaf .npy layout wants). Leaf names are ``{prefix}.{i}.{part}``
    with ``part`` in S/f/C; entries keep their list order so restores
    preserve LRU recency.

    Carries may be device arrays (the service keeps them device-resident
    between drains): the whole batch is materialized with ONE blocking
    ``jax.device_get`` at save time — a single host sync per snapshot —
    instead of one implicit transfer per leaf."""
    out: Dict[str, Any] = {}
    for i, (s, f, c) in enumerate(carries):
        out[f"{prefix}.{i:05d}.S"] = s
        out[f"{prefix}.{i:05d}.f"] = f
        out[f"{prefix}.{i:05d}.C"] = c
    host = jax.device_get(out)
    return {k: np.asarray(v) for k, v in host.items()}


def carries_from_leaves(prefix: str, leaves: Dict[str, np.ndarray],
                        count: int) -> List[tuple]:
    """Inverse of :func:`carry_leaves` for ``count`` entries."""
    return [(leaves[f"{prefix}.{i:05d}.S"],
             leaves[f"{prefix}.{i:05d}.f"],
             leaves[f"{prefix}.{i:05d}.C"])
            for i in range(count)]
