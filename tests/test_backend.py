"""Kernel-backend layer: registry/selection precedence, and the parity
sweep — every kernel registered in ``KERNEL_NAMES`` must agree between the
Pallas suite (interpret mode) and the jnp oracle suite across shapes ×
mask dtypes, bitwise for integer outputs and allclose for float ones.
The sweep is driven off the registry itself: registering a kernel without
a parity case fails ``test_every_registered_kernel_has_parity_case``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pso
from repro.kernels import (ENV_VAR, KERNEL_NAMES, KernelBackend,
                           get_backend, register_backend,
                           registered_backends, resolve_backend_name)
from repro.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(1, 8, 16), (2, 40, 72)]
MASK_DTYPES = [jnp.uint8, jnp.int32]


class _Problem:
    """One random matching instance with planted singleton rows (so the
    injectivity half of the fused prune has work to do)."""

    def __init__(self, seed, B, n, m, mask_dtype):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        S = jax.random.uniform(k1, (B, n, m))
        self.S = S / S.sum(-1, keepdims=True)
        self.S_q = ref.quantize_s(self.S)
        Q = jax.random.bernoulli(k2, 0.3, (n, n)).astype(jnp.uint8)
        self.Q = jnp.triu(Q, k=1)                      # DAG
        G = jax.random.bernoulli(k3, 0.4, (m, m)).astype(jnp.uint8)
        self.G = jnp.triu(G, k=1)
        mask = jax.random.bernoulli(k4, 0.8, (n, m))
        mask = mask.at[:, 0].set(True)                 # no empty rows
        # plant singletons: rows 0 and n//2 keep exactly one candidate,
        # claiming their columns from every other row on the first
        # injectivity propagation
        for i, j in ((0, 1), (n // 2, min(3, m - 1))):
            mask = mask.at[i, :].set(False).at[i, j].set(True)
        self.mask = mask.astype(mask_dtype)
        self.Mb = jnp.broadcast_to(self.mask, (B, n, m)
                                   ).astype(mask_dtype)
        self.V = jax.random.normal(k5, (B, n, m)) * 0.1
        self.r = jax.random.uniform(k1, (B, 3))
        # a projected assignment for the feasibility kernel
        self.M_hat = ref.greedy_project(self.S[0], self.mask)
        # fused-epoch inputs: the B axis doubles as the particle axis N,
        # with 3 pre-drawn inner steps and a seeded local-best fitness
        self.f_local = -jnp.sum(self.S * self.S, axis=(1, 2))
        self.r_steps = jnp.stack([self.r * w for w in (0.25, 0.5, 0.75)])
        # fused-tail input: a pre-drawn Gumbel field (the epilogue's one
        # random input, drawn host-side by ``run_epoch``)
        self.gum = jax.random.gumbel(k5, self.S.shape, dtype=jnp.float32)

    def epoch_args(self):
        """(S, V, S_local, f_local, S_star, f_star, S_bar, mask, Q, G,
        r_all) for one problem — the ``epoch_fused`` signature."""
        return (self.S, self.V, self.S, self.f_local, self.S[0],
                jnp.float32(-1e6), self.S.mean(0), self.mask, self.Q,
                self.G, self.r_steps)

    def epoch_args_batch(self):
        """Two stacked problems for ``epoch_fused_batch`` (problem 1 is
        the base instance, problem 2 a column-rolled variant)."""
        def two(x, axis=None):
            alt = jnp.roll(x, 1, axis=-1) if axis is not None else x
            return jnp.stack([x, alt])
        S2 = two(self.S, -1)
        return (S2, two(self.V, -1), S2, two(self.f_local),
                two(self.S[0], -1), jnp.full((2,), -1e6, jnp.float32),
                two(self.S.mean(0), -1), two(self.mask, -1), two(self.Q),
                two(self.G), two(self.r_steps))

    def finish_args(self):
        """(S, f_final, gum, mask, Q, G) for one problem — the
        ``epoch_finish`` signature (B doubles as the particle axis)."""
        return (self.S, self.f_local, self.gum, self.mask, self.Q, self.G)

    def finish_args_batch(self):
        """Two stacked problems for ``epoch_finish_batch`` with
        ``gum=None`` (the τ=0 calling convention)."""
        def two(x, axis=None):
            alt = jnp.roll(x, 1, axis=-1) if axis is not None else x
            return jnp.stack([x, alt])
        return (two(self.S, -1), two(self.f_local), None,
                two(self.mask, -1), two(self.Q), two(self.G))


_HYPER = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)

# Every registered kernel gets one invocation recipe; outputs are compared
# leaf-by-leaf across backends.
KERNEL_CASES = {
    "edge_fitness": lambda bk, p: bk.edge_fitness(p.S, p.Q, p.G),
    "edge_fitness_quantized":
        lambda bk, p: bk.edge_fitness_quantized(p.S_q, p.Q, p.G),
    "pso_update": lambda bk, p: bk.pso_update(
        p.S, p.V, p.S, p.S[0], p.S.mean(0), p.mask, p.r, **_HYPER),
    "ullmann_refine_step":
        lambda bk, p: bk.ullmann_refine_step(p.Mb, p.Q, p.G),
    "greedy_project": lambda bk, p: bk.greedy_project(p.S[0], p.mask),
    "masked_argmax": lambda bk, p: bk.masked_argmax(p.S[0], p.mask),
    "structured_project":
        lambda bk, p: bk.structured_project(p.S[0], p.Q, p.G, p.mask),
    "injectivity_prune": lambda bk, p: bk.injectivity_prune(p.mask),
    "is_feasible": lambda bk, p: bk.is_feasible(p.M_hat, p.Q, p.G),
    "prune_fixpoint": lambda bk, p: bk.prune_fixpoint(p.mask, p.Q, p.G),
    "prune_fixpoint_batch":
        lambda bk, p: bk.prune_fixpoint_batch(p.Mb, p.Q[None].repeat(
            p.Mb.shape[0], 0), p.G[None].repeat(p.Mb.shape[0], 0)),
    # the fused epoch covers both fitness paths across the sweep: the
    # single-problem case runs float, the batched case quantized
    "epoch_fused": lambda bk, p: bk.epoch_fused(*p.epoch_args(), **_HYPER),
    "epoch_fused_batch": lambda bk, p: bk.epoch_fused_batch(
        *p.epoch_args_batch(), quantized=True, **_HYPER),
    # the fused tail covers both projection modes across the sweep: the
    # single-problem case runs Gumbel-perturbed, the batched case τ=0
    "epoch_finish": lambda bk, p: bk.epoch_finish(
        *p.finish_args(), gumbel_tau=0.3, refine_threshold=0.5,
        refine_iters=2, elite_k=max(1, p.S.shape[0] // 2),
        consensus_temp=25.0),
    "epoch_finish_batch": lambda bk, p: bk.epoch_finish_batch(
        *p.finish_args_batch(), gumbel_tau=0.0, refine_threshold=0.5,
        refine_iters=2, elite_k=max(1, p.S.shape[0] // 2),
        consensus_temp=25.0),
    "quantize_s": lambda bk, p: bk.quantize_s(p.S),
    "dequantize_s": lambda bk, p: bk.dequantize_s(p.S_q),
    "row_normalize_quantized":
        lambda bk, p: bk.row_normalize_quantized(p.S_q[0], p.mask),
}


def _assert_leaves_match(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape
        if np.issubdtype(w.dtype, np.floating):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)
        else:
            np.testing.assert_array_equal(g, w)


def test_every_registered_kernel_has_parity_case():
    assert set(KERNEL_CASES) == set(KERNEL_NAMES)
    # and every backend actually provides every entry point
    for name in registered_backends():
        bk = get_backend(name)
        for k in KERNEL_NAMES:
            assert callable(getattr(bk, k))


@pytest.mark.parametrize("mask_dtype", MASK_DTYPES)
@pytest.mark.parametrize("B,n,m", SHAPES)
@pytest.mark.parametrize("kernel", sorted(KERNEL_CASES))
def test_backend_parity(kernel, B, n, m, mask_dtype):
    p = _Problem(hash((kernel, B, n, m)) % (2 ** 31), B, n, m, mask_dtype)
    got = KERNEL_CASES[kernel](get_backend("interpret"), p)
    want = KERNEL_CASES[kernel](get_backend("ref"), p)
    _assert_leaves_match(got, want)


# ---------------------- fused prune semantics ------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_prune_matches_legacy_alternation(backend):
    """The fused kernel must reproduce the original loose-jnp fixpoint
    (refine sweep alternating with injectivity prune) exactly, on a mask
    with planted singletons, and report ≥ 1 sweep."""
    p = _Problem(7, 1, 12, 20, jnp.uint8)
    legacy = ref.prune_mask_fixpoint(p.mask, p.Q, p.G)
    got, sweeps = get_backend(backend).prune_fixpoint(p.mask, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))
    assert int(sweeps) >= 1
    # idempotent: a fixpoint re-prunes to itself in one sweep
    again, sweeps2 = get_backend(backend).prune_fixpoint(got, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(got))
    assert int(sweeps2) == 1


def test_fused_prune_sweep_counts_agree_across_backends():
    p = _Problem(11, 1, 10, 16, jnp.uint8)
    _, s_ref = get_backend("ref").prune_fixpoint(p.mask, p.Q, p.G)
    _, s_int = get_backend("interpret").prune_fixpoint(p.mask, p.Q, p.G)
    assert int(s_ref) == int(s_int)


def test_fused_prune_respects_iteration_budget():
    p = _Problem(13, 1, 12, 20, jnp.uint8)
    for bk_name in ("ref", "interpret"):
        bk = get_backend(bk_name)
        one, sweeps = bk.prune_fixpoint(p.mask, p.Q, p.G, max_iters=1)
        want = ref.injectivity_prune(
            ref.ullmann_refine_step(p.mask, p.Q, p.G))
        np.testing.assert_array_equal(np.asarray(one), np.asarray(want))
        assert int(sweeps) <= 1


# ---------------------- fused epoch semantics ------------------------------

def _legacy_run_epoch(carry, key, Q, G, mask, cfg):
    """The pre-fusion ``run_epoch`` inner loop, verbatim: per-step PRNG
    splits inside a ``lax.scan`` over ~6 loose kernel dispatches. The
    fused path must reproduce it bitwise — including the RNG draw order
    and the ``f_star`` trace."""
    from repro.kernels import backend as kernel_backend
    bk = kernel_backend.for_config(cfg)
    S_star, f_star, S_bar = carry
    if cfg.gumbel_tau > 0:
        k_init, k_steps, k_gum = jax.random.split(key, 3)
    else:
        k_init, k_steps = jax.random.split(key)
        k_gum = key
    S, V = pso.init_particles(k_init, cfg.num_particles, mask)
    S_local = S
    f_local = pso._fitness(S, Q, G, cfg)
    best0 = jnp.argmax(f_local)
    better0 = f_local[best0] > f_star
    S_star = jnp.where(better0, S[best0], S_star)
    f_star = jnp.where(better0, f_local[best0], f_star)

    def inner(state, k):
        S, V, S_local, f_local, S_star, f_star = state
        r = jax.random.uniform(k, (cfg.num_particles, 3))
        S, V = bk.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                             omega=cfg.omega, c1=cfg.c1, c2=cfg.c2,
                             c3=cfg.c3, v_max=cfg.v_max)
        S = pso._maybe_requantize(S, mask, cfg)
        f = pso._fitness(S, Q, G, cfg)
        improved = f > f_local
        S_local = jnp.where(improved[:, None, None], S, S_local)
        f_local = jnp.maximum(f, f_local)
        b = jnp.argmax(f_local)
        better = f_local[b] > f_star
        S_star = jnp.where(better, S_local[b], S_star)
        f_star = jnp.where(better, f_local[b], f_star)
        return (S, V, S_local, f_local, S_star, f_star), f_star

    keys = jax.random.split(k_steps, cfg.inner_steps)
    (S, *_, S_star, f_star), f_trace = jax.lax.scan(
        inner, (S, V, S_local, f_local, S_star, f_star), keys)
    return _legacy_epoch_finish(S, S_star, f_star, f_trace, k_gum,
                                Q, G, mask, cfg)


def _legacy_epoch_finish(S, S_star, f_star, f_trace, k_gum, Q, G, mask,
                         cfg):
    """The pre-fusion epoch epilogue, verbatim: ~6 loose dispatches
    (structured/greedy projections, Ullmann refinement, feasibility,
    a full ``_fitness`` RECOMPUTE of the final swarm, and the top_k
    elite consensus). The fused tail must reproduce every output
    bitwise — including ``fitness``, which it now threads from the
    epoch kernel's last inner step instead of recomputing."""
    from repro.kernels import backend as kernel_backend
    bk = kernel_backend.for_config(cfg)
    if cfg.gumbel_tau > 0:
        gum = jax.random.gumbel(k_gum, S.shape, dtype=jnp.float32)
        S_proj_a = jnp.log(jnp.clip(S.astype(jnp.float32), 1e-9, None)) \
            + cfg.gumbel_tau * gum
    else:
        S_proj_a = S
    M_a = jax.vmap(lambda s: bk.structured_project(s, Q, G, mask))(S_proj_a)
    feas_a = jax.vmap(bk.is_feasible, in_axes=(0, None, None))(M_a, Q, G)
    M_proj = jax.vmap(lambda s: bk.greedy_project(s, mask))(S)
    rowmax = S.max(axis=-1, keepdims=True)
    cand = ((S >= cfg.refine_threshold * rowmax) | (M_proj > 0))
    cand = (cand & (mask[None] > 0)).astype(jnp.uint8)
    cand = jax.lax.fori_loop(
        0, cfg.refine_iters, lambda _, c: bk.ullmann_refine_step(c, Q, G),
        cand)
    S_restricted = S * cand.astype(S.dtype)
    M_b = jax.vmap(lambda s, c: bk.structured_project(s, Q, G, c))(
        S_restricted, cand)
    empty_rows = cand.sum(-1, keepdims=True) == 0
    M_b = jnp.where(empty_rows, M_proj, M_b).astype(jnp.uint8)
    feas_b = jax.vmap(bk.is_feasible, in_axes=(0, None, None))(M_b, Q, G)
    M_hat = jnp.where(feas_a[:, None, None], M_a, M_b)
    feasible = feas_a | feas_b
    f_final = pso._fitness(S, Q, G, cfg)
    k = max(1, int(round(cfg.elite_frac * S.shape[0])))
    f_top, idx = jax.lax.top_k(f_final, k)
    w = jax.nn.softmax((f_top - f_top[0]) / cfg.consensus_temp)
    S_bar = jnp.einsum("k,knm->nm", w, S[idx])
    out = dict(mappings=M_hat, feasible=feasible, fitness=f_final,
               f_star_trace=f_trace, S_final=S)
    return (S_star, f_star, S_bar), out


def _assert_leaves_bitwise(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("gumbel_tau", [0.0, 0.3])
@pytest.mark.parametrize("quantized", [False, True])
def test_run_epoch_bitwise_equals_legacy_scan(quantized, gumbel_tau,
                                              backend):
    """The refactored ``run_epoch`` (epoch prologue → fused epoch →
    fused tail, two launches) is BITWISE the pre-fusion code (inline
    scan + ~6 loose epilogue dispatches): same RNG key consumption,
    same ``f_star_trace``, same carry — and the threaded ``fitness``
    equals the legacy epilogue's full recompute, on both the ``ref``
    oracle and the Pallas body in interpret mode."""
    p = _Problem(21, 1, 10, 18, jnp.uint8)
    cfg = pso.PSOConfig(num_particles=6, epochs=1, inner_steps=5,
                        quantized=quantized, gumbel_tau=gumbel_tau,
                        backend=backend)
    key = jax.random.PRNGKey(3)
    carry0 = pso.default_carry(p.mask)
    got = pso.run_epoch(carry0, key, p.Q, p.G, p.mask, cfg)
    want = _legacy_run_epoch(carry0, key, p.Q, p.G, p.mask,
                             cfg.replace(backend="ref"))
    _assert_leaves_bitwise(got, want)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("gumbel_tau", [0.0, 0.3])
@pytest.mark.parametrize("quantized", [False, True])
def test_run_epoch_batch_bitwise_equals_vmapped_single(quantized,
                                                       gumbel_tau,
                                                       backend):
    """``run_epoch_batch`` (two problem-gridded launches) is bitwise the
    per-problem ``run_epoch`` on every backend × quantized × Gumbel
    config — each problem's slice of the carry and outputs equals an
    independent single-problem epoch with that problem's key."""
    P = 2
    p = _Problem(29, 1, 9, 15, jnp.uint8)
    Qb = jnp.stack([p.Q] * P)
    Gb = jnp.stack([p.G] * P)
    maskb = jnp.stack([p.mask, jnp.roll(p.mask, 1, axis=-1)])
    keys = jax.random.split(jax.random.PRNGKey(41), P)
    cfg = pso.PSOConfig(num_particles=5, epochs=1, inner_steps=4,
                        quantized=quantized, gumbel_tau=gumbel_tau,
                        backend=backend)
    carry0 = pso.default_carry_batch(maskb)
    carry_b, outs_b = pso.run_epoch_batch(carry0, keys, Qb, Gb, maskb,
                                          cfg)
    for b in range(P):
        carry1 = jax.tree_util.tree_map(lambda x: x[b], carry0)
        got = (jax.tree_util.tree_map(lambda x: x[b], carry_b),
               jax.tree_util.tree_map(lambda x: x[b], outs_b))
        want = pso.run_epoch(carry1, keys[b], Qb[b], Gb[b], maskb[b],
                             cfg)
        _assert_leaves_bitwise(got, want)


@pytest.mark.parametrize("mask_dtype", MASK_DTYPES)
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("B,n,m", SHAPES)
def test_fused_epoch_bitwise_across_backends(B, n, m, quantized,
                                             mask_dtype):
    """The fused kernel's own outputs (S_final, S_star, f_star, f_trace)
    are bitwise-identical between the loose-scan ``ref`` path and the
    Pallas body in interpret mode — stronger than the allclose bar the
    float kernels in the generic sweep get."""
    p = _Problem(hash(("epoch", B, n, m)) % (2 ** 31), B, n, m, mask_dtype)
    args = p.epoch_args_batch()
    got = get_backend("interpret").epoch_fused_batch(
        *args, quantized=quantized, **_HYPER)
    want = get_backend("ref").epoch_fused_batch(
        *args, quantized=quantized, **_HYPER)
    _assert_leaves_bitwise(got, want)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_epoch_f_star_trace_monotone(backend):
    """Property: the in-epoch global best can only improve — the f_star
    trace is non-decreasing step over step, starts no lower than the
    seeded f_star, and ends at the returned f_star (both backends)."""
    p = _Problem(33, 4, 10, 18, jnp.uint8)
    args = p.epoch_args()
    _, _, f_star, f_trace, _ = get_backend(backend).epoch_fused(
        *args, **_HYPER)
    trace = np.asarray(f_trace)
    assert np.all(np.diff(trace) >= 0)
    assert trace[0] >= float(args[5])     # seeded f_star lower-bounds it
    assert trace[-1] == np.asarray(f_star)


def test_epoch_rng_draws_match_scan_consumption():
    """Property: hoisting the per-step uniforms out of the scan (the
    ``r_all`` the fused kernel consumes) yields value-identical draws in
    the same order as splitting inside the loop — the RNG-consumption
    contract the bitwise parity above rests on."""
    k_steps = jax.random.PRNGKey(17)
    K, N = 6, 5
    keys = jax.random.split(k_steps, K)
    hoisted = jax.vmap(lambda k: jax.random.uniform(k, (N, 3)))(keys)
    _, scanned = jax.lax.scan(
        lambda c, k: (c, jax.random.uniform(k, (N, 3))), None, keys)
    np.testing.assert_array_equal(np.asarray(hoisted), np.asarray(scanned))
    # and _epoch_start feeds exactly these draws to the fused kernel
    p = _Problem(5, 1, 8, 16, jnp.uint8)
    cfg = pso.PSOConfig(num_particles=N, inner_steps=K, backend="ref")
    _, k_steps2 = jax.random.split(jax.random.PRNGKey(17))
    *_, r_all, _ = pso._epoch_start(
        pso.default_carry(p.mask), jax.random.PRNGKey(17),
        p.Q, p.G, p.mask, cfg)
    want = jax.vmap(lambda k: jax.random.uniform(k, (N, 3)))(
        jax.random.split(k_steps2, K))
    np.testing.assert_array_equal(np.asarray(r_all), np.asarray(want))


# ---------------------- fused tail semantics -------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_epoch_fused_f_last_equals_fitness_recompute(backend):
    """The fused epoch's 5th output (last-step per-particle fitness) is
    the ``_fitness`` of the returned final swarm — the identity that
    lets the fused tail drop the pre-fusion epilogue's redundant
    fitness launch. Semantically the two are the same op sequence on
    the same bits; asserted here allclose-tight because XLA may group
    the f32 residual reduction differently inside the jitted epoch
    program than in a standalone ``_fitness`` dispatch (a last-ulp
    effect). The *pipeline-level* bitwise bar — threaded fitness vs the
    legacy epilogue's recompute inside ``run_epoch`` — is held by
    ``test_run_epoch_bitwise_equals_legacy_scan``."""
    p = _Problem(37, 6, 10, 18, jnp.uint8)
    for quantized in (False, True):
        args = p.epoch_args()
        S_fin, _, _, _, f_last = get_backend(backend).epoch_fused(
            *args, quantized=quantized, **_HYPER)
        cfg = pso.PSOConfig(quantized=quantized, backend=backend)
        want = pso._fitness(S_fin, p.Q, p.G, cfg)
        np.testing.assert_allclose(np.asarray(f_last), np.asarray(want),
                                   rtol=1e-6, atol=0)


def test_fused_tail_consumes_legacy_gumbel_key_order():
    """Regression: the fused tail draws its Gumbel field from the THIRD
    split of the epoch key — the legacy ``(k_init, k_steps, k_gum)``
    order — and a τ=0 config still splits 2-way, so the inner-step
    stream is untouched by the Gumbel feature being off."""
    p = _Problem(9, 1, 8, 16, jnp.uint8)
    key = jax.random.PRNGKey(23)
    N, K = 4, 3
    cfg = pso.PSOConfig(num_particles=N, inner_steps=K, gumbel_tau=0.4,
                        backend="ref")
    carry0 = pso.default_carry(p.mask)
    *_, k_gum = pso._epoch_start(carry0, key, p.Q, p.G, p.mask, cfg)
    _, _, k_gum_want = jax.random.split(key, 3)
    np.testing.assert_array_equal(np.asarray(k_gum),
                                  np.asarray(k_gum_want))
    # τ=0: 2-way split, and the hoisted step draws come from its k_steps
    cfg0 = cfg.replace(gumbel_tau=0.0)
    *_, r_all, _ = pso._epoch_start(carry0, key, p.Q, p.G, p.mask, cfg0)
    _, k_steps = jax.random.split(key)
    want = jax.vmap(lambda k: jax.random.uniform(k, (N, 3)))(
        jax.random.split(k_steps, K))
    np.testing.assert_array_equal(np.asarray(r_all), np.asarray(want))


def test_consensus_and_refinement_route_through_seam():
    """``pso.elite_consensus`` / ``pso.ullmann_refine_candidates`` must
    delegate to the KernelBackend seam (a custom suite can override
    them), and the seam's results must equal the pre-seam inline
    top_k/refine computations bitwise."""
    calls = []

    class Spy(KernelBackend):
        def elite_consensus(self, S_all, f_all, *, elite_k,
                            consensus_temp):
            calls.append(("consensus", elite_k))
            return super().elite_consensus(
                S_all, f_all, elite_k=elite_k,
                consensus_temp=consensus_temp)

        def ullmann_refine_candidates(self, S, M_proj, Q, G, mask, *,
                                      refine_threshold, refine_iters):
            calls.append(("refine", refine_iters))
            return super().ullmann_refine_candidates(
                S, M_proj, Q, G, mask,
                refine_threshold=refine_threshold,
                refine_iters=refine_iters)

    try:
        register_backend(Spy("spy-test", ops_backend="ref"))
        p = _Problem(3, 4, 8, 16, jnp.uint8)
        cfg = pso.PSOConfig(num_particles=4, refine_iters=2,
                            backend="spy-test")
        S_bar, w_total, w = pso.elite_consensus(p.S, p.f_local, cfg)
        M_proj = jax.vmap(lambda s: ref.greedy_project(s, p.mask))(p.S)
        M_hat, cand = pso.ullmann_refine_candidates(
            p.S, M_proj, p.Q, p.G, p.mask, cfg)
        assert ("consensus", 1) in calls
        assert ("refine", 2) in calls
        # bitwise vs the pre-seam inline code
        f_top, idx = jax.lax.top_k(p.f_local, 1)
        w_want = jax.nn.softmax((f_top - f_top[0]) / cfg.consensus_temp)
        np.testing.assert_array_equal(
            np.asarray(S_bar),
            np.asarray(jnp.einsum("k,knm->nm", w_want, p.S[idx])))
        assert np.asarray(M_hat).dtype == np.uint8
        assert np.asarray(cand).shape == p.S.shape
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("spy-test", None)


# ---------------------- registry + selection precedence --------------------

def test_selection_precedence(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # 4. platform default (CPU → ref)
    assert resolve_backend_name() == "ref"
    assert resolve_backend_name(config=pso.PSOConfig()) == "ref"
    # 3. env override beats the default (and "auto" configs)
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert resolve_backend_name() == "interpret"
    assert resolve_backend_name(config=pso.PSOConfig(backend="auto")) \
        == "interpret"
    # 2. an explicit config beats the env
    assert resolve_backend_name(config=pso.PSOConfig(backend="ref")) == "ref"
    # 1. an explicit argument beats everything
    assert resolve_backend_name(
        "pallas", config=pso.PSOConfig(backend="ref")) == "pallas"
    assert get_backend("interpret").name == "interpret"


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(KeyError, match="registered"):
        get_backend("no-such-backend")


def test_register_custom_backend_roundtrip():
    class Custom(KernelBackend):
        pass

    try:
        register_backend(Custom("custom-test", ops_backend="ref"))
        assert "custom-test" in registered_backends()
        bk = get_backend("custom-test")
        assert isinstance(bk, Custom)
        p = _Problem(3, 1, 8, 16, jnp.uint8)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("custom-test", None)


def test_register_custom_backend_defaults_and_casing():
    """The documented recipe must work as written: a suite registered
    with no ops_backend runs its inherited kernels on the platform
    default path, and mixed-case names resolve through every selection
    route (names are normalized)."""
    try:
        register_backend(KernelBackend("MySuite"))
        bk = get_backend("MySuite")          # arg path, caller's casing
        assert bk.name == "mysuite"
        assert get_backend(config=pso.PSOConfig(backend="MySuite")) is bk
        p = _Problem(5, 1, 8, 16, jnp.uint8)
        # inherited kernel: platform default ("auto" → ref on CPU)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("mysuite", None)
    # an explicit dispatch tag the ops layer cannot honour fails loudly
    with pytest.raises(ValueError, match="dispatch tag"):
        KernelBackend("broken", ops_backend="no-such-tag")


# ---------------------- the seam end-to-end --------------------------------

@pytest.mark.slow
def test_match_runs_on_interpret_backend():
    """The whole Algorithm-1 program compiles and solves a planted
    instance with every kernel routed through the Pallas-interpret
    suite — the seam reaches every call site, not just the leaf tests."""
    from repro.core import graphs
    key = jax.random.PRNGKey(0)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 4, 0.4)
    g = graphs.embed_query_in_target(kt, q, 8)
    Q, G, mask = graphs.as_device_graphs(q, g)
    cfg = pso.PSOConfig(num_particles=4, epochs=1, inner_steps=2,
                        refine_iters=2, backend="interpret")
    outs = pso.match(key, Q, G, mask, cfg)
    ref_cfg = cfg.replace(backend="ref")
    outs_ref = pso.match(key, Q, G, mask, ref_cfg)
    # same pruned search space, same sweep count, and both find the
    # planted embedding
    assert int(outs["prune_sweeps"]) == int(outs_ref["prune_sweeps"])
    assert bool(np.asarray(outs["feasible"]).any())
    assert bool(np.asarray(outs_ref["feasible"]).any())
