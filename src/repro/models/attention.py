"""Attention: GQA/MHA with RoPE or M-RoPE, MLA (DeepSeek-V2), cross-attn.

KV caches are explicit pytrees so ``serve_step`` can shard them:
  GQA cache:  {"k": (B, S_max, Hkv, Dh), "v": (B, S_max, Hkv, Dh)}
  MLA cache:  {"ckv": (B, S_max, kv_lora), "k_rope": (B, S_max, rope_dim)}
MLA caches the *compressed latents* (the whole point of MLA: 512+64 floats
per token instead of 2·H·Dh), expanding K/V on the fly at decode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import common
from repro.models.common import apply_mrope, apply_rope, dense_init


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig) -> dict:
    d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.kv_heads
    Dh = cfg.resolved_head_dim
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, Dh), dtype),
        "wk": dense_init(ks[1], (d, Hkv, Dh), dtype),
        "wv": dense_init(ks[2], (d, Hkv, Dh), dtype),
        "wo": dense_init(ks[3], (H, Dh, d), dtype, in_axis=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dtype)
        p["bk"] = jnp.zeros((Hkv, Dh), dtype)
        p["bv"] = jnp.zeros((Hkv, Dh), dtype)
    return p


_Q_CHUNK = 2048          # prefill q-chunking threshold/size (memory bound)


def _sdpa(q, k, v, mask, compute_dtype, unroll: bool = False):
    """q: (B,Sq,H,Dh); k/v: (B,Skv,Hkv,Dh).

    Sharding/memory design (see EXPERIMENTS.md §Perf):
      * train/prefill (Sq > 1): K/V are repeated to the full head count so
        the einsums are plain MHA with heads sharded on the tensor axis —
        the 5-D grouped einsum made GSPMD pick a kv-head-sharded layout
        (kv_heads < tensor size) and fall back to "involuntary full
        rematerialization" replication;
      * long prefill: q is chunked (scan over 2048-row blocks) so the
        (B,H,Sq,Skv) logits never materialize — 32k×32k attention would
        otherwise need ~17 GB/device of scratch;
      * decode (Sq == 1): grouped einsum against an *S-sharded* KV cache
        (flash-decode): the only collectives are tiny softmax-stat psums.
    """
    from repro.runtime.mesh_ctx import constrain
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    groups = H // Hkv
    scale = Dh ** -0.5

    if Sq == 1:
        qg = q.reshape(B, Sq, Hkv, groups, Dh)
        k = constrain(k, "batch", "tensor", None, None)
        v = constrain(v, "batch", "tensor", None, None)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(compute_dtype),
                            k.astype(compute_dtype)) * scale
        logits = logits.astype(jnp.float32)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs,
                         v.astype(compute_dtype))
        return out.reshape(B, Sq, H, Dh)

    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    q = constrain(q.astype(compute_dtype), "batch", None, "tensor", None)
    k = constrain(k.astype(compute_dtype), "batch", None, "tensor", None)
    v = constrain(v.astype(compute_dtype), "batch", None, "tensor", None)

    def att(q_blk, mask_blk):
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k) * scale
        logits = constrain(logits.astype(jnp.float32),
                           "batch", "tensor", None, None)
        logits = jnp.where(mask_blk[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    if Sq > _Q_CHUNK and Sq % _Q_CHUNK == 0:
        nc = Sq // _Q_CHUNK
        qc = jnp.moveaxis(q.reshape(B, nc, _Q_CHUNK, H, Dh), 1, 0)
        mc = mask.reshape(nc, _Q_CHUNK, mask.shape[-1])

        def body(_, inp):
            q_blk, m_blk = inp
            return None, att(q_blk, m_blk)

        _, out = jax.lax.scan(body, None, (qc, mc),
                              unroll=True if unroll else 1)
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)
    else:
        out = att(q, mask)
    return out


def gqa_attention(params: dict, cfg: ModelConfig, x: jax.Array,
                  positions, cache: Optional[dict] = None,
                  cache_index=None, kv_source: Optional[jax.Array] = None,
                  causal: bool = True):
    """Full attention. ``kv_source`` (cross-attention) overrides K/V input.
    With a cache: append current K/V at ``cache_index`` and attend over the
    full cache buffer. Returns (out, new_cache)."""
    cd = common.dt(cfg.compute_dtype)
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)

    if kv_source is None:  # self-attention: positional encoding on q & k
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        k_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": k_buf, "v": v_buf}
        k, v = k_buf, v_buf
        kv_len = k.shape[1]
        if causal:
            mask = common.causal_mask(x.shape[1], kv_len, cache_index)
        else:
            mask = jnp.ones((x.shape[1], kv_len), dtype=bool)
    else:
        kv_len = k.shape[1]
        mask = (common.causal_mask(x.shape[1], kv_len, 0) if causal else
                jnp.ones((x.shape[1], kv_len), dtype=bool))

    out = _sdpa(q, k, v, mask, cd, unroll=cfg.unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    return out.astype(x.dtype), new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    Dh = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.kv_heads, Dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.kv_heads, Dh), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        # q: low-rank: d -> q_lora -> H*(nope+rope)
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": common.init_rmsnorm(m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H, qd), dtype),
        # kv: compress d -> kv_lora (+ decoupled rope key from d)
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": common.init_rmsnorm(m.kv_lora_rank, dtype),
        "wk_rope": dense_init(ks[3], (d, m.rope_head_dim), dtype),
        # expand latents: kv_lora -> H*(nope_k + v)
        "wk_b": dense_init(ks[4], (m.kv_lora_rank, H, m.nope_head_dim),
                           dtype),
        "wv_b": dense_init(ks[5], (m.kv_lora_rank, H, m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (H, m.v_head_dim, d), dtype, in_axis=(0, 1)),
    }


def mla_attention(params: dict, cfg: ModelConfig, x: jax.Array, positions,
                  cache: Optional[dict] = None, cache_index=None):
    m: MLAConfig = cfg.mla
    cd = common.dt(cfg.compute_dtype)
    B, S, d = x.shape
    H = cfg.num_heads

    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(x.dtype))
    q_lat = common.rmsnorm(params["q_norm"], q_lat, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(x.dtype))
    ckv = common.rmsnorm(params["kv_norm"], ckv, cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["wk_rope"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        ckv_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1)
        kr_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            cache_index, axis=1)
        new_cache = {"ckv": ckv_buf, "k_rope": kr_buf}
        ckv_all, k_rope_all = ckv_buf, kr_buf
        mask = common.causal_mask(S, ckv_all.shape[1], cache_index)
    else:
        ckv_all, k_rope_all = ckv, k_rope
        mask = common.causal_mask(S, S, 0)

    # expand latents to per-head K_nope and V
    k_nope = jnp.einsum("btr,rhk->bthk", ckv_all.astype(cd),
                        params["wk_b"].astype(cd))
    v = jnp.einsum("btr,rhk->bthk", ckv_all.astype(cd),
                   params["wv_b"].astype(cd))

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    logits = (jnp.einsum("bshk,bthk->bhst", q_nope.astype(cd), k_nope)
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(cd),
                           k_rope_all.astype(cd))) * scale
    logits = jnp.where(mask[None, None], logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(cd)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd))
    return out.astype(x.dtype), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }
