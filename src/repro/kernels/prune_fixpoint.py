"""Pallas TPU kernel: fused global-mask pre-prune to fixpoint.

The pre-prune (``ref.prune_mask_fixpoint``) is the cold-start workhorse of
the matcher: before any swarm runs, the global compatibility mask is shrunk
by alternating one Ullmann refinement sweep (1-hop arc consistency, four
{0,1}/small-int matmuls — the MXU path) with one injectivity-propagation
step (row/column reductions — the VPU path). Executed as loose jnp ops this
is 2·iters separate dispatches with an HBM round-trip for the mask between
every half-step; on planted instances the fixpoint takes 5–15 iterations,
so the pre-prune dominates cold-start latency.

This kernel fuses BOTH half-steps into one body and iterates them to
fixpoint *in-kernel*: the mask lives in registers/VMEM for the whole loop,
and an in-kernel convergence flag (``jnp.any(m' != m)`` as the
``lax.while_loop`` carry) stops the sweep the moment nothing changes — one
``pallas_call``, one HBM read of the mask, one write. The iteration count
is emitted per problem (SMEM scalar) as the prune-latency observable the
scheduler's cost accounting consumes.

Grid: ``(B,)`` problems, one per step; each problem carries its OWN Q/G
(the batched matcher prunes per-problem masks), so blocks are
``(1, n, m)`` / ``(1, n, n)`` / ``(1, m, m)``. VMEM at scheduler scale
(n, m ≤ 512 padded): mask + Q + G + int32 temporaries ≈ 5 MB.

Padding requirements (ops.py enforces): padded entries of the mask must be
0 and padded rows/cols of Q and G zero. Zero rows are never singletons
(row-sum 0 ≠ 1) and contribute no violations, so the fused step is exact
w.r.t. the unpadded semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _fused_step(mk: jax.Array, q: jax.Array, g: jax.Array) -> jax.Array:
    """One fused iteration: Ullmann refinement sweep + injectivity prune.

    All int32, mirroring ``ref.ullmann_refine_step`` /
    ``ref.injectivity_prune`` exactly so the Pallas kernel is bitwise
    interchangeable with the jnp oracle.
    """
    # -- refinement sweep: four matmuls on the MXU --
    support_out = jax.lax.dot_general(
        mk, g, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)              # M @ G^T
    support_in = jnp.dot(mk, g, preferred_element_type=jnp.int32)
    miss_out = (support_out == 0).astype(jnp.int32)
    miss_in = (support_in == 0).astype(jnp.int32)
    viol = (jnp.dot(q, miss_out, preferred_element_type=jnp.int32)
            + jax.lax.dot_general(
                q, miss_in, dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.int32))     # Q^T @ miss_in
    mk = mk * (viol == 0).astype(jnp.int32)
    # -- injectivity propagation: row/col reductions on the VPU --
    singleton_rows = (jnp.sum(mk, axis=1, keepdims=True) == 1
                      ).astype(jnp.int32)
    claimed = jnp.sum(singleton_rows * mk, axis=0, keepdims=True)  # (1, m)
    keep = 1 - (claimed > 0).astype(jnp.int32) * (1 - singleton_rows * mk)
    return mk * jnp.clip(keep, 0, 1)


def _prune_kernel(m_ref, q_ref, g_ref, o_ref, it_ref, *, max_iters: int):
    m0 = m_ref[0].astype(jnp.int32)                    # (n, m)
    q = q_ref[0].astype(jnp.int32)                     # (n, n)
    g = g_ref[0].astype(jnp.int32)                     # (m, m)
    n, m_dim = m0.shape
    # each productive iteration removes ≥ 1 candidate, so n·m + 1 bounds
    # the convergence loop when no explicit budget is given
    bound = max_iters if max_iters > 0 else n * m_dim + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < bound)

    def body(state):
        mk, _, it = state
        mk2 = _fused_step(mk, q, g)
        return mk2, jnp.any(mk2 != mk), it + jnp.int32(1)

    out, _, sweeps = jax.lax.while_loop(
        cond, body, (m0, jnp.bool_(True), jnp.int32(0)))
    o_ref[0] = out.astype(o_ref.dtype)
    it_ref[0, 0] = sweeps


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def prune_fixpoint_pallas(M: jax.Array, Qb: jax.Array, Gb: jax.Array,
                          max_iters: int = 0, interpret: bool = False):
    """Fused batched pre-prune. M: (B, n, m) masks; Qb: (B, n, n);
    Gb: (B, m, m) per-problem graphs. Returns ``(pruned (B, n, m),
    sweeps (B,) int32)`` — the single-problem case is just B = 1.
    """
    B, n, m = M.shape
    kernel = functools.partial(_prune_kernel, max_iters=max_iters)
    out, sweeps = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, n, m), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, n), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, m, m), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, m), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n, m), M.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(M, Qb, Gb)
    return out, sweeps[:, 0]
