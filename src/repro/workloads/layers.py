"""Layer-level workload descriptors: the DNN-side input to the scheduler.

A workload is a DAG of ``LayerSpec``s with MAC counts and activation byte
counts — enough for (a) tile-DAG lowering (core.preemptible_dag), (b) the
latency/energy cost model (accel.energy), and (c) the LTS-vs-TSS DRAM
traffic accounting that drives the paper's energy comparison.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class LayerKind(enum.Enum):
    CONV = "conv"
    MATMUL = "matmul"
    ATTN = "attn"
    MOE = "moe"
    POOL = "pool"
    REDUCE = "reduce"
    NORM = "norm"
    ACT = "act"
    ELEMENTWISE = "elementwise"
    EMBED = "embed"
    SSM = "ssm"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    kind: LayerKind
    macs: float                 # multiply-accumulates for the whole layer
    bytes_moved: float          # output activation bytes (traffic unit)
    preds: Tuple[int, ...] = ()  # indices of producer layers


@dataclasses.dataclass
class WorkloadGraph:
    name: str
    layers: List[LayerSpec]

    def adjacency(self) -> np.ndarray:
        n = len(self.layers)
        adj = np.zeros((n, n), dtype=np.uint8)
        for v, spec in enumerate(self.layers):
            for u in spec.preds:
                adj[u, v] = 1
        return adj

    @property
    def total_macs(self) -> float:
        return float(sum(l.macs for l in self.layers))

    @property
    def total_bytes(self) -> float:
        return float(sum(l.bytes_moved for l in self.layers))

    def validate(self) -> None:
        adj = self.adjacency()
        n = len(self.layers)
        # acyclic: preds must come earlier (builders emit topo order)
        for v, spec in enumerate(self.layers):
            assert all(u < v for u in spec.preds), (self.name, v)
        assert adj.shape == (n, n)


class Builder:
    """Tiny sequential-with-branches builder used by the zoo."""

    def __init__(self, name: str):
        self.name = name
        self.layers: List[LayerSpec] = []

    def add(self, name: str, kind: LayerKind, macs: float, out_bytes: float,
            preds: Optional[Sequence[int]] = None) -> int:
        if preds is None:
            preds = [len(self.layers) - 1] if self.layers else []
        preds = tuple(p for p in preds if p >= 0)
        self.layers.append(LayerSpec(name=name, kind=kind, macs=macs,
                                     bytes_moved=out_bytes, preds=preds))
        return len(self.layers) - 1

    def build(self) -> WorkloadGraph:
        wg = WorkloadGraph(name=self.name, layers=self.layers)
        wg.validate()
        return wg


def conv_macs(cin: int, cout: int, k: int, oh: int, ow: int) -> float:
    return float(cin) * cout * k * k * oh * ow


def conv_out_bytes(cout: int, oh: int, ow: int, dtype_bytes: int = 1) -> float:
    return float(cout) * oh * ow * dtype_bytes
