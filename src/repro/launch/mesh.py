"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips ("data", "model");
multi-pod: 2×16×16 = 512 chips ("pod", "data", "model"); parameters and
activations treat ("pod", "data") as one combined FSDP/batch axis (see
runtime.sharding.mesh_axes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
