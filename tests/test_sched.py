"""Scheduling-layer tests: preemptible DAG, ILP tensors, simulator,
schedulers, interrupt policies."""
import numpy as np
import pytest

from repro.accel import CLOUD, EDGE, CostModel
from repro.accel.target_graph import free_engine_graph, target_graph
from repro.core import ilp, interrupts, preemptible_dag
from repro.core.graphs import compatibility_mask
from repro.core.pso import PSOConfig
from repro.sched import (SimConfig, Simulator, get_scheduler, make_scenario)
from repro.sched.tasks import fixed_scenario, make_burst_scenario
from repro.sched.metrics import run_all, speedup_table
from repro.workloads import get_workload


def test_preemptible_dag_window_bounds_size():
    wl = get_workload("resnet50")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=4)
    assert 0 < pd.n <= 64
    assert pd.graph.is_dag()
    pd8 = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=8)
    assert pd8.n >= pd.n


def test_preemptible_dag_multi_task_merge():
    wl1, wl2 = get_workload("mobilenetv2"), get_workload("unet")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl1, 0), (1, wl2, 0)], tile_capacity_macs=cap, window_stages=3)
    assert set(pd.task_tiles) == {0, 1}
    # no cross-task edges
    for a in pd.task_tiles[0]:
        for b in pd.task_tiles[1]:
            assert pd.graph.adj[a, b] == 0 and pd.graph.adj[b, a] == 0


def test_pad_problem_preserves_matchability():
    from repro.core import graphs, ullmann
    import jax
    q = graphs.random_dag(jax.random.PRNGKey(0), 5, 0.4)
    g = graphs.embed_query_in_target(jax.random.PRNGKey(1), q, 10)
    mask = compatibility_mask(q, g)
    Qp, Gp, maskp = preemptible_dag.pad_problem(q.adj, g.adj, mask, 8, 16)
    sols = ullmann.serial_ullmann(Qp, Gp, maskp, max_solutions=1)
    assert sols, "padded problem must stay feasible"
    M = preemptible_dag.unpad_mapping(sols[0], 5, 10)
    covered = M.astype(int) @ g.adj.astype(int) @ M.astype(int).T
    assert (covered >= q.adj).all()


def test_ilp_tensors_valid_for_real_match():
    import jax
    from repro.core.matcher import IMMSchedMatcher
    wl = get_workload("mobilenetv2")
    cap = EDGE.engine_tile_capacity_macs()
    pd = preemptible_dag.build_preemptible_dag(
        [(0, wl, 0)], tile_capacity_macs=cap, window_stages=2)
    tgt = free_engine_graph(EDGE, [True] * EDGE.engines)
    cfg = PSOConfig(num_particles=48, epochs=4, inner_steps=10)
    res = IMMSchedMatcher(cfg).match(pd.graph, tgt,
                                     key=jax.random.PRNGKey(0))
    assert res.found
    st = ilp.build_schedule_tensors(pd, np.asarray(res.mapping), EDGE)
    errs = ilp.validate_schedule(st, pd)
    # same-stage cross-engine deps are impossible by construction (stages
    # are topological levels), so a feasible mapping must validate
    assert errs == [], errs
    assert st.X.sum() == pd.n


def test_xy_route_lengths():
    r = ilp.xy_route(EDGE, 0, EDGE.engines - 1)
    assert len(r) == (EDGE.noc_rows - 1) + (EDGE.noc_cols - 1)
    assert ilp.xy_route(EDGE, 5, 5) == []


def test_adaptive_preemption_ratio_monotone():
    lo = interrupts.adaptive_preemption_ratio(1e-3, 1.0)
    hi = interrupts.adaptive_preemption_ratio(1.0, 1.1)
    assert 0.2 <= lo < hi <= 1.0
    assert interrupts.adaptive_preemption_ratio(1.0, 0.0) == 1.0


def test_select_victims_largest_slack_first():
    running = [
        interrupts.RunningTask(0, 1, [0, 1], remaining_time=1.0,
                               deadline=10.0),   # slack 9 (pick first)
        interrupts.RunningTask(1, 1, [2, 3], remaining_time=1.0,
                               deadline=1.5),    # slack .5
        interrupts.RunningTask(2, 3, [4, 5], remaining_time=1.0,
                               deadline=99.0),   # higher priority: immune
    ]
    dec = interrupts.select_victims(running, idle_engines=[], now=0.0,
                                    engines_needed=2, urgent_priority=2)
    assert dec.victims == [0]
    dec = interrupts.select_victims(running, idle_engines=[], now=0.0,
                                    engines_needed=4, urgent_priority=2)
    assert dec.victims == [0, 1]
    assert 4 not in dec.freed_engines and 5 not in dec.freed_engines


@pytest.mark.parametrize("name", ["immsched", "isosched", "prema",
                                  "planaria", "moca", "cdmsa"])
def test_all_schedulers_complete_tasks(name):
    sc = make_scenario("simple", rate_hz=25, horizon=0.3, seed=3)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, get_scheduler(name)).run(sc)
    assert r.finished == r.total, f"{name} dropped tasks"
    assert r.total_energy > 0 and r.avg_total_latency > 0


def test_immsched_beats_baselines_on_latency():
    sc = make_scenario("middle", rate_hz=30, horizon=0.4, seed=5)
    res = run_all(sc, EDGE, ["immsched", "isosched", "prema", "planaria"])
    sp = speedup_table(res)
    assert all(v > 1.0 for v in sp.values()), sp
    # LTS baselines must be worse than the TSS baseline
    assert sp["prema"] > sp["isosched"]
    assert sp["planaria"] > sp["isosched"]


def test_immsched_real_matcher_mode_runs():
    """End-to-end: actual PSO-Ullmann matching inside the simulator."""
    wls = [get_workload("mobilenetv2"), get_workload("mobilenetv2"),
           get_workload("resnet50")]
    sc = fixed_scenario(wls)
    cfg = SimConfig(platform=EDGE, matcher_mode="real",
                    pso_cfg=PSOConfig(num_particles=32, epochs=2,
                                      inner_steps=6),
                    window_stages=2)
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    assert r.finished == r.total
    assert r.urgent_met == r.urgent_total


def test_make_scenario_burst_defaults_byte_identical():
    """The burst knobs at their defaults must not perturb the RNG stream:
    legacy scenarios stay byte-identical."""
    a = make_scenario("simple", rate_hz=25, horizon=0.3, seed=3)
    b = make_scenario("simple", rate_hz=25, horizon=0.3, seed=3,
                      burst_size=1, burst_frac=0.0)
    assert a.name == b.name and len(a.tasks) == len(b.tasks)
    for x, y in zip(a.tasks, b.tasks):
        assert (x.name, x.arrival, x.priority, x.deadline, x.urgent) == \
               (y.name, y.arrival, y.priority, y.deadline, y.urgent)


def test_make_burst_scenario_simultaneous_arrivals():
    sc = make_burst_scenario("simple", rate_hz=40, horizon=0.3,
                             burst_size=4, burst_frac=0.6, seed=7)
    assert sc.name == "simple-burst4"
    from collections import Counter
    counts = Counter(t.arrival for t in sc.tasks)
    assert max(counts.values()) == 4        # full bursts share one instant
    assert min(counts.values()) == 1        # singleton events survive


def test_burst_delivered_as_one_arrival_event():
    """The simulator must coalesce simultaneous arrivals into ONE
    on_event call carrying the whole burst."""
    sc = make_burst_scenario("simple", rate_hz=40, horizon=0.3,
                             burst_size=4, burst_frac=0.6, seed=7)
    burst_sizes = []

    class Spy:
        def __init__(self, inner):
            self.inner = inner

        def __getattr__(self, k):
            return getattr(self.inner, k)

        def on_event(self, sim, now, tasks, trigger, arrived=None):
            if trigger == "arrival":
                burst_sizes.append(len(arrived))
            return self.inner.on_event(sim, now, tasks, trigger,
                                       arrived=arrived)

    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, Spy(get_scheduler("immsched"))).run(sc)
    assert r.finished == r.total
    assert sum(burst_sizes) == r.total       # every task delivered once
    assert max(burst_sizes) == 4             # the burst came in one event


@pytest.mark.slow
def test_immsched_real_mode_coalesces_burst_matches():
    """Real-matcher mode on an urgent burst: the whole burst's matchings
    go through the service as coalesced batch launches."""
    sc = make_burst_scenario("simple", rate_hz=30, horizon=0.25,
                             burst_size=3, burst_frac=0.8,
                             urgent_frac=0.7, seed=5)
    cfg = SimConfig(platform=EDGE, matcher_mode="real",
                    pso_cfg=PSOConfig(num_particles=32, epochs=2,
                                      inner_steps=6),
                    window_stages=2)
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    assert r.finished == r.total
    assert r.urgent_met == r.urgent_total
    assert r.matcher_stats["coalesced_requests"] > 0
    assert r.matcher_stats["batch_occupancy"] > 0.5


def test_urgent_preemption_happens_under_load():
    """With the array saturated, an urgent arrival must still meet its
    deadline under IMMSched (interruptibility)."""
    wls = [get_workload("unet")] * 3 + [get_workload("mobilenetv2")]
    sc = fixed_scenario(wls, urgent_last=True)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
    r = Simulator(cfg, get_scheduler("immsched")).run(sc)
    assert r.urgent_met == r.urgent_total == 1
