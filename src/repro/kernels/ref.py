"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel's test sweeps
shapes/dtypes and asserts allclose against the function here. They are also
the default CPU execution path (jit'd XLA) used by the core library, since
Pallas interpret mode is only for validation.

All functions take a single particle's matrices; batch with ``jax.vmap``.
Shapes: Q (n, n), G (m, m), Mask/S/V/M (n, m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-9


# ---------------------------------------------------------------------------
# 1. Edge-preserving fitness:  residual = || Q - S G S^T ||_F^2   (paper §3.3)
# ---------------------------------------------------------------------------

def edge_fitness(S: jax.Array, Q: jax.Array, G: jax.Array) -> jax.Array:
    """Float path. Returns the *fitness* f = -residual (higher is better)."""
    S = S.astype(jnp.float32)
    Qf = Q.astype(jnp.float32)
    Gf = G.astype(jnp.float32)
    SG = S @ Gf                      # (n, m)
    SGS = SG @ S.T                   # (n, n)
    resid = Qf - SGS
    return -jnp.sum(resid * resid)


def edge_fitness_quantized(S_q: jax.Array, Q: jax.Array, G: jax.Array,
                           scale: int = 255) -> jax.Array:
    """Fixed-point path (paper §3.4): S quantized to uint8 (S ≈ S_q/scale),
    binary Q/G in {0,1}; all MACs accumulate in int32, exactly as on the
    accelerator's int8 datapath. Residual is returned in *integer* units of
    (1/scale²); fitness = -residual so PSO ordering matches the float path.

    Note overflow headroom: entries of S_q G ≤ 255·m and of S_q G S_qᵀ ≤
    255²·m ≈ 6.5e4·m, so int32 accumulation is exact for m ≤ 32768 — far
    beyond any engine array. The final squared-residual reduction happens in
    f32 (the role of the hardware's wide accumulator tree) since the squares
    exceed int32 range.
    """
    S_i = S_q.astype(jnp.int32)
    Q_i = Q.astype(jnp.int32)
    G_i = G.astype(jnp.int32)
    SG = S_i @ G_i                   # int32 (n, m)
    SGS = SG @ S_i.T                 # int32 (n, n), units of 1/scale^2
    resid = (Q_i * (scale * scale) - SGS).astype(jnp.float32)
    return -jnp.sum(resid * resid)


# ---------------------------------------------------------------------------
# 2. Ullmann refinement sweep (paper §3.3: feasibility via matrix products)
# ---------------------------------------------------------------------------

def ullmann_refine_step(M: jax.Array, Q: jax.Array, G: jax.Array) -> jax.Array:
    """One vectorized Ullmann refinement sweep for directed monomorphism.

    Keep candidate (i, j) iff
      out: ∀u with Q[i,u]=1  ∃v: M[u,v]=1 ∧ G[j,v]=1   (image has the out-edge)
      in:  ∀u with Q[u,i]=1  ∃v: M[u,v]=1 ∧ G[v,j]=1   (image has the in-edge)

    Expressed entirely as int32-accumulated matmuls + comparisons — the form
    the paper maps onto the MAC array.
    """
    Mi = M.astype(jnp.int32)
    Qi = Q.astype(jnp.int32)
    Gi = G.astype(jnp.int32)
    # support_out[u, j] = #candidates v of u with edge j->v in G
    support_out = Mi @ Gi.T                      # (n, m)
    # support_in[u, j]  = #candidates v of u with edge v->j in G
    support_in = Mi @ Gi                         # (n, m)
    miss_out = (support_out == 0).astype(jnp.int32)
    miss_in = (support_in == 0).astype(jnp.int32)
    # violations[i, j] = #neighbours u of i whose support at j is empty
    viol = Qi @ miss_out + Qi.T @ miss_in        # (n, m)
    return (M.astype(jnp.int32) * (viol == 0)).astype(M.dtype)


def _fixpoint(step, M: jax.Array, max_iters: int = 0) -> jax.Array:
    """Iterate ``step`` to a fixpoint (``max_iters=0``: while_loop until
    nothing changes — each productive iteration removes ≥ 1 candidate, so
    termination is bounded by the candidate count; > 0: fixed fori_loop)."""
    if max_iters and max_iters > 0:
        return jax.lax.fori_loop(0, max_iters, lambda _, m: step(m), M)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        m, _ = state
        m2 = step(m)
        return m2, jnp.any(m2 != m)

    out, _ = jax.lax.while_loop(cond, body, (M, jnp.bool_(True)))
    return out


def ullmann_refine_fixpoint(M: jax.Array, Q: jax.Array, G: jax.Array,
                            max_iters: int = 0) -> jax.Array:
    """Iterate the sweep to fixpoint (bounded by n·m sweeps, far fewer in
    practice; ``max_iters=0`` means until convergence with a while_loop)."""
    return _fixpoint(lambda m: ullmann_refine_step(m, Q, G), M, max_iters)


def injectivity_prune(M: jax.Array) -> jax.Array:
    """All-different propagation on a candidate matrix.

    If a query row has exactly one surviving candidate column, no other row
    may use that column (mappings are injective). One application of the
    rule; iterate together with ``ullmann_refine_step`` to a fixpoint.
    Expressed as row/column reductions + elementwise ops only, so it lowers
    onto the same comparator/MAC datapath as the refinement sweep.
    """
    Mi = M.astype(jnp.int32)
    singleton_rows = (Mi.sum(axis=1, keepdims=True) == 1).astype(jnp.int32)
    claimed = (singleton_rows * Mi).sum(axis=0, keepdims=True)   # (1, m)
    keep = 1 - (claimed > 0).astype(jnp.int32) * (1 - singleton_rows * Mi)
    return (Mi * jnp.clip(keep, 0, 1)).astype(M.dtype)


def prune_mask_fixpoint(mask: jax.Array, Q: jax.Array, G: jax.Array,
                        max_iters: int = 0) -> jax.Array:
    """Shrink the global compatibility mask before any swarm runs.

    Alternates one Ullmann refinement sweep (1-hop arc consistency) with
    one injectivity-propagation step until nothing changes. This is the
    Ullmann half of the algorithm applied *globally* — on planted
    instances it often collapses most rows to singletons, turning the PSO
    into a local repair of the few remaining free rows. Empty rows simply
    make every particle infeasible, which is the correct answer.
    """
    return _fixpoint(
        lambda m: injectivity_prune(ullmann_refine_step(m, Q, G)),
        mask, max_iters)


def prune_fixpoint_count(mask: jax.Array, Q: jax.Array, G: jax.Array,
                         max_iters: int = 0):
    """``prune_mask_fixpoint`` with an explicit convergence counter.

    Semantic twin of the fused Pallas ``prune_fixpoint`` kernel: one fused
    iteration = one Ullmann refinement sweep followed by one injectivity-
    propagation step, iterated while anything changes and the sweep budget
    holds (``max_iters=0``: until convergence, bounded by the candidate
    count — each productive iteration removes ≥ 1 candidate). The pruned
    mask is identical to ``prune_mask_fixpoint``'s (a converged mask is a
    fixpoint of the step, so stopping early never changes the result).

    Returns ``(pruned_mask, sweeps)`` with ``sweeps`` the int32 number of
    fused iterations executed (including the final no-change one) — the
    prune-latency observable the scheduler's cost accounting consumes.
    """
    n, m = mask.shape
    bound = max_iters if max_iters and max_iters > 0 else n * m + 1

    def step(mk):
        return injectivity_prune(ullmann_refine_step(mk, Q, G))

    def cond(state):
        _, changed, it = state
        return changed & (it < bound)

    def body(state):
        mk, _, it = state
        mk2 = step(mk)
        return mk2, jnp.any(mk2 != mk), it + jnp.int32(1)

    out, _, sweeps = jax.lax.while_loop(
        cond, body, (mask, jnp.bool_(True), jnp.int32(0)))
    return out, sweeps


def is_feasible(M: jax.Array, Q: jax.Array, G: jax.Array) -> jax.Array:
    """Feasibility: M is a (partial-)injective 0/1 assignment matrix with one
    candidate per row, and M G Mᵀ covers Q (paper: "checking whether M̂ G M̂ᵀ
    contains the query graph Q")."""
    Mi = M.astype(jnp.int32)
    rows_ok = jnp.all(Mi.sum(axis=1) == 1)
    cols_ok = jnp.all(Mi.sum(axis=0) <= 1)
    mapped = Mi @ G.astype(jnp.int32) @ Mi.T
    covers = jnp.all(mapped >= Q.astype(jnp.int32))
    return rows_ok & cols_ok & covers


# ---------------------------------------------------------------------------
# 3. Fused PSO update (velocity + position + mask + row-normalize)
# ---------------------------------------------------------------------------

def pso_update(S: jax.Array, V: jax.Array, S_local: jax.Array,
               S_star: jax.Array, S_bar: jax.Array, mask: jax.Array,
               r: jax.Array, omega: float, c1: float, c2: float, c3: float,
               v_max: float = 1.0):
    """One PSO step for one particle (paper Algorithm 1 lines 8-11).

    r: (3,) uniform randoms for the cognitive/social/consensus terms.
    Returns (S_new, V_new); S_new is masked, non-negative, row-stochastic.
    """
    S = S.astype(jnp.float32)
    V = V.astype(jnp.float32)
    maskf = mask.astype(jnp.float32)
    V_new = (omega * V
             + c1 * r[0] * (S_local.astype(jnp.float32) - S)
             + c2 * r[1] * (S_star.astype(jnp.float32) - S)
             + c3 * r[2] * (S_bar.astype(jnp.float32) - S))
    V_new = jnp.clip(V_new, -v_max, v_max)
    S_new = jnp.clip(S + V_new, 0.0, None) * maskf
    row_sum = S_new.sum(axis=1, keepdims=True)
    # Rows whose mask is empty (or collapsed to zero) fall back to uniform
    # over the mask — mirrors the hardware's reciprocal-multiply normalizer
    # with a "row invalid" escape.
    mask_rows = maskf.sum(axis=1, keepdims=True)
    uniform = maskf / jnp.maximum(mask_rows, 1.0)
    S_new = jnp.where(row_sum > EPS, S_new / jnp.maximum(row_sum, EPS), uniform)
    return S_new, V_new


# ---------------------------------------------------------------------------
# 4. Masked argmax with index (the redesigned comparator accumulator tree)
# ---------------------------------------------------------------------------

def masked_argmax(X: jax.Array, mask: jax.Array):
    """Global argmax of X over entries where mask != 0.

    Returns (value, flat_index) with flat_index = i*m + j, matching the
    paper's tree accumulator that "outputs the index corresponding to the
    maximum value within a vector". If the mask is empty, value = -inf and
    index = 0.
    """
    neg = jnp.finfo(jnp.float32).min
    flat = jnp.where(mask.reshape(-1) != 0, X.reshape(-1).astype(jnp.float32),
                     neg)
    idx = jnp.argmax(flat)
    return flat[idx], idx.astype(jnp.int32)


def greedy_project(S: jax.Array, mask: jax.Array) -> jax.Array:
    """Project a relaxed S onto a discrete injective assignment M̂.

    Greedy global-argmax: repeatedly take the highest-probability feasible
    (tile, PE) pair, then knock out its row and column. n sequential steps of
    the masked-argmax primitive — exactly what the comparator-tree hardware
    executes. Returns a 0/1 (n, m) matrix; rows with no feasible PE stay 0
    (later failing the feasibility check, as they must).
    """
    n, m = S.shape
    Sf = S.astype(jnp.float32)

    def body(_, state):
        avail, out = state
        val, idx = masked_argmax(Sf, avail)
        i, j = idx // m, idx % m
        take = val > jnp.finfo(jnp.float32).min
        row_kill = jnp.where(jnp.arange(n) == i, 0, 1).astype(avail.dtype)
        col_kill = jnp.where(jnp.arange(m) == j, 0, 1).astype(avail.dtype)
        new_avail = avail * row_kill[:, None] * col_kill[None, :]
        new_out = out.at[i, j].set(jnp.where(take, 1, 0).astype(out.dtype))
        return (jnp.where(take, new_avail, avail),
                jnp.where(take, new_out, out))

    avail0 = (mask != 0).astype(jnp.uint8)
    out0 = jnp.zeros((n, m), dtype=jnp.uint8)
    _, out = jax.lax.fori_loop(0, n, body, (avail0, out0))
    return out


def structured_project(S: jax.Array, Q: jax.Array, G: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Adjacency-guided projection: embed the query DAG vertex-by-vertex in
    topological order (the preemptible-DAG builder emits tiles pre-sorted),
    assigning tile i to the highest-S target vertex that is (a) unused,
    (b) mask-compatible, and (c) adjacent in G to the images of ALL of i's
    already-placed predecessors.

    This is the Ullmann-guidance step done constructively: on sparse
    targets (engine meshes, degree ≤ 4) a structure-blind argmax projection
    almost never lands on a consistent sub-DAG, while this one inherits
    feasibility by construction (only the later *out*-edges still need the
    final verification). Rows with no consistent candidate stay zero (the
    feasibility check rejects them).
    """
    n, m = S.shape
    Sf = S.astype(jnp.float32)
    Qi = Q.astype(jnp.int32)
    Gi = G.astype(jnp.int32)
    neg = jnp.finfo(jnp.float32).min
    succ_need = Qi.sum(axis=1)                        # (n,) out-degree

    def body(i, state):
        avail, col_avail, out, img_rows = state
        # img_rows[p] = G[assign[p]] for assigned p (else zeros)
        preds = Qi[:, i]                              # (n,)
        need = preds.sum()
        support = preds @ img_rows                    # (m,) adj-pred count
        # forward checking: candidate j must keep enough *free*
        # out-neighbours for i's (all still unplaced) successors
        free_out = Gi @ col_avail                     # (m,)
        feas = ((avail[i] > 0) & (support >= need)
                & (free_out >= succ_need[i]))
        scores = jnp.where(feas, Sf[i], neg)
        j = jnp.argmax(scores)
        ok = scores[j] > neg
        col_kill = (jnp.arange(m) != j) | (~ok)
        new_avail = avail * col_kill[None, :].astype(avail.dtype)
        new_col = col_avail * col_kill.astype(col_avail.dtype)
        new_out = out.at[i, j].set(jnp.where(ok, 1, 0).astype(out.dtype))
        new_img = img_rows.at[i].set(
            jnp.where(ok, Gi[j], jnp.zeros((m,), jnp.int32)))
        return new_avail, new_col, new_out, new_img

    avail0 = (mask != 0).astype(jnp.uint8)
    col0 = jnp.ones((m,), jnp.int32)
    out0 = jnp.zeros((n, m), jnp.uint8)
    img0 = jnp.zeros((n, m), jnp.int32)
    _, _, out, _ = jax.lax.fori_loop(0, n, body,
                                     (avail0, col0, out0, img0))
    return out


# ---------------------------------------------------------------------------
# Quantization helpers (paper §3.4)
# ---------------------------------------------------------------------------

def quantize_s(S: jax.Array, scale: int = 255) -> jax.Array:
    """Uniform uint8 quantization of a row-stochastic S."""
    return jnp.clip(jnp.round(S.astype(jnp.float32) * scale), 0, 255
                    ).astype(jnp.uint8)


def dequantize_s(S_q: jax.Array, scale: int = 255) -> jax.Array:
    return S_q.astype(jnp.float32) / scale


def row_normalize_quantized(S_q: jax.Array, mask: jax.Array,
                            scale: int = 255) -> jax.Array:
    """Hardware-style row renormalization: divide-free.

    The accelerator replaces dividers with "multiplication by a
    reconfigurable reciprocal value" — we model a 16-bit fixed-point
    reciprocal (Q1.15) of each int32 row sum, then a fused
    multiply-round-shift back to uint8.
    """
    row = S_q.astype(jnp.int32).sum(axis=1, keepdims=True)      # int32
    rowf = jnp.maximum(row, 1)
    recip_q15 = jnp.round((1 << 15) / rowf).astype(jnp.int32)   # Q1.15 table
    prod = S_q.astype(jnp.int32) * recip_q15 * scale            # Q1.15 units
    out = (prod + (1 << 14)) >> 15                              # round
    out = jnp.clip(out, 0, 255).astype(jnp.uint8)
    maskq = (mask != 0)
    # empty rows -> uniform over mask (same escape as the float path)
    mask_rows = maskq.sum(axis=1, keepdims=True)
    uniform = jnp.where(
        maskq, jnp.clip(scale // jnp.maximum(mask_rows, 1), 1, 255), 0
    ).astype(jnp.uint8)
    return jnp.where(row > 0, out * maskq, uniform)
