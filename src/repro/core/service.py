"""Online matcher service: a tiered revalidate → rebase → swarm pipeline.

``pso.match`` alone is a batch API: every new (n, m) query/target shape
triggers an XLA recompile (seconds) and every call restarts the swarm from
the cold uniform prior — the opposite of what an *online* scheduler needs
when tasks arrive unpredictably at microsecond granularity. The
``MatcherService`` turns it into a service:

  * **Shape classes** — query/target problems are bucketed to padded
    ``(n_pad, m_pad)`` classes via ``preemptible_dag.pad_problem`` (dummy
    tiles pinned to dummy PEs, semantics preserved), so repeat arrivals of
    any size within a bucket reuse one compiled executable.
  * **Bounded compile LRU** — one jit wrapper per (bucket, config), held in
    an LRU of ``cache_capacity`` entries; evicting an entry drops its
    executable. Repeat arrivals never recompile.
  * **Warm starts** — the final global-controller state ``(S*, f*, S̄)`` of
    each call is remembered in a two-level :class:`CarryStore`: an *exact*
    content-keyed LRU plus a *similarity* index keyed by
    (query digest, bucket, free-engine signature) for platform-state
    drift.
  * **Early exit** — the service enables ``cfg.early_exit`` so easy
    matches stop scanning epochs once a feasible mapping clears the
    fitness bound (1 epoch instead of T on planted instances).

**The tiered decision pipeline.** ``drain`` flushes every same-bucket
request through three stages, so a mixed easy/hard burst costs one cheap
revalidation launch plus a swarm sized to the hard subset — strictly no
worse than sequential, and far better than the uniform batch that pays
max-epochs × B whenever one hard problem rides in a burst of easy ones:

  * **Tier 0 — batched revalidation.** All requests with a stored exact
    carry are re-validated in ONE ``pso.revalidate_batch`` launch: one
    structured projection + feasibility check per problem, no epochs.
    Hits are served immediately at revalidation cost.
  * **Tier 1 — similarity rebase.** Tier-0 misses (and cold requests)
    whose workload matches a *similar* platform state — same query
    digest, nearest free-engine set by bitmask overlap — are re-run
    through the same revalidation kernel with the neighbour's carry,
    which ``pso.rebase_carry`` projects onto the new compatibility mask.
    A hit stores the rebased carry under this problem's exact key (next
    arrival is a Tier-0 hit); the verified mapping is feasibility-checked
    against the actual problem, so a rebased carry can never yield an
    infeasible mapping marked found.
  * **Tier 2 — swarm.** Only the residual misses launch the full batched
    swarm (``pso.match_batch``), warm-seeded with their failed exact
    carry or the rebased neighbour consensus (f* reset to -inf: fitness
    is not transferable across platform states, direction is).

Batch launches are padded to a small set of classes (``batch_classes``)
that joins the compile-cache key; pad slots are filled with a *trivial
pre-finished problem* whose carry validates in epoch 0, so padding never
re-burns a real problem's epoch budget (its only cost is the slot width).

Per-tier statistics (launches / problems checked / hits / wall time) are
exported via ``stats`` / ``stats_dict()`` and surfaced by
``sched.metrics`` through ``SimResult.matcher_stats``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import time
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.accel.target_graph import signature_bits
from repro.checkpoint.manager import CheckpointManager
from repro.core import persist, pso
from repro.core.graphs import (Graph, compatibility_mask,
                               topological_relabel)
from repro.core.matcher import (MatchResult, build_distributed_match,
                                build_distributed_match_batch,
                                build_distributed_revalidate_batch,
                                collect_batch_results, collect_result)
from repro.core.preemptible_dag import pad_problem
from repro.kernels import backend as kernel_backend
from repro.kernels import pallas_compat


# process-global latch: the export-drops-donation degradation warning
# fires at most once however many services a process builds
_DONATION_EXPORT_WARNED: List[bool] = []


def _round_up(v: int, mult: int) -> int:
    mult = max(mult, 1)
    return ((v + mult - 1) // mult) * mult


def shape_bucket(n: int, m: int, n_multiple: int = 8,
                 m_multiple: int = 16) -> Tuple[int, int]:
    """Stable padded shape class for an (n, m) matching problem.

    The target bucket must leave room for the ``n_pad - n`` dummy PEs that
    ``pad_problem`` pins the dummy query tiles to.
    """
    n_pad = _round_up(max(n, 1), n_multiple)
    m_pad = _round_up(max(m, 1) + (n_pad - n), m_multiple)
    return n_pad, m_pad


@dataclasses.dataclass
class TierStats:
    """Counters for one pipeline stage."""
    launches: int = 0                # jit dispatches this tier issued
    checked: int = 0                 # real problems examined
    hits: int = 0                    # requests served by this tier
    wall_s: float = 0.0              # wall time spent in this tier

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.checked, 1)


@dataclasses.dataclass
class ServiceStats:
    """Cumulative counters for one ``MatcherService`` incarnation.

    Counters cover the compile LRU, warm-start stores, per-tier pipeline
    activity, the fused pre-prune observable the scheduler calibrates
    against, and the warm-restart persistence layer (``jit_traces`` /
    ``aot_*`` / ``snapshot_*`` / ``restored_*``). Exported flat — plus
    derived rates — by ``MatcherService.stats_dict()``; counters reset
    with the process (a restart starts a fresh incarnation, which is
    exactly what the restart benchmarks measure)."""
    calls: int = 0
    compile_cache_hits: int = 0      # bucket already had an executable
    compile_cache_misses: int = 0    # new bucket → jit compile
    compile_evictions: int = 0
    warm_hits: int = 0               # exact carry found for the call
    warm_misses: int = 0
    warm_evictions: int = 0
    epochs_run: int = 0              # total epochs actually executed
    epochs_budgeted: int = 0         # cfg.epochs × calls
    epoch_fused_launches: int = 0    # swarm dispatches whose epochs ran
                                     # through the fused epoch kernel
                                     # (KernelBackend.epoch_fused_batch)
    epoch_finish_launches: int = 0   # swarm dispatches whose epoch
                                     # epilogue ran through the fused
                                     # tail (KernelBackend.epoch_finish)
    epoch_finish_problems: int = 0   # problems those epilogues covered
                                     # (batch dispatches count B each)
    found: int = 0
    batch_launches: int = 0          # swarm (Tier-2) batch executions
    coalesced_requests: int = 0      # requests served in a shared launch
    batch_problems: int = 0          # real problems through the swarm path
    batch_slots: int = 0             # padded swarm batch slots launched
    carry_fastpath_hits: int = 0     # requests served by revalidation only
                                     # (0 epochs: Tier 0, Tier 1, or the
                                     # in-kernel fast path)
    pad_slots_frozen: int = 0        # pad slots pre-finished from epoch 0
    prune_problems: int = 0          # real problems that ran the pre-prune
    prune_sweeps: int = 0            # total fused prune iterations executed
    sim_lookups: int = 0             # similarity-store nearest() queries
    sim_neighbor_hits: int = 0       # queries that found a neighbour carry
    sim_evictions: int = 0
    # -- warm-restart persistence (AOT executable cache + snapshots) ----
    jit_traces: int = 0              # Python-level jit traces this process
                                     # actually ran (the cold-start cost a
                                     # warm restart must NOT pay: a
                                     # restored burst asserts == 0)
    aot_cache_hits: int = 0          # executables deserialized from disk
    aot_cache_misses: int = 0        # persistence on, but no blob on disk
    aot_exports: int = 0             # executables serialized to disk
    aot_export_failures: int = 0     # export unsupported → plain jit
    aot_call_fallbacks: int = 0      # deserialized blob rejected the call
                                     # signature → live re-trace
    snapshot_saves: int = 0
    snapshot_restores: int = 0       # successful state restores
    snapshot_stale_skipped: int = 0  # version/digest drift → ignored
    snapshot_skipped_keys: int = 0   # entries with unencodable keys
    restored_carries: int = 0        # exact carries loaded by restore
    restored_sim_entries: int = 0    # similarity entries loaded by restore
    # -- async front end (AsyncServiceFrontEnd) ------------------------
    fe_submitted: int = 0            # requests offered to the front end
    fe_admitted: int = 0             # requests accepted into the queue
    fe_shed: int = 0                 # rejected by admission control
    fe_forced_drains: int = 0        # block-policy drains to make room
    fe_drains: int = 0               # total front-end drain rounds
    fe_drain_deadline: int = 0       # rounds fired by slack crossing
    fe_drain_batch_full: int = 0     # rounds fired by a full batch class
    fe_drain_flush: int = 0          # rounds fired by explicit flush
    fe_queue_peak: int = 0           # max observed queue depth
    fe_wait_s: float = 0.0           # total queue-wait time (admit→drain)
    # -- host-sync census (device-resident drain pipeline) --------------
    drains: int = 0                  # drain rounds that flushed requests
    host_syncs: int = 0              # blocking device→host fetches
                                     # (one per pipeline stage under the
                                     # pipelined drain; one per launch
                                     # under the serial arm)
    host_bytes_transferred: int = 0  # payload bytes those fetches moved
    host_sync_wall_s: float = 0.0    # wall time spent blocked in fetches
    donated_launches: int = 0        # launches that donated their carry
                                     # input buffers to XLA
    tier0: TierStats = dataclasses.field(default_factory=TierStats)
    tier1: TierStats = dataclasses.field(default_factory=TierStats)
    tier2: TierStats = dataclasses.field(default_factory=TierStats)

    @property
    def epochs_saved(self) -> int:
        """Budgeted minus executed epochs (early exit + fast paths)."""
        return self.epochs_budgeted - self.epochs_run

    @property
    def compile_hit_rate(self) -> float:
        """Fraction of calls served by an already-built executable."""
        return self.compile_cache_hits / max(self.calls, 1)

    @property
    def warm_hit_rate(self) -> float:
        """Fraction of calls that found an exact stored carry."""
        return self.warm_hits / max(self.calls, 1)

    @property
    def revalidated_rate(self) -> float:
        """Fraction of calls served without any swarm epoch (all tiers)."""
        return self.carry_fastpath_hits / max(self.calls, 1)

    @property
    def avg_prune_sweeps(self) -> float:
        """Mean fused pre-prune iterations per pruned problem — the
        prune-latency observable the scheduler's analytic cost model is
        calibrated against."""
        return self.prune_sweeps / max(self.prune_problems, 1)

    @property
    def batch_occupancy(self) -> float:
        """Real problems per launched swarm slot (1.0 = no padding waste).

        Vacuously 1.0 when the pipeline served everything without a
        swarm launch — zero launches waste zero pad slots."""
        if self.batch_slots == 0:
            return 1.0
        return self.batch_problems / self.batch_slots

    @property
    def host_syncs_per_drain(self) -> float:
        """Blocking device→host fetches per drain round — the pipelined
        drain's budget is ONE for an all-warm burst (one batched fetch
        for every Tier-0 launch of every bucket group) and at most one
        per engaged tier otherwise. Counts single ``match`` calls too,
        so read it on drain-only traffic for the regression gate."""
        return self.host_syncs / max(self.drains, 1)


@dataclasses.dataclass
class ServiceMatchResult(MatchResult):
    bucket: Tuple[int, int] = (0, 0)
    compile_cache_hit: bool = False
    warm_hit: bool = False
    latency_s: float = 0.0           # wall time of the launches that
                                     # served this request
    batch_size: int = 1              # real problems in the serving launch
    coalesced: bool = False          # served together with other requests
    tier: int = 2                    # pipeline stage that served it:
                                     # 0 revalidate, 1 rebase, 2 swarm


@dataclasses.dataclass
class _PendingRequest:
    """A submitted problem, pre-padded to its shape bucket so ``drain``
    can group by bucket without touching the graphs again."""
    key: jax.Array
    workload_key: object
    order: np.ndarray
    crop: Tuple[int, int]
    bucket: Tuple[int, int]
    Qp: np.ndarray
    Gp: np.ndarray
    maskp: np.ndarray
    engine_sig: Optional[bytes] = None   # free-engine bitmask (Tier-1 key)
    qdigest: str = ""                    # query-content digest (Tier-1 key)
    cdigest: str = ""                    # full-content digest (Tier-0 key)


@dataclasses.dataclass(eq=False)
class _PipelineItem:
    """One request flowing through the tiers of a bucket-group pipeline."""
    req: _PendingRequest
    ticket: int
    warm_key: Tuple
    carry: Optional[tuple]           # exact stored carry (Tier-0 input)
    warm_hit: bool
    seed: Optional[tuple] = None     # rebased neighbour carry (Tier-2 seed)
    t0: float = 0.0                  # pipeline intake timestamp
    latency_s: float = 0.0           # intake → end of the serving launch
    result: Optional[ServiceMatchResult] = None


@dataclasses.dataclass(eq=False)
class _LaunchRecord:
    """One dispatched-but-not-fetched launch of the drain pipeline.

    The pipelined drain splits every tier launch into a *dispatch* half
    (build inputs, enqueue the jit call — JAX returns immediately with
    futures) and an *apply* half (consume the fetched host outputs).
    Records carry everything the apply half needs, so all launches of a
    stage can dispatch back-to-back and resolve through ONE batched
    blocking ``device_get``."""
    kind: str                        # "reval" | "swarm"
    bucket: Tuple[int, int]
    items: List[_PipelineItem]
    tier: int
    B: int                           # real problems in the launch
    bclass: int                      # padded batch class dispatched
    compile_hit: bool
    outs: dict                       # device-side output pytree (futures)
    carries: Optional[List] = None   # reval: per-item input carries
    padded: Optional[List] = None    # swarm: padded request list
    miss_sink: Optional[List] = None # reval: where misses are appended
    t0: float = 0.0                  # dispatch timestamp


class CarryStore:
    """Two-level warm-start store for the tiered pipeline.

    * **exact** — LRU of full content keys (workload key + shapes + a
      digest of Qp/Gp/maskp): a hit means *this exact problem* was solved
      before; its carry feeds Tier 0.
    * **similarity** — LRU keyed by ``(query digest, bucket, engine
      signature)``: entries describe *which platform state* a carry was
      produced on. ``nearest`` returns the stored carry whose free-engine
      bitmask overlaps the query's the most (ties go to the most recently
      stored), feeding Tier 1 rebases under fragmentation drift.

    ``nearest`` probes a **popcount-bucketed index**: entries of one
    (query digest, bucket) group are binned by the popcount of their
    free-engine bitmask, and bins are visited in decreasing order of the
    best overlap they could possibly hold (``min(pop, query_pop)``),
    stopping as soon as the bound cannot beat the best hit found — at
    thousands of stored platform states the probe touches a handful of
    bins instead of scanning the store. The exhaustive linear scan is
    kept as ``_nearest_linear`` (``sim_index=False`` fallback, and the
    oracle the index is property-tested against).

    Popcounts are computed ONCE on host numpy when an entry is ingested
    (``_sim_pop``) — ``put``/``nearest`` never reduce a bit vector per
    stored entry again, so no store operation can turn into a per-entry
    device sync however the bits arrive.

    The store is payload-agnostic (tests store plain ints), but it
    participates in device-carry lifetime management: any stored value
    exposing ``retain``/``release`` (the service's
    :class:`DeviceCarryPool` handles) is retained on insert and released
    when it is overwritten or evicted, so slab rows are reclaimed the
    moment no store references them.
    """

    def __init__(self, capacity: int, sim_capacity: int,
                 stats: ServiceStats, sim_index: bool = True):
        self.capacity = max(int(capacity), 1)
        self.sim_capacity = max(int(sim_capacity), 1)
        self.stats = stats
        self.sim_index = bool(sim_index)
        self._exact: "OrderedDict[Tuple, tuple]" = OrderedDict()
        self._sim: "OrderedDict[Tuple, Tuple[np.ndarray, tuple]]" = \
            OrderedDict()
        # recency sequence per similarity key (== iteration order of
        # ``_sim``): the index's explicit most-recent-wins tiebreaker
        self._sim_seq: Dict[Tuple, int] = {}
        self._seq = 0
        # (qdigest, bucket, bit-length) -> {popcount: OrderedDict[sig]}
        self._sim_buckets: Dict[Tuple, Dict[int, "OrderedDict[bytes, None]"]] \
            = {}
        # per-entry popcount, computed once at ingest (host numpy)
        self._sim_pop: Dict[Tuple, int] = {}

    def __len__(self) -> int:
        return len(self._exact)

    @property
    def sim_entries(self) -> int:
        """Number of entries currently in the similarity store."""
        return len(self._sim)

    @staticmethod
    def _retain(carry) -> None:
        r = getattr(carry, "retain", None)
        if callable(r):
            r()

    @staticmethod
    def _release(carry) -> None:
        r = getattr(carry, "release", None)
        if callable(r):
            r()

    def clear(self) -> None:
        """Drop both stores and the derived popcount index/recency,
        releasing every device-pool carry they referenced."""
        for c in self._exact.values():
            self._release(c)
        for _, c in self._sim.values():
            self._release(c)
        self._exact.clear()
        self._sim.clear()
        self._sim_seq.clear()
        self._sim_buckets.clear()
        self._sim_pop.clear()

    # -- exact tier --------------------------------------------------------

    def get(self, key) -> Tuple[Optional[tuple], bool]:
        """Exact-store lookup → ``(carry, hit)``; refreshes LRU recency
        and counts ``warm_hits``/``warm_misses``."""
        if key in self._exact:
            self._exact.move_to_end(key)
            self.stats.warm_hits += 1
            return self._exact[key], True
        self.stats.warm_misses += 1
        return None, False

    def put(self, key, carry) -> None:
        """Store ``carry`` (a ``(S*, f*, S̄)`` tuple of (n, m)/scalar/
        (n, m) arrays, or a device-pool handle of one) under the exact
        content key, evicting LRU entries beyond ``capacity``."""
        old = self._exact.get(key)
        if old is not None and old is not carry:
            self._release(old)
        if old is not carry:
            self._retain(carry)
        self._exact[key] = carry
        while len(self._exact) > self.capacity:
            _, evicted = self._exact.popitem(last=False)
            self._release(evicted)
            self.stats.warm_evictions += 1

    # -- similarity tier ---------------------------------------------------

    @staticmethod
    def _bits(sig: bytes) -> np.ndarray:
        return np.asarray(signature_bits(sig))

    def put_similar(self, qdigest: str, bucket: Tuple[int, int],
                    sig: bytes, carry) -> None:
        """Store ``carry`` under the similarity key (query digest, shape
        bucket, free-engine signature) and index it by signature
        popcount (computed once, at ingest); refreshes recency for
        most-recent-wins ``nearest`` tiebreaks."""
        key = (qdigest, bucket, sig)
        bits = self._bits(sig)
        prev = self._sim.get(key)
        fresh = prev is None
        if not fresh and prev[1] is not carry:
            self._release(prev[1])
        if fresh or prev[1] is not carry:
            self._retain(carry)
        self._sim[key] = (bits, carry)
        self._sim.move_to_end(key)
        self._seq += 1
        self._sim_seq[key] = self._seq
        if fresh:
            pc = int(bits.sum())
            self._sim_pop[key] = pc
            group = self._sim_buckets.setdefault(
                (qdigest, bucket, bits.shape[0]), {})
            group.setdefault(pc, OrderedDict())[sig] = None
        while len(self._sim) > self.sim_capacity:
            old_key, (old_bits, old_carry) = self._sim.popitem(last=False)
            self._drop_sim_key(old_key, old_bits)
            self._release(old_carry)
            self.stats.sim_evictions += 1

    def _drop_sim_key(self, key: Tuple, bits: np.ndarray) -> None:
        """Remove an evicted similarity entry from the popcount index
        (``bits``: the entry's already-unpacked bit vector; the entry's
        popcount comes from the ingest-time cache, not a recount)."""
        qd, bk, sig = key
        self._sim_seq.pop(key, None)
        pc = self._sim_pop.pop(key, None)
        gkey = (qd, bk, bits.shape[0])
        group = self._sim_buckets.get(gkey)
        if group is None:
            return
        if pc is None:  # pragma: no cover - pre-index entries
            pc = int(bits.sum())
        bin_ = group.get(pc)
        if bin_ is not None:
            bin_.pop(sig, None)
            if not bin_:
                del group[pc]
        if not group:
            del self._sim_buckets[gkey]

    def nearest(self, qdigest: str, bucket: Tuple[int, int], sig: bytes,
                exclude_sig: Optional[bytes] = None
                ) -> Optional[Tuple[bytes, tuple]]:
        """Stored carry of the platform state nearest to ``sig``.

        Nearest = max popcount of the AND of the free-engine bitmasks;
        ties broken toward the smaller symmetric difference, then toward
        the most recently stored entry. Returns ``(stored_sig, carry)``
        or None when no same-workload entry overlaps at all. Served from
        the popcount-bucketed index (identical results to
        ``_nearest_linear`` — property-tested) unless ``sim_index`` is
        off.
        """
        if not self.sim_index:
            return self._nearest_linear(qdigest, bucket, sig, exclude_sig)
        bits = self._bits(sig)
        qpop = int(bits.sum())
        group = self._sim_buckets.get((qdigest, bucket, bits.shape[0]))
        if not group or qpop == 0:
            return None

        def upper_bound(pc: int) -> Tuple[int, int]:
            # best (overlap, -symdiff) any popcount-pc bitmask can score
            ov = min(pc, qpop)
            return ov, -(pc + qpop - 2 * ov)

        best = None
        best_score = (0, float("-inf"), -1)     # (overlap, -symdiff, seq)
        for pc in sorted(group, key=upper_bound, reverse=True):
            ub = upper_bound(pc)
            if ub[0] <= 0 or ub < (best_score[0], best_score[1]):
                break        # bins are bound-sorted: nothing below can win
            for s in group[pc]:
                if s == exclude_sig:
                    continue
                key = (qdigest, bucket, s)
                b, carry = self._sim[key]
                overlap = int((b & bits).sum())
                if overlap <= 0:
                    continue
                score = (overlap, -int((b ^ bits).sum()),
                         self._sim_seq[key])
                if score > best_score:
                    best_score = score
                    best = (s, carry)
        return best

    # -- snapshot support --------------------------------------------------

    def export_state(self) -> Tuple[List[Tuple[Tuple, tuple]],
                                    List[Tuple[Tuple, tuple]]]:
        """Both stores as ``(exact_items, sim_items)`` key/carry lists.

        Items come out in LRU order (least recent first) so an
        ``import_state`` replay reproduces recency — evictions and
        ``nearest`` most-recent-wins tiebreaks behave identically after
        a snapshot/restore round trip. Carries are returned as stored
        (device or host arrays); the snapshot writer converts to numpy.
        """
        exact = [(k, c) for k, c in self._exact.items()]
        sim = [(k, c) for k, (_, c) in self._sim.items()]
        return exact, sim

    def import_state(self, exact_items, sim_items) -> Tuple[int, int]:
        """Replay exported items into this (fresh) store, oldest first.

        Uses the normal ``put``/``put_similar`` paths so the similarity
        popcount index and recency sequence are rebuilt from scratch —
        the snapshot never persists derived index structures, only the
        keys and carries. Returns ``(n_exact, n_sim)`` loaded. Entries
        beyond this store's capacities age out exactly as live puts
        would."""
        for k, c in exact_items:
            self.put(k, c)
        for (qdigest, bucket, sig), c in sim_items:
            self.put_similar(qdigest, bucket, sig, c)
        return len(exact_items), len(sim_items)

    def _nearest_linear(self, qdigest: str, bucket: Tuple[int, int],
                        sig: bytes, exclude_sig: Optional[bytes] = None
                        ) -> Optional[Tuple[bytes, tuple]]:
        """Exhaustive-scan fallback (and the index's test oracle)."""
        bits = self._bits(sig)
        best = None
        best_score = (0, float("-inf"))
        for (qd, bk, s), (b, carry) in self._sim.items():
            if qd != qdigest or bk != bucket or s == exclude_sig:
                continue
            if b.shape != bits.shape:
                continue
            overlap = int((b & bits).sum())
            if overlap <= 0:
                continue
            score = (overlap, -int((b ^ bits).sum()))
            if score >= best_score:     # >=: most recent wins ties
                best_score = score
                best = (s, carry)
        return best


@functools.lru_cache(maxsize=64)
def _pool_writer(cap: int, n: int, m: int):
    """Jitted donated row write for one slab shape: all three carry
    parts land in their slabs in-place (``donate_argnums`` lets XLA
    alias the outputs onto the input buffers, so a put never doubles
    the slab's footprint). One trace per (capacity, n, m)."""
    def write(Sb, fb, Cb, s, f, c, row):
        Sb = jax.lax.dynamic_update_index_in_dim(Sb, s, row, 0)
        fb = jax.lax.dynamic_update_index_in_dim(fb, f, row, 0)
        Cb = jax.lax.dynamic_update_index_in_dim(Cb, c, row, 0)
        return Sb, fb, Cb

    return jax.jit(write, donate_argnums=(0, 1, 2))


class _CarryHandle:
    """Refcounted reference to one slab row of a :class:`DeviceCarryPool`.

    Stored in :class:`CarryStore` in place of a raw carry tuple: each
    store that holds the handle ``retain``\\ s it, and the row is
    returned to the pool's free list when the last reference is
    ``release``\\ d (eviction, overwrite, or ``clear``). ``materialize``
    yields the ``(S*, f*, S̄)`` view lazily — device slices, no host
    sync."""

    __slots__ = ("pool", "shape", "row", "refs")

    def __init__(self, pool: "DeviceCarryPool", shape: Tuple[int, int],
                 row: int):
        self.pool = pool
        self.shape = shape
        self.row = row
        self.refs = 0

    def retain(self) -> None:
        """Count one more store holding this row."""
        self.refs += 1

    def release(self) -> None:
        """Drop one reference; frees the slab row at zero."""
        self.refs -= 1
        if self.refs <= 0 and self.row >= 0:
            self.pool._free(self.shape, self.row)
            self.row = -1

    def materialize(self) -> tuple:
        """The stored ``(S*, f*, S̄)`` as lazy device slices."""
        return self.pool._read(self.shape, self.row)

    def __iter__(self):
        """Duck-type as the carry tuple itself: iterating a handle
        yields the materialized ``(S*, f*, S̄)`` device parts."""
        return iter(self.materialize())

    def __len__(self) -> int:
        return 3


class _LazyCarry:
    """Tuple-shaped view of a pooled carry handed out in results.

    Slicing three device arrays out of the pool costs real dispatch
    time, and most callers never look at ``result.carry`` — so Tier-0
    hits hand out this view instead. It retains the handle (pinning the
    slab row even if the store evicts the entry later) and slices the
    parts out only on first access; the reference drops when the view
    is garbage-collected."""

    __slots__ = ("_handle", "_parts")

    def __init__(self, handle: "_CarryHandle"):
        handle.retain()
        self._handle = handle
        self._parts = None

    def materialize(self) -> tuple:
        if self._parts is None:
            # once sliced, the parts reference the slab *value* at this
            # moment (jax arrays are immutable), so the row pin can drop
            self._parts = self._handle.materialize()
            self._handle.release()
            self._handle = None
        return self._parts

    def __iter__(self):
        return iter(self.materialize())

    def __len__(self) -> int:
        return 3

    def __getitem__(self, i):
        return self.materialize()[i]

    def __del__(self):
        h = self._handle
        if h is not None:
            try:
                h.release()
            except Exception:  # pragma: no cover - interpreter teardown
                pass


class DeviceCarryPool:
    """Device-resident slab storage for warm-start carries.

    Carries used to live in the :class:`CarryStore` as loose per-entry
    arrays; every drain re-assembled its batch inputs with host
    ``np.stack([np.asarray(...)])`` — a blocking device→host→device
    round trip per launch. This pool keeps one growable slab triple per
    padded shape — ``S``: (cap, n, m), ``f``: (cap,), ``C``: (cap, n, m),
    all float32, all device-resident — and hands out refcounted
    :class:`_CarryHandle` rows:

      * ``put`` writes a row through a donated jit update (in place, no
        slab copy),
      * ``gather`` turns a batch of handles into stacked launch inputs
        with ONE ``jnp.take`` per part — device-side, dispatched
        asynchronously, never a host sync,
      * rows are recycled through a free list as store evictions release
        their handles.

    Slabs grow geometrically (``jnp.concatenate`` with a zero block), so
    amortized put cost stays O(row). The pool never syncs to host; the
    persistence layer materializes handles lazily at snapshot-save time.
    """

    def __init__(self, block: int = 32):
        self.block = max(int(block), 1)
        self._slabs: Dict[Tuple[int, int], dict] = {}
        self.puts = 0                # rows written (donated updates)
        self.gathers = 0             # batched jnp.take gathers served
        # steady-state warm drains gather the same row sets every time;
        # caching the device index array saves a host→device transfer
        # dispatch per launch
        self._idx_cache: "OrderedDict[tuple, jax.Array]" = OrderedDict()

    def _slab_for(self, shape: Tuple[int, int]) -> dict:
        slab = self._slabs.get(shape)
        if slab is None:
            n, m = shape
            cap = self.block
            slab = {"S": jnp.zeros((cap, n, m), jnp.float32),
                    "f": jnp.zeros((cap,), jnp.float32),
                    "C": jnp.zeros((cap, n, m), jnp.float32),
                    "free": list(range(cap - 1, -1, -1)), "cap": cap}
            self._slabs[shape] = slab
        if not slab["free"]:
            old = slab["cap"]
            grow = max(old, self.block)
            n, m = shape
            slab["S"] = jnp.concatenate(
                [slab["S"], jnp.zeros((grow, n, m), jnp.float32)])
            slab["f"] = jnp.concatenate(
                [slab["f"], jnp.zeros((grow,), jnp.float32)])
            slab["C"] = jnp.concatenate(
                [slab["C"], jnp.zeros((grow, n, m), jnp.float32)])
            slab["cap"] = old + grow
            slab["free"] = list(range(old + grow - 1, old - 1, -1))
        return slab

    def put(self, carry: tuple) -> _CarryHandle:
        """Write one ``(S*, f*, S̄)`` carry into a slab row (donated
        in-place update) and return its (unretained) handle. Accepts
        device or host arrays; parts are cast to the slab's float32."""
        S = jnp.asarray(carry[0], jnp.float32)
        f = jnp.asarray(carry[1], jnp.float32)
        C = jnp.asarray(carry[2], jnp.float32)
        shape = (int(S.shape[0]), int(S.shape[1]))
        slab = self._slab_for(shape)
        row = slab["free"].pop()
        writer = _pool_writer(slab["cap"], *shape)
        slab["S"], slab["f"], slab["C"] = writer(
            slab["S"], slab["f"], slab["C"], S, f, C, jnp.int32(row))
        self.puts += 1
        return _CarryHandle(self, shape, row)

    def gather(self, handles: Sequence[_CarryHandle]) -> tuple:
        """Stacked ``(S, f, C)`` launch inputs for a batch of same-shape
        handles — one ``jnp.take`` per part, all on device. The result
        is freshly allocated, so callers may donate it to a launch."""
        shape = handles[0].shape
        slab = self._slabs[shape]
        rows = tuple(h.row for h in handles)
        idx = self._idx_cache.get(rows)
        if idx is None:
            idx = jnp.asarray(rows, jnp.int32)
            self._idx_cache[rows] = idx
            while len(self._idx_cache) > 256:
                self._idx_cache.popitem(last=False)
        self.gathers += 1
        return (jnp.take(slab["S"], idx, axis=0),
                jnp.take(slab["f"], idx, axis=0),
                jnp.take(slab["C"], idx, axis=0))

    def _read(self, shape: Tuple[int, int], row: int) -> tuple:
        slab = self._slabs[shape]
        return (slab["S"][row], slab["f"][row], slab["C"][row])

    def _free(self, shape: Tuple[int, int], row: int) -> None:
        slab = self._slabs.get(shape)
        if slab is not None:
            slab["free"].append(row)

    @property
    def live_rows(self) -> int:
        """Rows currently referenced by at least one store entry."""
        return sum(s["cap"] - len(s["free"])
                   for s in self._slabs.values())


class MatcherService:
    """Warm-start online wrapper around Algorithm 1.

    Single-device by default; pass ``mesh`` + ``axis_names`` to run each
    bucket's executable as the collective-fused distributed matcher.
    ``tiered=False`` disables the staged pipeline and restores the
    uniform one-swarm-launch-per-batch drain (the PR-2 baseline);
    ``similarity=False`` keeps the pipeline but disables Tier-1 rebases
    (the content-keyed baseline).

    **Warm-restart persistence.** Pass ``persist_dir`` (or set
    ``REPRO_PERSIST_DIR``; pass ``persist_dir=False`` to force
    persistence off even when the env var is set — the cold-restart
    baseline arm) to survive process restarts:

      * ``<persist_dir>/aot/`` — each single-device executable is
        ``jax.export``-serialized on its first trace and lazily
        deserialized on the first compile-LRU miss of a restarted
        process, so the first post-restart burst runs with
        ``stats.jit_traces == 0``. Keys include the resolved kernel
        backend, every ``PSOConfig`` field, bucketing parameters, jax
        version and platform (``config_digest``) — drift is a clean
        miss, never a wrong program. Mesh-sharded executables are not
        exported (the blob pins device topology); they rely on the XLA
        compilation-cache fallback below.
      * ``<persist_dir>/snapshots/`` — ``save_snapshot`` /
        ``restore_snapshot`` persist the :class:`CarryStore` (exact +
        similarity carries; the popcount index is rebuilt on load) and
        the prune-sweep calibration counters through
        :class:`~repro.checkpoint.manager.CheckpointManager` (atomic
        commit, ``keep=snapshot_keep``). Snapshots are versioned and
        digest-validated: a restore against a drifted config is skipped
        cleanly (``snapshot_stale_skipped``), never mis-applied.
      * ``<persist_dir>/xla/`` — JAX's persistent compilation cache is
        enabled here (process-global; opt out with ``REPRO_JAX_CACHE=0``)
        so the residual XLA compile of deserialized modules and of the
        non-exportable mesh executables is also served from disk.
    """

    def __init__(self, cfg: Optional[pso.PSOConfig] = None, *,
                 mesh=None, axis_names: Sequence[str] = ("data",),
                 cache_capacity: int = 16, warm_capacity: int = 256,
                 warm_start: bool = True, early_exit: bool = True,
                 n_multiple: int = 8, m_multiple: int = 16,
                 batch_classes: Sequence[int] = (1, 2, 4, 8),
                 tiered: bool = True, similarity: bool = True,
                 sim_capacity: int = 128, sim_index: bool = True,
                 pipelined: bool = True,
                 donate_buffers: Optional[bool] = None,
                 persist_dir: Union[str, bool, None] = None,
                 aot_cache: Optional[bool] = None,
                 snapshot_keep: int = 3):
        cfg = cfg or pso.PSOConfig()
        if early_exit and not cfg.early_exit:
            cfg = cfg.replace(early_exit=True)
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.cache_capacity = max(int(cache_capacity), 1)
        self.warm_start = warm_start
        self.n_multiple = n_multiple
        self.m_multiple = m_multiple
        self.batch_classes = tuple(sorted(set(int(b) for b in batch_classes)))
        assert self.batch_classes and self.batch_classes[0] >= 1
        self.tiered = tiered
        self.similarity = similarity
        # pipelined=False restores the legacy serial drain (host-staged
        # carry stacking, dispatch → blocking fetch per launch) — the
        # baseline arm bench_pipeline measures the pipeline against
        self.pipelined = bool(pipelined)
        if donate_buffers is None:
            donate_buffers = pallas_compat.donation_supported()
        self.donate_buffers = bool(donate_buffers)
        self.stats = ServiceStats()
        self._carries = CarryStore(warm_capacity, sim_capacity, self.stats,
                                   sim_index=sim_index)
        self._pool = DeviceCarryPool()
        # per-bucket pre-finished pad carry, pooled once and pinned so
        # padded warm batches stay all-handle (one-gather launch inputs)
        self._pad_handles: Dict[Tuple[int, int], _CarryHandle] = {}
        self._compiled: "OrderedDict[Tuple, object]" = OrderedDict()
        self._pending: List[_PendingRequest] = []
        # -- persistence wiring -------------------------------------------
        # persist_dir: a path enables persistence there; None defers to
        # the REPRO_PERSIST_DIR env var; False forces persistence OFF
        # even when the env var is set (cold-restart baselines must not
        # silently warm up from an operator's persist root).
        if persist_dir is None:
            persist_dir = persist.default_persist_dir()
        self.persist_dir = persist_dir if persist_dir else None
        if aot_cache is None:
            aot_cache = persist.aot_cache_enabled()
        self._aot: Optional[persist.AOTCache] = None
        self._ckpt: Optional[CheckpointManager] = None
        if self.persist_dir:
            if aot_cache:
                self._aot = persist.AOTCache(
                    os.path.join(self.persist_dir, "aot"), self.stats)
            self._ckpt = CheckpointManager(
                os.path.join(self.persist_dir, "snapshots"),
                async_save=False, keep=snapshot_keep)
            persist.enable_jax_compilation_cache(
                os.path.join(self.persist_dir, "xla"))
        if self._aot is not None and self.donate_buffers \
                and not _DONATION_EXPORT_WARNED \
                and not pallas_compat.export_preserves_donation():
            # degrade LOUDLY (once per process): results stay correct,
            # but AOT-restored executables run without the in-place
            # carry update
            _DONATION_EXPORT_WARNED.append(True)
            warnings.warn(
                "jax.export round trips drop donate_argnums on this "
                "toolchain: AOT-cached executables will not update "
                "carry buffers in place (correctness is unaffected). "
                "Pass donate_buffers=False to silence.",
                RuntimeWarning, stacklevel=2)

    @property
    def warm_capacity(self) -> int:
        """Exact warm-start store capacity (entries)."""
        return self._carries.capacity

    def clear_carries(self) -> None:
        """Drop every stored warm-start carry (exact and similarity)."""
        self._carries.clear()

    @property
    def config_digest(self) -> str:
        """Digest guarding everything persisted by this service: resolved
        kernel backend + all ``PSOConfig`` fields + shape-bucketing
        parameters + jax version/platform + mesh-ness. AOT executables
        and snapshots from a process whose digest differs are ignored."""
        return kernel_backend.config_digest(
            self.cfg,
            extra=("svc-v2", jax.__version__, jax.default_backend(),
                   self.n_multiple, self.m_multiple, self.batch_classes,
                   self.mesh is not None))

    # -- caches ------------------------------------------------------------

    def _cache_put(self, cache_key, fn):
        self._compiled[cache_key] = fn
        while len(self._compiled) > self.cache_capacity:
            self._compiled.popitem(last=False)
            self.stats.compile_evictions += 1
        return fn

    def _cache_get(self, cache_key):
        fn = self._compiled.get(cache_key)
        if fn is not None:
            self._compiled.move_to_end(cache_key)
            self.stats.compile_cache_hits += 1
        return fn

    def _count_first_call(self, fn):
        """Wrap a live-jit executable so its lazy first-call trace shows
        up in ``stats.jit_traces`` (the observable the AOT cache zeroes
        out across restarts)."""
        fired: List[int] = []

        def wrapped(*args):
            if not fired:
                fired.append(1)
                self.stats.jit_traces += 1
            return fn(*args)

        return wrapped

    def _resolve_executable(self, cache_key, kind: str,
                            bucket: Tuple[int, int], bclass: int, build):
        """Compile-LRU lookup with the on-disk AOT layer behind it.

        Miss order: (1) in-memory LRU; (2) deserialized ``jax.export``
        blob — runs with NO Python trace; (3) ``build()`` a live jit
        function, which traces on first call and (when exportable and
        persistence is on) serializes itself to disk for the next
        process. Every path lands in the LRU under ``cache_key``."""
        fn = self._cache_get(cache_key)
        if fn is not None:
            return fn
        self.stats.compile_cache_misses += 1
        if self._aot is not None:
            aot_key = f"{kind}-n{bucket[0]}m{bucket[1]}-b{bclass}" \
                      f"-{self.config_digest}"
            loaded = self._aot.load(aot_key, build)
            if loaded is not None:
                self.stats.aot_cache_hits += 1
                return self._cache_put(cache_key, loaded)
            self.stats.aot_cache_misses += 1
            built = build()
            if getattr(built, "aot_exportable", True):
                return self._cache_put(
                    cache_key, self._aot.wrap_exporting(aot_key, built))
            return self._cache_put(cache_key, self._count_first_call(built))
        return self._cache_put(cache_key, self._count_first_call(build()))

    def _executable(self, bucket: Tuple[int, int]):
        """Single-problem swarm executable for one shape bucket."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(key, Q, G, mask, carry0, _cfg=cfg):
                    return pso._match_body(key, Q, G, mask, _cfg, carry0)

                return jax.jit(fn)
            return build_distributed_match(bucket, self.mesh, self.cfg,
                                           self.axis_names)

        return self._resolve_executable(bucket, "match", bucket, 1, build)

    def _executable_batch(self, bucket: Tuple[int, int], bclass: int):
        """One swarm executable per (shape bucket, padded batch class)."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(keys, Qb, Gb, maskb, carry0, _cfg=cfg):
                    return pso._match_batch_body(keys, Qb, Gb, maskb, _cfg,
                                                 carry0)

                return jax.jit(
                    fn, donate_argnums=self._donate_argnums("batch"))
            return build_distributed_match_batch(bucket, self.mesh,
                                                 self.cfg, self.axis_names,
                                                 bclass)

        return self._resolve_executable((bucket, bclass), "batch",
                                        bucket, bclass, build)

    def _executable_reval(self, bucket: Tuple[int, int], bclass: int):
        """Tier-0/1 revalidation executable (no epochs, no keys)."""
        def build():
            if self.mesh is None:
                cfg = self.cfg

                def fn(Qb, Gb, maskb, carry0, _cfg=cfg):
                    return pso._revalidate_batch_body(Qb, Gb, maskb, _cfg,
                                                      carry0)

                return jax.jit(
                    fn, donate_argnums=self._donate_argnums("reval"))
            return build_distributed_revalidate_batch(
                bucket, self.mesh, self.cfg, self.axis_names, bclass)

        return self._resolve_executable((bucket, bclass, "reval"), "reval",
                                        bucket, bclass, build)

    def _batch_class(self, k: int) -> int:
        """Smallest padded batch class holding k problems."""
        for c in self.batch_classes:
            if c >= k:
                return c
        return self.batch_classes[-1]

    @staticmethod
    def _warm_key(req: _PendingRequest) -> Tuple:
        """Exact warm starts are only valid for the *same* problem (f*
        values are not comparable across different Q/G), so the key always
        includes the content digest ``_prepare`` computed; the request's
        ``workload_key`` additionally scopes entries to the caller's
        (workload, platform-state) naming."""
        return (req.workload_key, req.Qp.shape[0], req.Gp.shape[0],
                req.cdigest)

    def _get_carry(self, warm_key):
        if not self.warm_start:
            self.stats.warm_misses += 1
            return None, False
        return self._carries.get(warm_key)

    def _put_carry(self, warm_key, carry):
        if self.warm_start:
            self._carries.put(warm_key, carry)

    def _store_result_carries(self, req: _PendingRequest, warm_key,
                              res: MatchResult, dev_carry=None) -> None:
        """Store a fresh carry under the exact key, and — when the call
        produced a served decision on a known platform state — under the
        similarity key too, so future drifted states can rebase it.

        ``dev_carry`` (the launch's still-on-device ``(S*, f*, S̄)``
        slices) keeps the stored copy device-resident: it lands in the
        :class:`DeviceCarryPool` without ever visiting the host. Without
        it the result's host carry is uploaded once at store time."""
        if not self.warm_start:
            return
        carry = res.carry if dev_carry is None else dev_carry
        # mesh-sharded services skip the (single-device) pool: their
        # launch outputs carry mesh shardings the slabs can't hold
        stored = self._pool.put(self._carry_tuple(carry)) \
            if self.mesh is None else res.carry
        self._put_carry(warm_key, stored)
        if (self.similarity and res.found and req.engine_sig is not None):
            self._carries.put_similar(req.qdigest, req.bucket,
                                      req.engine_sig, stored)

    # -- snapshots ---------------------------------------------------------

    def save_snapshot(self, step: Optional[int] = None,
                      extra: Optional[Dict] = None) -> int:
        """Persist the service's warm state as one atomic checkpoint.

        Saved: every :class:`CarryStore` entry (exact and similarity,
        in LRU order; carries land as one ``.npy`` leaf per array) plus
        the prune-sweep calibration counters
        (``prune_problems``/``prune_sweeps`` — the observable the
        scheduler's analytic cost model reads). NOT saved: compiled
        executables (the AOT cache owns those), transient stats, pending
        requests. ``extra`` (JSON-serializable) rides in the snapshot
        metadata — the scheduler stores its tier-predictor posteriors
        there. Entries whose keys cannot be encoded (non-str/int/bytes/
        tuple workload keys) are skipped and counted
        (``snapshot_skipped_keys``). Returns the committed step number.
        Requires ``persist_dir``."""
        if self._ckpt is None:
            raise RuntimeError("save_snapshot needs persist_dir "
                               "(or REPRO_PERSIST_DIR)")
        exact_items, sim_items = self._carries.export_state()
        arrays: Dict[str, np.ndarray] = {}
        exact_keys, exact_carries = [], []
        for k, c in exact_items:
            try:
                exact_keys.append(persist.encode_key(k))
            except TypeError:
                self.stats.snapshot_skipped_keys += 1
                continue
            # device-pool handles materialize to lazy device slices here;
            # the ONE blocking transfer happens inside carry_leaves
            exact_carries.append(self._carry_tuple(c))
        sim_keys, sim_carries = [], []
        for k, c in sim_items:
            try:
                sim_keys.append(persist.encode_key(k))
            except TypeError:
                self.stats.snapshot_skipped_keys += 1
                continue
            sim_carries.append(self._carry_tuple(c))
        arrays.update(persist.carry_leaves("exact", exact_carries))
        arrays.update(persist.carry_leaves("sim", sim_carries))
        # flat-dict checkpoints must be non-empty for restore_flat to see
        # a committed structure even when no carries are stored yet
        arrays["snapshot.marker"] = np.zeros((), np.int8)
        extras = {
            "format_version": persist.SNAPSHOT_VERSION,
            "config_digest": self.config_digest,
            "exact_keys": exact_keys,
            "sim_keys": sim_keys,
            "calibration": {
                "prune_problems": int(self.stats.prune_problems),
                "prune_sweeps": int(self.stats.prune_sweeps),
            },
            "extra": extra or {},
        }
        if step is None:
            latest = self._ckpt.latest_step()
            step = 0 if latest is None else latest + 1
        self._ckpt.save(step, arrays, extras=extras)
        self._ckpt.wait()
        self.stats.snapshot_saves += 1
        return step

    def restore_snapshot(self, step: Optional[int] = None
                         ) -> Optional[Dict]:
        """Load the newest (or ``step``-th) snapshot into this service.

        Validation before anything is touched: the snapshot's format
        version and ``config_digest`` must match this service's — a
        snapshot written under a different kernel backend, ``PSOConfig``,
        bucketing, jax version or platform is counted in
        ``snapshot_stale_skipped`` and ignored (warm state from a
        drifted config could verify carries that no longer mean the same
        thing). On success the :class:`CarryStore` is rebuilt (recency
        preserved, similarity popcount index reconstructed), the
        prune-sweep calibration counters are re-seeded, and the
        snapshot's ``extra`` dict is returned (``{}`` when none was
        stored). Returns None when nothing (valid) exists to restore.
        Requires ``persist_dir``."""
        if self._ckpt is None:
            raise RuntimeError("restore_snapshot needs persist_dir "
                               "(or REPRO_PERSIST_DIR)")
        try:
            arrays, extras = self._ckpt.restore_flat(step)
        except (OSError, ValueError, KeyError):
            arrays, extras = None, None
        if arrays is None:
            return None
        if extras.get("format_version") != persist.SNAPSHOT_VERSION or \
                extras.get("config_digest") != self.config_digest:
            self.stats.snapshot_stale_skipped += 1
            return None
        exact_keys = [persist.decode_key(k) for k in extras["exact_keys"]]
        sim_keys = [persist.decode_key(k) for k in extras["sim_keys"]]
        exact_carries = persist.carries_from_leaves(
            "exact", arrays, len(exact_keys))
        sim_carries = persist.carries_from_leaves(
            "sim", arrays, len(sim_keys))
        if self.mesh is None:
            # restored carries go straight back to device residency: one
            # pool row per entry, uploaded once; rows free themselves as
            # store replay/evictions release the handles
            exact_carries = [self._pool.put(c) for c in exact_carries]
            sim_carries = [self._pool.put(c) for c in sim_carries]
        n_exact, n_sim = self._carries.import_state(
            list(zip(exact_keys, exact_carries)),
            list(zip(sim_keys, sim_carries)))
        calib = extras.get("calibration", {})
        self.stats.prune_problems += int(calib.get("prune_problems", 0))
        self.stats.prune_sweeps += int(calib.get("prune_sweeps", 0))
        self.stats.snapshot_restores += 1
        self.stats.restored_carries += n_exact
        self.stats.restored_sim_entries += n_sim
        return extras.get("extra", {})

    def verify_snapshot_roundtrip(self, step: Optional[int] = None
                                  ) -> bool:
        """Save a snapshot, restore it into a FRESH twin service, and
        bitwise-compare the warm state — the mid-run round-trip probe
        the invariant fuzzer leans on.

        The twin is built with this service's config (same
        ``config_digest``, so the restore is accepted) but no AOT cache
        (snapshots only; nothing is compiled). Compared: both carry
        stores' key sequences in LRU order, every carry leaf
        (``dtype``/``shape``/bytes — via :meth:`_carry_tuple`, so
        device-pool handles materialize identically on both sides) and
        the prune-sweep calibration counters. Raises ``AssertionError``
        naming the first divergence; returns True when the round trip
        is bitwise clean. Requires ``persist_dir``."""
        step = self.save_snapshot(step=step)
        twin = MatcherService(
            self.cfg, mesh=self.mesh, axis_names=self.axis_names,
            cache_capacity=self.cache_capacity,
            warm_capacity=self._carries.capacity,
            warm_start=self.warm_start, n_multiple=self.n_multiple,
            m_multiple=self.m_multiple,
            batch_classes=self.batch_classes, tiered=self.tiered,
            similarity=self.similarity,
            sim_capacity=self._carries.sim_capacity,
            sim_index=self._carries.sim_index,
            pipelined=self.pipelined,
            donate_buffers=self.donate_buffers,
            persist_dir=self.persist_dir, aot_cache=False)
        restored = twin.restore_snapshot(step=step)
        assert restored is not None, \
            "snapshot round trip: restore rejected its own snapshot"

        def _leaves(svc):
            exact, sim = svc._carries.export_state()
            return ([(k, svc._carry_tuple(c)) for k, c in exact],
                    [(k, svc._carry_tuple(c)) for k, c in sim])

        for store, mine, theirs in zip(("exact", "sim"), _leaves(self),
                                       _leaves(twin)):
            assert [k for k, _ in mine] == [k for k, _ in theirs], \
                f"snapshot round trip: {store} store keys diverged"
            for (key, a), (_, b) in zip(mine, theirs):
                a, b = [tuple(np.asarray(x) for x in c) for c in (a, b)]
                assert len(a) == len(b), \
                    f"snapshot round trip: carry arity for {key!r}"
                for x, y in zip(a, b):
                    assert x.dtype == y.dtype and x.shape == y.shape \
                        and x.tobytes() == y.tobytes(), \
                        f"snapshot round trip: {store} carry for " \
                        f"{key!r} not bitwise equal"
        assert (twin.stats.prune_problems, twin.stats.prune_sweeps) == \
            (self.stats.prune_problems, self.stats.prune_sweeps), \
            "snapshot round trip: calibration counters diverged"
        return True

    # -- matching ----------------------------------------------------------

    def _prepare(self, query: Graph, target: Graph, key, workload_key,
                 engine_sig: Optional[bytes] = None) -> _PendingRequest:
        """Relabel, bucket and pad a problem on the host — the jit call
        uploads Qp/Gp/maskp once; no device→host→device round trip.

        ``engine_sig`` (the free-engine bitmask, see
        ``accel.target_graph.free_engine_signature``) keys the similarity
        store; when omitted it is recovered from a ``(name, sig)``-style
        ``workload_key`` whose last element is bytes — the scheduler's
        existing naming convention."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if engine_sig is None and isinstance(workload_key, tuple) \
                and workload_key and isinstance(workload_key[-1], bytes):
            engine_sig = workload_key[-1]
        q, order = topological_relabel(query)
        n, m = q.n, target.n
        mask = compatibility_mask(q, target)
        bucket = shape_bucket(n, m, self.n_multiple, self.m_multiple)
        Qp, Gp, maskp = pad_problem(q.adj, target.adj, mask, *bucket)
        # one hashing pass yields both keys: the query-only digest (the
        # similarity key) is a prefix state of the full content digest
        # (the exact warm key)
        h = hashlib.sha1(np.ascontiguousarray(Qp).tobytes())
        qdigest = h.hexdigest()
        h.update(np.ascontiguousarray(Gp).tobytes())
        h.update(np.ascontiguousarray(maskp).tobytes())
        return _PendingRequest(key=key, workload_key=workload_key,
                               order=order, crop=(n, m), bucket=bucket,
                               Qp=Qp, Gp=Gp, maskp=maskp,
                               engine_sig=engine_sig, qdigest=qdigest,
                               cdigest=h.hexdigest())

    def _note_prune(self, problems: int, sweeps: int) -> None:
        """Account the fused pre-prune work a launch reported (the
        ``prune_sweeps`` observable of the match/revalidate kernels)."""
        if self.cfg.prune_mask and problems > 0:
            self.stats.prune_problems += problems
            self.stats.prune_sweeps += int(sweeps)

    def _tiers_active(self) -> bool:
        """Tier 0/1 only exist when the kernel fast path they batch is on
        (otherwise serving at 0 epochs would change semantics)."""
        return (self.tiered and self.warm_start
                and self.cfg.early_exit and self.cfg.carry_fastpath)

    # -- device residency --------------------------------------------------

    def _sync_fetch(self, tree):
        """THE blocking device→host transfer of the drain pipeline.

        Fetches a whole pytree (typically every pending launch's outputs)
        with one ``jax.device_get`` and records it in the host-sync
        census: ``host_syncs`` (count), ``host_bytes_transferred``
        (payload) and ``host_sync_wall_s`` (time spent blocked). Every
        result-consuming path routes through here, so the counters ARE
        the sync budget the transfer-guard test pins."""
        t0 = time.perf_counter()
        host = jax.device_get(tree)
        self.stats.host_syncs += 1
        self.stats.host_sync_wall_s += time.perf_counter() - t0
        self.stats.host_bytes_transferred += int(sum(
            getattr(leaf, "nbytes", 0)
            for leaf in jax.tree_util.tree_leaves(host)))
        return host

    def _fetch_tree(self, rec: "_LaunchRecord"):
        """The subset of a launch's outputs its apply step actually
        reads on host. Tier-0 revalidation never looks at the rebased
        ``S*``/``S̄`` planes host-side (hit carries stay pooled on
        device), so skipping them keeps the biggest leaves out of every
        warm fetch. Swarm and mesh launches fetch everything."""
        if rec.kind != "reval" or self.mesh is not None:
            return rec.outs
        keys = (("mapping", "ok", "f_carry", "prune_sweeps")
                if rec.tier == 0 else
                ("mapping", "ok_rebase", "fitness", "S_star", "S_bar",
                 "prune_sweeps"))
        return {k: rec.outs[k] for k in keys}

    @staticmethod
    def _carry_tuple(carry) -> tuple:
        """A stored carry as its ``(S*, f*, S̄)`` tuple: device-pool
        handles and lazy result views are materialized (lazy device
        slices, no host sync); plain tuples pass through."""
        if isinstance(carry, (_CarryHandle, _LazyCarry)):
            return carry.materialize()
        return carry

    def _stack_carries(self, carries: List) -> tuple:
        """Stacked ``(B, ...)`` carry inputs for one launch, device-side.

        All-handle same-shape batches (the warm steady state) take the
        pool's one-``jnp.take``-per-part gather; mixed batches (cold
        priors, rebased seeds, pad fillers) fall back to a device-side
        ``jnp.stack`` of the materialized parts. Either way the result
        is freshly allocated — safe to donate — and nothing round-trips
        through the host.

        Mesh services and the ``pipelined=False`` arm instead keep the
        legacy host staging this PR replaced: each carry part is pulled
        to host with a blocking ``np.asarray`` and re-stacked with
        numpy. Those implicit device→host transfers are what the
        pipeline eliminates, so they are charged to the host-sync
        census here (one sync per device-resident part)."""
        if self.mesh is not None or not self.pipelined:
            mats = [self._carry_tuple(c) for c in carries]
            stacked = []
            for i in range(3):
                parts = []
                for mat in mats:
                    p = mat[i]
                    if isinstance(p, jax.Array):
                        t0 = time.perf_counter()
                        p = np.asarray(p)
                        self.stats.host_syncs += 1
                        self.stats.host_sync_wall_s += \
                            time.perf_counter() - t0
                        self.stats.host_bytes_transferred += int(p.nbytes)
                    parts.append(np.asarray(p))
                stacked.append(np.stack(parts))
            return tuple(stacked)
        if all(isinstance(c, _CarryHandle) for c in carries) and \
                len({c.shape for c in carries}) == 1:
            return self._pool.gather(carries)
        mats = [self._carry_tuple(c) for c in carries]
        return tuple(jnp.stack([jnp.asarray(m[i], jnp.float32)
                                for m in mats])
                     for i in range(3))

    def _donate_argnums(self, kind: str) -> Tuple[int, ...]:
        """Argnums a fresh jit build of ``kind`` may donate (empty when
        ``donate_buffers`` is off or the kind's inputs can alias stored
        state — see ``kernels.backend.SERVICE_DONATABLE_ARGNUMS``)."""
        if not self.donate_buffers:
            return ()
        return kernel_backend.donate_argnums_for(kind)

    def match(self, query: Graph, target: Graph,
              key: Optional[jax.Array] = None,
              workload_key=None,
              engine_sig: Optional[bytes] = None) -> ServiceMatchResult:
        """Match ``query`` onto ``target`` through the service caches.

        ``workload_key`` names the (workload, platform-state) class for
        warm-start scoping — e.g. ``(task_name, free_engine_signature)``.
        Results are exactly the unpadded equivalent of a direct
        ``pso.match`` on the same problem. A single call serves warm
        repeats through the in-kernel carry fast path (Tier 0, free
        inside the swarm launch) and attempts a Tier-1 rebase on an
        exact-carry MISS with a similar stored platform state. Unlike
        ``drain``, a failed exact carry goes straight to the swarm —
        probing the similarity store behind it would add a second
        dispatch to every warm single call; batch that traffic through
        ``submit``/``drain`` to get the full pipeline.
        """
        t0 = time.perf_counter()
        self.stats.calls += 1
        self.stats.epochs_budgeted += self.cfg.epochs
        req = self._prepare(query, target, key, workload_key, engine_sig)
        key, bucket = req.key, req.bucket
        order, (n, m) = req.order, req.crop
        Qp, Gp, maskp = req.Qp, req.Gp, req.maskp

        warm_key = self._warm_key(req)
        carry0, warm_hit = self._get_carry(warm_key)
        if carry0 is not None:
            self.stats.tier0.checked += 1

        # Tier 1 (single-call path): exact miss, but a similar platform
        # state is stored — revalidate its rebased carry before swarming.
        seed = None
        if carry0 is None and self._tiers_active() and self.similarity \
                and req.engine_sig is not None:
            item = _PipelineItem(req=req, ticket=0, warm_key=warm_key,
                                 carry=None, warm_hit=False, t0=t0)
            nb = self._lookup_neighbor(item)
            if nb is not None:
                residual = self._launch_revalidate(bucket, [item], [nb],
                                                   tier=1)
                if not residual:
                    res = item.result
                    res.latency_s = time.perf_counter() - t0
                    return res
                seed = item.seed

        hits_before = self.stats.compile_cache_hits
        fn = self._executable(bucket)
        compile_hit = self.stats.compile_cache_hits > hits_before

        if carry0 is None:
            carry0 = seed if seed is not None \
                else pso.default_carry(jnp.asarray(maskp))
        else:
            carry0 = self._carry_tuple(carry0)

        if self.mesh is None:
            outs = fn(key, Qp, Gp, maskp, carry0)
        else:
            num_shards = int(np.prod([self.mesh.shape[a]
                                      for a in self.axis_names]))
            keys = jax.random.split(key, num_shards)
            outs = fn(keys, Qp, Gp, maskp, carry0)

        # the controller state stays device-resident for the store; the
        # result itself resolves through ONE counted blocking fetch
        dev_carry = (outs["S_star"], outs["f_star"], outs["S_bar"])
        base = collect_result(self._sync_fetch(outs), order=order,
                              crop=(n, m))
        res = ServiceMatchResult(**{f.name: getattr(base, f.name)
                                    for f in dataclasses.fields(MatchResult)})
        self._store_result_carries(req, warm_key, res, dev_carry=dev_carry)
        self.stats.epochs_run += res.epochs_run
        self._note_prune(1, res.prune_sweeps)
        if res.found:
            self.stats.found += 1
        if res.carry_verified:
            # the in-kernel fast path IS Tier 0 for a single call
            self.stats.carry_fastpath_hits += 1
            self.stats.tier0.hits += 1
            res.tier = 0
        else:
            self.stats.tier2.launches += 1
            self.stats.epoch_fused_launches += 1
            self.stats.epoch_finish_launches += 1
            self.stats.epoch_finish_problems += 1
            self.stats.tier2.checked += 1
            if res.found:
                self.stats.tier2.hits += 1
            res.tier = 2
        res.bucket = bucket
        res.compile_cache_hit = compile_hit
        res.warm_hit = warm_hit
        res.latency_s = time.perf_counter() - t0
        return res

    # -- request coalescing ------------------------------------------------

    def submit(self, query: Graph, target: Graph,
               key: Optional[jax.Array] = None, workload_key=None,
               engine_sig: Optional[bytes] = None) -> int:
        """Queue a problem for the next ``drain``; returns its ticket
        index into the results list ``drain`` will return."""
        self._pending.append(self._prepare(query, target, key, workload_key,
                                           engine_sig))
        return len(self._pending) - 1

    @property
    def pending(self) -> int:
        """Number of submitted problems waiting for the next drain."""
        return len(self._pending)

    def drain(self) -> List[ServiceMatchResult]:
        """Flush the pending queue through the tiered pipeline.

        Same-bucket requests form one pipeline group: Tier 0 revalidates
        every stored carry in one cheap launch, Tier 1 rebases similar
        carries for the misses, and only the residual requests launch the
        Tier-2 swarm (chunked to batch classes). Results come back in
        submission order; each request's ``latency_s`` is the wall time
        of the launches that actually served it, so an easy request no
        longer pays a hard neighbour's epochs.

        With ``pipelined=True`` (the default) each tier dispatches its
        launches for EVERY bucket group before anything blocks: the host
        builds and enqueues group B's batch while the device still runs
        group A's, and each stage resolves through one batched fetch —
        an all-warm drain costs exactly one blocking host sync
        (``stats.host_syncs_per_drain``). ``pipelined=False`` restores
        the legacy serial walk: carries staged through host numpy (one
        implicit sync per device-resident carry part) and one blocking
        fetch per launch.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        self.stats.drains += 1
        results: List[Optional[ServiceMatchResult]] = [None] * len(pending)
        groups: "OrderedDict[Tuple[int, int], List[int]]" = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(req.bucket, []).append(i)
        if self._tiers_active() and self.pipelined:
            self._drain_pipelined(pending, groups, results)
            return results  # type: ignore[return-value]
        max_chunk = self.batch_classes[-1]
        for bucket, idxs in groups.items():
            reqs = [pending[i] for i in idxs]
            if self._tiers_active():
                self._run_pipeline(bucket, reqs, idxs, results)
            else:
                for pos in range(0, len(idxs), max_chunk):
                    chunk = idxs[pos:pos + max_chunk]
                    self._launch_batch_legacy(
                        bucket, [pending[i] for i in chunk], chunk, results)
        return results  # type: ignore[return-value]

    def match_many(self, problems: Sequence[Tuple[Graph, Graph]],
                   keys: Optional[Sequence[jax.Array]] = None,
                   workload_keys: Optional[Sequence] = None,
                   engine_sigs: Optional[Sequence[Optional[bytes]]] = None
                   ) -> List[ServiceMatchResult]:
        """Convenience: submit a burst of (query, target) problems and
        drain them through the tiered pipeline."""
        for i, (q, g) in enumerate(problems):
            self.submit(q, g,
                        key=None if keys is None else keys[i],
                        workload_key=(None if workload_keys is None
                                      else workload_keys[i]),
                        engine_sig=(None if engine_sigs is None
                                    else engine_sigs[i]))
        return self.drain()

    # -- the tiered pipeline ----------------------------------------------

    def _intake(self, reqs: List[_PendingRequest], tickets: List[int]
                ) -> List[_PipelineItem]:
        """Shared per-request intake for both drain paths: call/budget
        accounting, exact-carry lookup, group coalescing stats."""
        t_start = time.perf_counter()
        items: List[_PipelineItem] = []
        for req, ticket in zip(reqs, tickets):
            self.stats.calls += 1
            self.stats.epochs_budgeted += self.cfg.epochs
            wk = self._warm_key(req)
            carry, hit = self._get_carry(wk)
            items.append(_PipelineItem(req=req, ticket=ticket, warm_key=wk,
                                       carry=carry, warm_hit=hit,
                                       t0=t_start))
        if len(items) > 1:
            # the group shares ONE pipeline decision, whichever tier ends
            # up serving each member
            self.stats.coalesced_requests += len(items)
        return items

    def _run_pipeline(self, bucket, reqs: List[_PendingRequest],
                      tickets: List[int], results: List) -> None:
        """Revalidate → similarity-rebase → swarm for one bucket group."""
        items = self._intake(reqs, tickets)
        max_chunk = self.batch_classes[-1]

        # ---- Tier 0: batched revalidation of every stored carry ----
        residual: List[_PipelineItem] = [it for it in items
                                         if it.carry is None]
        cand = [it for it in items if it.carry is not None]
        for pos in range(0, len(cand), max_chunk):
            chunk = cand[pos:pos + max_chunk]
            residual.extend(self._launch_revalidate(
                bucket, chunk, [it.carry for it in chunk], tier=0))

        # ---- Tier 1: rebase the nearest similar carry for the misses ----
        if self.similarity and residual:
            t1_items, t1_carries = [], []
            for it in residual:
                nb = self._lookup_neighbor(it)
                if nb is not None:
                    t1_items.append(it)
                    t1_carries.append(nb)
            for pos in range(0, len(t1_items), max_chunk):
                self._launch_revalidate(
                    bucket, t1_items[pos:pos + max_chunk],
                    t1_carries[pos:pos + max_chunk], tier=1)

        # ---- Tier 2: swarm sized to the residual (hard) subset ----
        residual = [it for it in items if it.result is None]
        for pos in range(0, len(residual), max_chunk):
            self._launch_swarm(bucket, residual[pos:pos + max_chunk])

        for it in items:
            it.result.latency_s = it.latency_s
            results[it.ticket] = it.result

    def _drain_pipelined(self, pending: List[_PendingRequest],
                         groups: "OrderedDict[Tuple[int, int], List[int]]",
                         results: List) -> None:
        """Async-dispatch drain: every bucket group's launches for one
        tier go out before ANY of them blocks, then the whole stage
        resolves through a single batched fetch (``_apply_all``).

        Host-side tier decisions for later groups (padding, carry
        gathers, store probes) overlap device execution of earlier
        groups' launches, and the per-stage sync count is 1 instead of
        one per launch. Results and stored carries are bitwise identical
        to the serial walk: store keys embed the bucket, so groups never
        interact, and within a group the tier order and miss order are
        preserved exactly."""
        max_chunk = self.batch_classes[-1]
        # ---- Tier 0: dispatch every group's revalidation launches ----
        recs: List[_LaunchRecord] = []
        state = []                 # (bucket, items, residual) per group
        for bucket, idxs in groups.items():
            items = self._intake([pending[i] for i in idxs], idxs)
            residual = [it for it in items if it.carry is None]
            cand = [it for it in items if it.carry is not None]
            for pos in range(0, len(cand), max_chunk):
                chunk = cand[pos:pos + max_chunk]
                recs.append(self._dispatch_revalidate(
                    bucket, chunk, [it.carry for it in chunk], tier=0,
                    miss_sink=residual))
            state.append((bucket, items, residual))
        self._apply_all(recs)

        # ---- Tier 1: rebase lookups + dispatches across all groups ----
        recs = []
        for bucket, items, residual in state:
            if not (self.similarity and residual):
                continue
            t1_items, t1_carries = [], []
            for it in residual:
                nb = self._lookup_neighbor(it)
                if nb is not None:
                    t1_items.append(it)
                    t1_carries.append(nb)
            for pos in range(0, len(t1_items), max_chunk):
                recs.append(self._dispatch_revalidate(
                    bucket, t1_items[pos:pos + max_chunk],
                    t1_carries[pos:pos + max_chunk], tier=1,
                    miss_sink=[]))
        self._apply_all(recs)

        # ---- Tier 2: swarm the residual of every group ----
        recs = []
        for bucket, items, _ in state:
            residual = [it for it in items if it.result is None]
            for pos in range(0, len(residual), max_chunk):
                recs.append(self._dispatch_swarm(
                    bucket, residual[pos:pos + max_chunk]))
        self._apply_all(recs)

        for _, items, _ in state:
            for it in items:
                it.result.latency_s = it.latency_s
                results[it.ticket] = it.result

    def _apply_all(self, recs: List[_LaunchRecord]) -> None:
        """Resolve one pipeline stage: ONE blocking fetch covering every
        dispatched launch's outputs, then the per-launch applies in
        dispatch order (which preserves the serial walk's store/miss
        ordering)."""
        if not recs:
            return
        hosts = self._sync_fetch([self._fetch_tree(rec) for rec in recs])
        for rec, host in zip(recs, hosts):
            if rec.kind == "reval":
                self._apply_revalidate(rec, host)
            else:
                self._apply_swarm(rec, host)

    def _lookup_neighbor(self, item: _PipelineItem) -> Optional[tuple]:
        """Similarity-store probe for one Tier-0 miss; returns the carry
        of the nearest stored platform state, or None."""
        req = item.req
        if req.engine_sig is None:
            return None
        self.stats.sim_lookups += 1
        nb = self._carries.nearest(
            req.qdigest, req.bucket, req.engine_sig,
            # the exact carry already failed revalidation — don't retry it
            exclude_sig=req.engine_sig if item.carry is not None else None)
        if nb is None:
            return None
        self.stats.sim_neighbor_hits += 1
        return nb[1]

    def _launch_revalidate(self, bucket, items: List[_PipelineItem],
                           carries: List[tuple], tier: int
                           ) -> List[_PipelineItem]:
        """One *serial* Tier-0/1 launch: dispatch, then a blocking fetch
        of just this launch's outputs (one sync per launch — the arm
        ``bench_pipeline`` measures the pipelined drain against).

        Hits get their result attached (0 epochs, revalidation cost);
        misses are returned for the next tier. Tier-1 misses keep the
        rebased carry (f* reset to -inf) as their Tier-2 swarm seed."""
        misses: List[_PipelineItem] = []
        rec = self._dispatch_revalidate(bucket, items, carries, tier,
                                        miss_sink=misses)
        self._apply_revalidate(rec, self._sync_fetch(self._fetch_tree(rec)))
        return misses

    def _dispatch_revalidate(self, bucket, items: List[_PipelineItem],
                             carries: List[tuple], tier: int,
                             miss_sink: List) -> _LaunchRecord:
        """Enqueue one Tier-0/1 revalidation launch (no host sync): pad
        the batch, stack the carries device-side, dispatch. The returned
        record resolves via ``_apply_revalidate`` once its outputs are
        fetched."""
        t0 = time.perf_counter()
        B = len(items)
        bclass = self._batch_class(B)
        tstats = self.stats.tier0 if tier == 0 else self.stats.tier1

        hits_before = self.stats.compile_cache_hits
        fn = self._executable_reval(bucket, bclass)
        compile_hit = self.stats.compile_cache_hits > hits_before

        reqs = [it.req for it in items]
        stored = list(carries)
        padded, carries = list(reqs), list(carries)
        if bclass > B:
            pad_req, pad_carry = self._pad_slot(bucket, reqs[0], carries[0])
            padded += [pad_req] * (bclass - B)
            carries += [pad_carry] * (bclass - B)
        Qb = np.stack([r.Qp for r in padded])
        Gb = np.stack([r.Gp for r in padded])
        maskb = np.stack([r.maskp for r in padded])
        carry0 = self._stack_carries(carries)
        if self.mesh is None and self._donate_argnums("reval"):
            self.stats.donated_launches += 1

        outs = fn(Qb, Gb, maskb, carry0)
        tstats.launches += 1
        tstats.checked += B
        return _LaunchRecord(kind="reval", bucket=bucket, items=items,
                             tier=tier, B=B, bclass=bclass,
                             compile_hit=compile_hit, outs=outs,
                             carries=stored, miss_sink=miss_sink, t0=t0)

    def _apply_revalidate(self, rec: _LaunchRecord, host: dict) -> None:
        """Consume one fetched revalidation launch: attach hit results,
        append misses to the record's sink (with their Tier-2 seeds),
        refresh stores. All array reads come from ``host`` or stay on
        device — this path never blocks."""
        tier, B, items = rec.tier, rec.B, rec.items
        bucket, carries = rec.bucket, rec.carries
        tstats = self.stats.tier0 if tier == 0 else self.stats.tier1
        # Tier 0 re-validates this problem's own carry (carried-f* gate);
        # Tier 1 additionally requires the rebased projection to clear the
        # fitness bound on THIS problem (stored f* isn't transferable)
        ok = np.asarray(host["ok" if tier == 0 else "ok_rebase"])
        maps = np.asarray(host["mapping"])
        # leaves outside this tier's _fetch_tree subset stay on device
        fits = host.get("fitness")
        S_rb = host.get("S_star")
        S_bar_rb = host.get("S_bar")
        f_carry = host.get("f_carry")
        sweeps = np.asarray(host["prune_sweeps"]).reshape(-1)
        self._note_prune(B, int(sweeps[:B].sum()))
        on_device = self.mesh is None
        done = time.perf_counter()

        tstats.wall_s += done - rec.t0
        for j, it in enumerate(items):
            it.latency_s = done - it.t0
            if not ok[j]:
                if tier == 1:
                    # rebased controller state seeds the Tier-2 swarm;
                    # keep it device-resident (slices of the launch
                    # outputs) so the swarm stack never touches host
                    if on_device:
                        it.seed = (rec.outs["S_star"][j],
                                   np.float32(-np.inf),
                                   rec.outs["S_bar"][j])
                    else:
                        it.seed = (S_rb[j], np.float32(-np.inf),
                                   S_bar_rb[j])
                rec.miss_sink.append(it)
                continue
            tstats.hits += 1
            self.stats.carry_fastpath_hits += 1
            self.stats.found += 1
            if tier == 0:
                # the stored carry revalidated: it stays in the store
                # untouched; its f* comes from the output echo, not a
                # per-item device read, and the result's carry is a lazy
                # view — no pool slicing unless the caller looks at it
                carry = (_LazyCarry(carries[j])
                         if isinstance(carries[j], _CarryHandle)
                         else self._carry_tuple(carries[j]))
                f_res = float(f_carry[j])
            else:
                carry = (S_rb[j], fits[j], S_bar_rb[j])
                f_res = float(fits[j])
                if self.warm_start:
                    stored = self._pool.put(
                        (rec.outs["S_star"][j], rec.outs["fitness"][j],
                         rec.outs["S_bar"][j])) if on_device else carry
                    self._put_carry(it.warm_key, stored)
                    if it.req.engine_sig is not None:
                        self._carries.put_similar(it.req.qdigest, bucket,
                                                  it.req.engine_sig,
                                                  stored)
            it.result = self._revalidated_result(
                it, maps[j], f_res, carry, tier=tier, batch=B,
                compile_hit=rec.compile_hit, prune_sweeps=int(sweeps[j]))

    def _revalidated_result(self, item: _PipelineItem, M_c: np.ndarray,
                            f_res: float, carry, *, tier: int, batch: int,
                            compile_hit: bool, prune_sweeps: int = 0
                            ) -> ServiceMatchResult:
        """Host-side result for a request served by revalidation alone —
        the 0-epoch equivalent of what ``collect_result`` produces when
        the in-kernel fast path skipped every epoch."""
        req, cfg = item.req, self.cfg
        n, m = req.crop
        M = np.asarray(M_c)[:n, :m]
        unperm = np.empty_like(M)
        unperm[req.order, :] = M
        return ServiceMatchResult(
            mapping=unperm,
            feasible_count=0,
            f_star=f_res,
            f_star_trace=np.full((cfg.epochs, cfg.inner_steps), f_res,
                                 np.float32),
            all_mappings=np.zeros((0, n, m), np.uint8),
            all_feasible=np.zeros((0,), bool),
            all_fitness=np.zeros((0,), np.float32),
            carry=carry, epochs_run=0, carry_verified=True,
            prune_sweeps=prune_sweeps,
            bucket=req.bucket, compile_cache_hit=compile_hit,
            warm_hit=item.warm_hit, batch_size=batch,
            coalesced=batch > 1, tier=tier)

    # -- batch launches ----------------------------------------------------

    def _pad_slot(self, bucket, like: _PendingRequest, like_carry
                  ) -> Tuple[_PendingRequest, tuple]:
        """Pad filler for a batch launch: a trivial problem whose carry
        re-validates in epoch 0, so ``scan_epochs_batch`` freezes the pad
        slots immediately instead of re-burning a real problem's epoch
        budget (the old behaviour replicated problem 0 verbatim). Falls
        back to that replication (slot 0's problem AND carry, so the pad
        mirrors its trajectory exactly) for the degenerate n_pad > m_pad
        buckets where no injective trivial mask exists."""
        n_pad, m_pad = bucket
        if m_pad < n_pad:
            return like, like_carry
        Qp = np.zeros((n_pad, n_pad), dtype=like.Qp.dtype)
        Gp = np.zeros((m_pad, m_pad), dtype=like.Gp.dtype)
        maskp = np.zeros((n_pad, m_pad), dtype=like.maskp.dtype)
        idx = np.arange(n_pad)
        maskp[idx, idx] = 1
        S_id = np.zeros((n_pad, m_pad), np.float32)
        S_id[idx, idx] = 1.0
        # f* = +inf clears ANY early_exit_fitness bound, so the pad slot
        # is pre-finished regardless of the configured threshold
        if self.mesh is None:
            carry = self._pad_handles.get(bucket)
            if carry is None:
                carry = self._pool.put((S_id, np.float32(np.inf), S_id))
                carry.retain()     # pinned: pads recur on every drain
                self._pad_handles[bucket] = carry
        else:
            carry = (S_id, np.float32(np.inf), S_id.copy())
        req = _PendingRequest(key=like.key, workload_key=None,
                              order=np.arange(n_pad),
                              crop=(n_pad, m_pad), bucket=bucket,
                              Qp=Qp, Gp=Gp, maskp=maskp)
        return req, carry

    def _launch_swarm(self, bucket, items: List[_PipelineItem]) -> None:
        """One *serial* Tier-2 swarm launch over the pipeline's residual
        items: dispatch, then a blocking fetch of just this launch's
        outputs (the one-sync-per-launch baseline arm)."""
        rec = self._dispatch_swarm(bucket, items)
        self._apply_swarm(rec, self._sync_fetch(rec.outs))

    def _dispatch_swarm(self, bucket, items: List[_PipelineItem]
                        ) -> _LaunchRecord:
        """Enqueue one Tier-2 swarm launch (no host sync) over items
        whose carries are already resolved: failed exact carry, rebased
        neighbour seed, or the cold prior."""
        t0 = time.perf_counter()
        B = len(items)
        bclass = self._batch_class(B)

        hits_before = self.stats.compile_cache_hits
        fn = self._executable_batch(bucket, bclass)
        compile_hit = self.stats.compile_cache_hits > hits_before

        reqs = [it.req for it in items]
        carries = []
        for it in items:
            if it.carry is not None:
                carries.append(it.carry)
            elif it.seed is not None:
                carries.append(it.seed)
            else:
                carries.append(pso.default_carry(jnp.asarray(it.req.maskp)))

        pad = bclass - B
        padded = list(reqs)
        if pad:
            pad_req, pad_carry = self._pad_slot(bucket, reqs[0], carries[0])
            padded += [pad_req] * pad
            carries = carries + [pad_carry] * pad
            if pad_req is not reqs[0] and self.cfg.early_exit \
                    and self.cfg.carry_fastpath:
                self.stats.pad_slots_frozen += pad
        if self.mesh is None:
            # PRNG keys are device arrays: stack them device-side instead
            # of round-tripping each through np.asarray (a hidden sync)
            keysb = jnp.stack([jnp.asarray(r.key) for r in padded])
        else:
            keysb = np.stack([np.asarray(r.key) for r in padded])
        Qb = np.stack([r.Qp for r in padded])
        Gb = np.stack([r.Gp for r in padded])
        maskb = np.stack([r.maskp for r in padded])
        carry0 = self._stack_carries(carries)
        if self.mesh is None and self._donate_argnums("batch"):
            self.stats.donated_launches += 1

        outs = fn(keysb, Qb, Gb, maskb, carry0)
        self.stats.batch_launches += 1
        self.stats.batch_problems += B
        self.stats.batch_slots += bclass
        self.stats.tier2.launches += 1
        self.stats.epoch_fused_launches += 1
        self.stats.epoch_finish_launches += 1
        self.stats.epoch_finish_problems += B
        self.stats.tier2.checked += B
        return _LaunchRecord(kind="swarm", bucket=bucket, items=items,
                             tier=2, B=B, bclass=bclass,
                             compile_hit=compile_hit, outs=outs,
                             padded=padded, t0=t0)

    def _apply_swarm(self, rec: _LaunchRecord, host: dict) -> None:
        """Consume one fetched swarm launch: build per-item results from
        the host outputs, store the still-on-device controller state for
        future warm starts."""
        items, B, padded = rec.items, rec.B, rec.padded
        batch_results = collect_batch_results(
            host, rec.bclass,
            orders=[r.order for r in padded],
            crops=[r.crop for r in padded])
        done = time.perf_counter()
        on_device = self.mesh is None

        self.stats.tier2.wall_s += done - rec.t0
        for j, it in enumerate(items):
            base = batch_results[j]
            res = ServiceMatchResult(
                **{f.name: getattr(base, f.name)
                   for f in dataclasses.fields(MatchResult)})
            dev_carry = (rec.outs["S_star"][j], rec.outs["f_star"][j],
                         rec.outs["S_bar"][j]) if on_device else None
            self._store_result_carries(it.req, it.warm_key, res,
                                       dev_carry=dev_carry)
            self.stats.epochs_run += res.epochs_run
            self._note_prune(1, res.prune_sweeps)
            if res.found:
                self.stats.found += 1
                self.stats.tier2.hits += 1
            if res.carry_verified:
                self.stats.carry_fastpath_hits += 1
            res.bucket = rec.bucket
            res.compile_cache_hit = rec.compile_hit
            res.warm_hit = it.warm_hit
            res.batch_size = B
            res.coalesced = B > 1
            res.tier = 2
            # end-to-end drain latency: a Tier-2 request also waited out
            # every pipeline launch that preceded this one
            it.latency_s = done - it.t0
            it.result = res

    def _launch_batch_legacy(self, bucket, reqs: List[_PendingRequest],
                             tickets: List[int], results: List) -> None:
        """The untiered (PR-2) drain path: every request goes straight to
        one uniform swarm launch. Kept as the ``tiered=False`` baseline —
        `benchmarks/bench_tiers.py` measures the pipeline against it."""
        items = self._intake(reqs, tickets)
        self._launch_swarm(bucket, items)
        for it in items:
            it.result.latency_s = it.latency_s
            results[it.ticket] = it.result

    # -- reporting ---------------------------------------------------------

    def stats_dict(self) -> Dict[str, float]:
        """Flat ``{counter: value}`` export of :class:`ServiceStats`
        plus derived rates and per-tier breakdowns — the payload
        ``SimResult.matcher_stats`` surfaces (see the README stats
        glossary for per-key meanings)."""
        s = self.stats
        out = {
            "calls": s.calls,
            "compile_cache_hits": s.compile_cache_hits,
            "compile_cache_misses": s.compile_cache_misses,
            "compile_hit_rate": s.compile_hit_rate,
            "warm_hits": s.warm_hits,
            "warm_misses": s.warm_misses,
            "warm_hit_rate": s.warm_hit_rate,
            "epochs_run": s.epochs_run,
            "epochs_budgeted": s.epochs_budgeted,
            "epochs_saved": s.epochs_saved,
            "epoch_fused_launches": s.epoch_fused_launches,
            "epoch_finish_launches": s.epoch_finish_launches,
            "epoch_finish_problems": s.epoch_finish_problems,
            "epoch_backend": kernel_backend.resolve_backend_name(
                self.cfg.backend),
            "found": s.found,
            "batch_launches": s.batch_launches,
            "coalesced_requests": s.coalesced_requests,
            "batch_problems": s.batch_problems,
            "batch_slots": s.batch_slots,
            "batch_occupancy": s.batch_occupancy,
            "carry_fastpath_hits": s.carry_fastpath_hits,
            "revalidated_rate": s.revalidated_rate,
            "pad_slots_frozen": s.pad_slots_frozen,
            "prune_problems": s.prune_problems,
            "prune_sweeps": s.prune_sweeps,
            "avg_prune_sweeps": s.avg_prune_sweeps,
            "sim_lookups": s.sim_lookups,
            "sim_neighbor_hits": s.sim_neighbor_hits,
            "sim_evictions": s.sim_evictions,
            "sim_entries": self._carries.sim_entries,
            "jit_traces": s.jit_traces,
            "aot_cache_hits": s.aot_cache_hits,
            "aot_cache_misses": s.aot_cache_misses,
            "aot_exports": s.aot_exports,
            "aot_export_failures": s.aot_export_failures,
            "aot_call_fallbacks": s.aot_call_fallbacks,
            "snapshot_saves": s.snapshot_saves,
            "snapshot_restores": s.snapshot_restores,
            "snapshot_stale_skipped": s.snapshot_stale_skipped,
            "snapshot_skipped_keys": s.snapshot_skipped_keys,
            "restored_carries": s.restored_carries,
            "restored_sim_entries": s.restored_sim_entries,
            "fe_submitted": s.fe_submitted,
            "fe_admitted": s.fe_admitted,
            "fe_shed": s.fe_shed,
            "fe_forced_drains": s.fe_forced_drains,
            "fe_drains": s.fe_drains,
            "fe_drain_deadline": s.fe_drain_deadline,
            "fe_drain_batch_full": s.fe_drain_batch_full,
            "fe_drain_flush": s.fe_drain_flush,
            "fe_queue_peak": s.fe_queue_peak,
            "fe_wait_s": s.fe_wait_s,
            "drains": s.drains,
            "host_syncs": s.host_syncs,
            "host_syncs_per_drain": s.host_syncs_per_drain,
            "host_bytes_transferred": s.host_bytes_transferred,
            "host_sync_wall_s": s.host_sync_wall_s,
            "donated_launches": s.donated_launches,
            "pool_puts": self._pool.puts,
            "pool_gathers": self._pool.gathers,
            "pool_live_rows": self._pool.live_rows,
        }
        for name in ("tier0", "tier1", "tier2"):
            t: TierStats = getattr(s, name)
            out[f"{name}_launches"] = t.launches
            out[f"{name}_checked"] = t.checked
            out[f"{name}_hits"] = t.hits
            out[f"{name}_hit_rate"] = t.hit_rate
            out[f"{name}_wall_s"] = t.wall_s
        return out


@dataclasses.dataclass
class _QueuedRequest:
    rid: int
    query: Graph
    target: Graph
    deadline: float
    enqueued_at: float
    key: Optional[jax.Array] = None
    workload_key: object = None
    engine_sig: Optional[bytes] = None


class AsyncServiceFrontEnd:
    """Admission-controlled arrival queue in front of a MatcherService.

    ``MatcherService.submit``/``drain`` are caller-driven: whoever
    submits must also decide when to flush, so under sustained load the
    queue either grows without bound or gets drained one request at a
    time. This front end owns that decision. Requests enter a bounded
    queue (``max_depth``); when it is full the ``policy`` either
    **sheds** the new request (recorded, result ``None``) or **blocks**
    it by forcing a drain round to make room first. A queued batch is
    drained through the service's tiered pipeline when either

      * the queue can fill the service's largest batch class
        (``batch_classes[-1]`` requests queued) — launch-shaped, or
      * the *oldest* queued request's slack ``deadline - now`` falls to
        ``slack_threshold_s`` — deadline-shaped (checked at submit time
        and by ``poll``), or
      * the caller explicitly ``flush``\\ es.

    Every trigger reason, shed, forced drain, queue peak, and cumulative
    queue wait flows into the service's ``ServiceStats`` (``fe_*`` keys
    of ``stats_dict()``), so ``SimResult.matcher_stats`` →
    ``metrics.frontend_stats`` report it per run.

    Time is an explicit ``now`` parameter everywhere (falling back to
    ``clock()``), so the front end drops into the event-driven simulator
    — which advances virtual time — as readily as onto a wall clock.
    """

    def __init__(self, service: MatcherService, *, max_depth: int = 64,
                 policy: str = "shed", slack_threshold_s: float = 0.0,
                 clock=time.perf_counter):
        assert policy in ("shed", "block"), policy
        assert max_depth >= 1
        self.service = service
        self.max_depth = int(max_depth)
        self.policy = policy
        self.slack_threshold_s = float(slack_threshold_s)
        self._clock = clock
        self._queue: List[_QueuedRequest] = []
        self._results: Dict[int, Optional[ServiceMatchResult]] = {}
        self._next_rid = 0

    # -- observables ---------------------------------------------------

    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet drained)."""
        return len(self._queue)

    def next_deadline_check(self) -> float:
        """Earliest instant the deadline trigger could fire (the oldest
        queued deadline minus the slack threshold); +inf when idle. An
        event-driven host schedules its next ``poll`` here."""
        if not self._queue:
            return float("inf")
        return min(q.deadline for q in self._queue) - self.slack_threshold_s

    # -- request path --------------------------------------------------

    def submit(self, query: Graph, target: Graph, *,
               deadline: float = float("inf"),
               now: Optional[float] = None,
               key: Optional[jax.Array] = None, workload_key=None,
               engine_sig: Optional[bytes] = None) -> int:
        """Offer a request; returns a request id for ``take_result``.

        A shed request (queue full under the shed policy) still gets an
        id — its result is recorded as ``None`` immediately.
        """
        now = self._clock() if now is None else now
        stats = self.service.stats
        rid = self._next_rid
        self._next_rid += 1
        stats.fe_submitted += 1
        if len(self._queue) >= self.max_depth:
            if self.policy == "shed":
                stats.fe_shed += 1
                self._results[rid] = None
                return rid
            stats.fe_forced_drains += 1
            self._drain(now, "batch_full")
        self._queue.append(_QueuedRequest(
            rid=rid, query=query, target=target, deadline=float(deadline),
            enqueued_at=now, key=key, workload_key=workload_key,
            engine_sig=engine_sig))
        stats.fe_admitted += 1
        stats.fe_queue_peak = max(stats.fe_queue_peak, len(self._queue))
        self._check_triggers(now)
        return rid

    def poll(self, now: Optional[float] = None) -> int:
        """Fire any due drain trigger; returns requests drained (0 if
        none due). Hosts call this when time passes without submits —
        e.g. at ``next_deadline_check()``."""
        now = self._clock() if now is None else now
        return self._check_triggers(now)

    def flush(self, now: Optional[float] = None) -> int:
        """Drain everything queued regardless of triggers."""
        now = self._clock() if now is None else now
        return self._drain(now, "flush")

    def take_result(self, rid: int) -> Optional[ServiceMatchResult]:
        """Pop the result for ``rid``: a ``ServiceMatchResult``, or
        ``None`` if the request was shed. Raises ``KeyError`` while the
        request is still queued (not drained yet)."""
        return self._results.pop(rid)

    # -- internals -----------------------------------------------------

    def _check_triggers(self, now: float) -> int:
        if not self._queue:
            return 0
        if len(self._queue) >= self.service.batch_classes[-1]:
            return self._drain(now, "batch_full")
        oldest_slack = min(q.deadline for q in self._queue) - now
        if oldest_slack <= self.slack_threshold_s:
            return self._drain(now, "deadline")
        return 0

    def _drain(self, now: float, reason: str) -> int:
        if not self._queue:
            return 0
        stats = self.service.stats
        stats.fe_drains += 1
        setattr(stats, f"fe_drain_{reason}",
                getattr(stats, f"fe_drain_{reason}") + 1)
        batch, self._queue = self._queue, []
        tickets = [self.service.submit(q.query, q.target, key=q.key,
                                       workload_key=q.workload_key,
                                       engine_sig=q.engine_sig)
                   for q in batch]
        results = self.service.drain()
        for q, ticket in zip(batch, tickets):
            self._results[q.rid] = results[ticket]
            stats.fe_wait_s += max(now - q.enqueued_at, 0.0)
        return len(batch)
