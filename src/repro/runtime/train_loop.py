"""Training step factory: loss, gradient accumulation, optimizer, sharding.

The step is a single XLA program:
  * causal-LM cross-entropy computed on *tensor-sharded* logits (the vocab
    axis never materializes unsharded — with 128k–256k vocabularies this is
    the difference between fitting and OOM);
  * gradient accumulation as a ``lax.scan`` over microbatches — under FSDP
    sharding XLA overlaps each microbatch's reduce-scatter with the next
    microbatch's compute (latency-hiding scheduler);
  * optimizer states inherit parameter shardings (ZeRO-3);
  * optional int8 error-feedback gradient compression on the DP axis
    (explicit shard_map reduction, see optim.grad_compress).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import BuiltModel
from repro.optim import get_optimizer
from repro.optim.schedule import warmup_cosine
from repro.runtime import sharding as shd


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 1e-4, mesh: Optional[Mesh] = None,
                       profile: str = "2d"):
    """Mean token cross-entropy (+ z-loss). logits may be vocab-sharded;
    the log-sum-exp reductions stay sharded under GSPMD."""
    if mesh is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, shd.logits_spec(mesh, profile)))
    logits = logits.astype(jnp.float32)
    # align: some families prepend non-text positions (vlm patches)
    S = labels.shape[1]
    logits = logits[:, -S:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    if z_loss:
        loss = loss + z_loss * ((lse * mask) ** 2).sum() / denom
    return loss


def make_train_state(model: BuiltModel, train_cfg: TrainConfig,
                     key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    opt = get_optimizer(train_cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(state, mesh: Mesh, profile: str = "2d"):
    return {
        "params": shd.infer_param_specs(state["params"], mesh, profile),
        "opt": shd.infer_param_specs(state["opt"], mesh, profile),
        "step": P(),
    }


def make_train_step(model: BuiltModel, train_cfg: TrainConfig,
                    mesh: Optional[Mesh] = None, profile: str = "2d"):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` leaves have leading dim ``global_batch``; with
    ``train_cfg.microbatches > 1`` they are split and scanned.
    """
    cfg = model.cfg
    opt = get_optimizer(train_cfg)
    lr_fn = warmup_cosine(train_cfg.learning_rate, train_cfg.warmup_steps,
                          train_cfg.total_steps)
    M = train_cfg.microbatches

    def loss_fn(params, mb):
        from repro.runtime.mesh_ctx import mesh_context
        with mesh_context(mesh, profile):
            logits = model.train_logits(params, mb)
        return cross_entropy_loss(logits, mb["labels"], train_cfg.z_loss,
                                  mesh, profile)

    def constrain_like_params(tree, params):
        """Pin gradient(-accumulator) sharding to the parameter sharding —
        without this the microbatch-scan carry defaults to replicated and
        the f32 accumulator of a 480B model is ~1.9 TB *per device* (caught
        by the dry-run memory analysis; see EXPERIMENTS.md §Perf)."""
        if mesh is None:
            return tree
        specs = shd.infer_param_specs(params, mesh, profile)
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)),
            tree, specs)

    def grads_of(params, batch):
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, constrain_like_params(grads, params)

        def split(name, x):
            if name == "positions3":   # (3, B, S): batch is axis 1
                return jnp.moveaxis(
                    x.reshape(3, M, x.shape[1] // M, *x.shape[2:]), 0, 1)
            return x.reshape(M, x.shape[0] // M, *x.shape[1:])

        mbs = {k: split(k, v) for k, v in batch.items()}
        zero = constrain_like_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            params)

        def body(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            g_acc = constrain_like_params(g_acc, params)
            return (loss_acc + loss, g_acc), None

        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero),
                                            mbs)
        grads = jax.tree.map(lambda g: g / M, grads)
        return loss_sum / M, grads

    def train_step(state, batch):
        params = state["params"]
        loss, grads = grads_of(params, batch)
        # global-norm clip. NOTE: sum-of-squares per leaf, NOT vdot —
        # vdot ravels each grad, and flattening a sharded tensor forces a
        # full all-gather (f32 grads replicated per device: +1.9 TB/device
        # on arctic-480b; caught by the dry-run — EXPERIMENTS.md §Perf).
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, train_cfg.grad_clip
                            / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g * scale).astype(jnp.float32),
                             grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def jit_train_step(model: BuiltModel, train_cfg: TrainConfig, mesh: Mesh,
                   state, batch_specs):
    """jit with explicit in/out shardings for the dry-run and launcher."""
    step = make_train_step(model, train_cfg, mesh)
    sspecs = state_specs(state, mesh)
    in_sh = (shd.named(sspecs, mesh), shd.named(batch_specs, mesh))
    out_sh = (shd.named(sspecs, mesh), None)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0,))
