"""Docs integrity: public-seam docstrings (AST-enforced) + markdown
reference checking.

Two failure classes this file exists to catch early:

  * a public seam (service, kernel registry, checkpoint manager, PSO
    config) growing an undocumented method/field — the docstring pass
    is enforced structurally, pydocstyle-style, so it cannot rot;
  * a markdown doc referencing a file that does not exist (the classic
    "README links EXPERIMENTS.md which was never written"). Authored
    docs are checked for both ``[text](path)`` links and backticked
    repo paths; PAPERS.md / SNIPPETS.md are excluded as verbatim
    retrieval artifacts (their image refs point into the source
    archives, not this repo).
"""
import ast
import inspect
import os
import re
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Authored documentation subject to reference checking.
DOC_FILES = ("README.md", "ROADMAP.md", "EXPERIMENTS.md", "PAPER.md",
             "CHANGES.md")
DOC_DIRS = ("docs",)

#: Roots a backticked repo path may be relative to.
PATH_ROOTS = (".", "src", "src/repro")

MIN_DOC_LEN = 20


def _authored_docs():
    out = [os.path.join(REPO, f) for f in DOC_FILES
           if os.path.exists(os.path.join(REPO, f))]
    for d in DOC_DIRS:
        full = os.path.join(REPO, d)
        if os.path.isdir(full):
            out.extend(os.path.join(full, f) for f in sorted(os.listdir(full))
                       if f.endswith(".md"))
    return out


# ---------------------------------------------------------------------------
# docstring pass (AST-enforced, pydocstyle-style)
# ---------------------------------------------------------------------------

def _public_methods_missing_docstrings(cls):
    src = textwrap.dedent(inspect.getsource(cls))
    tree = ast.parse(src).body[0]
    missing = []
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name.startswith("_") and node.name != "__init__":
            continue
        doc = ast.get_docstring(node)
        if node.name == "__init__":
            # documented on the class itself
            continue
        if not doc or len(doc.strip()) < MIN_DOC_LEN:
            missing.append(node.name)
    return missing


SEAM_CLASSES = [
    ("repro.core.service", "MatcherService"),
    ("repro.core.service", "CarryStore"),
    ("repro.core.service", "ServiceStats"),
    ("repro.kernels.backend", "KernelBackend"),
    ("repro.checkpoint.manager", "CheckpointManager"),
    ("repro.core.persist", "AOTCache"),
]

SEAM_FUNCTIONS = [
    ("repro.kernels.backend", "for_config"),
    ("repro.kernels.backend", "get_backend"),
    ("repro.kernels.backend", "register_backend"),
    ("repro.kernels.backend", "resolve_backend_name"),
    ("repro.kernels.backend", "config_digest"),
    ("repro.core.persist", "enable_jax_compilation_cache"),
    ("repro.sched.metrics", "warm_restart_stats"),
    ("repro.sched.tasks", "make_restart_scenario"),
]


@pytest.mark.parametrize("module,name", SEAM_CLASSES,
                         ids=[f"{m}.{n}" for m, n in SEAM_CLASSES])
def test_public_seam_class_docstrings(module, name):
    import importlib
    cls = getattr(importlib.import_module(module), name)
    doc = inspect.getdoc(cls)
    assert doc and len(doc) >= MIN_DOC_LEN, \
        f"{module}.{name} needs a class docstring"
    missing = _public_methods_missing_docstrings(cls)
    assert not missing, \
        f"{module}.{name} public methods missing docstrings: {missing}"


@pytest.mark.parametrize("module,name", SEAM_FUNCTIONS,
                         ids=[f"{m}.{n}" for m, n in SEAM_FUNCTIONS])
def test_public_seam_function_docstrings(module, name):
    import importlib
    fn = getattr(importlib.import_module(module), name)
    doc = inspect.getdoc(fn)
    assert doc and len(doc) >= MIN_DOC_LEN, \
        f"{module}.{name} needs a docstring"


def test_psoconfig_every_field_commented():
    """Each PSOConfig knob must carry an inline ``#`` comment (the
    class's field-level documentation convention)."""
    from repro.core import pso
    src = textwrap.dedent(inspect.getsource(pso.PSOConfig))
    assert ast.get_docstring(ast.parse(src).body[0]), \
        "PSOConfig needs a class docstring"
    lines = src.splitlines()
    tree = ast.parse(src).body[0]
    fields = [n for n in tree.body if isinstance(n, ast.AnnAssign)]
    starts = [f.lineno for f in fields]
    uncommented = []
    for f, start in zip(fields, starts):
        nxt = min((s for s in starts if s > start),
                  default=len(lines) + 1)
        block = lines[start - 1:nxt - 1]
        if not any("#" in ln for ln in block):
            uncommented.append(f.target.id)
    assert not uncommented, \
        f"PSOConfig fields missing inline comments: {uncommented}"


def test_service_stats_table_matches_stats_dict():
    """Every ``restart_*``/``aot_*``/``snapshot_*``/``epoch_*`` counter
    the README documents must actually be emitted (service stats_dict or
    the scheduler's matcher_stats keys)."""
    from repro.core import pso
    from repro.core.service import MatcherService
    from repro.kernels.backend import KERNEL_NAMES
    emitted = set(MatcherService(pso.PSOConfig(
        num_particles=4, epochs=1, inner_steps=2)).stats_dict())
    emitted |= {"restart_count", "restart_restored_carries",
                "restart_restored_sim_entries",
                "restart_restored_posterior_buckets",
                "restart_restored_state_sigs",
                "restart_snapshots_saved", "restart_boot_restores"}
    readme = open(os.path.join(REPO, "README.md")).read()
    documented = set(re.findall(
        r"`((?:restart|aot|snapshot|jit|epoch)_[a-z_]+)`", readme))
    # kernel entry points share the epoch_ prefix but are not counters
    documented -= set(KERNEL_NAMES)
    assert documented, "README should document the persistence counters"
    unknown = documented - emitted
    assert not unknown, \
        f"README documents counters that are never emitted: {sorted(unknown)}"


# ---------------------------------------------------------------------------
# markdown reference integrity
# ---------------------------------------------------------------------------

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TICK_RE = re.compile(r"`([^`\n]+)`")
_SKIP_SCHEMES = ("http://", "https://", "mailto:")


def _resolve(base_dir, target):
    cands = [os.path.normpath(os.path.join(base_dir, target))]
    for root in PATH_ROOTS:
        cands.append(os.path.normpath(os.path.join(REPO, root, target)))
    return any(os.path.exists(c) for c in cands)


def test_experiments_md_exists():
    assert os.path.exists(os.path.join(REPO, "EXPERIMENTS.md")), \
        "README references EXPERIMENTS.md — it must exist"
    assert os.path.isdir(os.path.join(REPO, "docs")), \
        "docs/ARCHITECTURE.md suite missing"
    assert os.path.exists(os.path.join(REPO, "docs", "ARCHITECTURE.md"))


def test_markdown_links_resolve():
    broken = []
    for path in _authored_docs():
        base = os.path.dirname(path)
        for m in _LINK_RE.finditer(open(path).read()):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(_SKIP_SCHEMES):
                continue
            if not _resolve(base, target):
                broken.append((os.path.basename(path), m.group(1)))
    assert not broken, f"broken markdown links: {broken}"


def test_markdown_backticked_paths_exist():
    """Backticked tokens that look like repo paths (contain a ``/``,
    plain path characters only) must exist relative to the doc, the
    repo root, ``src/`` or ``src/repro/`` — catches prose references to
    renamed/deleted files that plain link-checking misses."""
    pathish = re.compile(r"^[A-Za-z0-9_.\-/]+$")
    broken = []
    for path in _authored_docs():
        base = os.path.dirname(path)
        for m in _TICK_RE.finditer(open(path).read()):
            tok = m.group(1).split("::")[0].rstrip(",:;")
            if "/" not in tok or not pathish.match(tok):
                continue
            if "*" in tok or tok.endswith("/-"):
                continue
            if tok.startswith("/"):
                # absolute tokens describe the runtime environment
                # (e.g. container mounts), not files this repo ships
                continue
            if not _resolve(base, tok):
                broken.append((os.path.basename(path), tok))
    assert not broken, f"backticked paths that do not exist: {broken}"
