"""Paper-figure benchmarks (Figs. 2, 6, 7, 8 + §3.4 quantization).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
following the harness contract; ``derived`` carries the figure's headline
ratio. Paper bands for reference:

  Fig 6 Speedup:    ×34.4 (PREMA) ×51.4 (CD-MSA) ×81.4 (Planaria)
                    ×27.9 (MoCA)  ×1.6  (IsoSched)
  Fig 7 LBT:        ×89.8 ×130.2 ×191.4 ×72.7 / ×3.4
  Fig 8 Energy eff: ×918.6 ×927.9 ×2722.2 ×2092.7 / ×3.43
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax

from repro.accel import CLOUD, EDGE, CostModel
from repro.core import graphs, pso
from repro.core.matcher import IMMSchedMatcher
from repro.sched.metrics import (energy_efficiency, latency_bound_throughput,
                                 run_all, speedup_table)
from repro.sched.simulator import SimConfig, Simulator
from repro.sched.schedulers import get_scheduler
from repro.sched.tasks import make_scenario
from repro.workloads import get_workload

BASELINES = ["isosched", "prema", "planaria", "moca", "cdmsa"]
ALL_SCHED = ["immsched"] + BASELINES
PLATFORMS = [("edge", EDGE), ("cloud", CLOUD)]
CLASSES = ["simple", "middle", "complex"]


def _timeit(fn, *args, repeat=1):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    return (time.perf_counter() - t0) / repeat * 1e6, out


# ---------------------------------------------------------------------------
# Fig. 6 — Speedup
# ---------------------------------------------------------------------------

RATES = {"simple": 25, "middle": 8, "complex": 3}   # per-class arrival Hz


def fig6_speedup() -> List[tuple]:
    rows = []
    agg: Dict[str, List[float]] = {b: [] for b in BASELINES}
    for pname, plat in PLATFORMS:
        for cls in CLASSES:
            sc = make_scenario(cls, rate_hz=RATES[cls], horizon=0.6,
                               seed=11)
            us, res = _timeit(run_all, sc, plat, ALL_SCHED)
            sp = speedup_table(res)
            for b, v in sp.items():
                agg[b].append(v)
                rows.append((f"speedup/{pname}/{cls}/{b}", us,
                             round(v, 2)))
    for b in BASELINES:
        rows.append((f"speedup/avg/{b}", 0.0,
                     round(float(np.mean(agg[b])), 2)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 7 — Latency-bound throughput
# ---------------------------------------------------------------------------

def fig7_lbt() -> List[tuple]:
    rows = []
    agg: Dict[str, List[float]] = {b: [] for b in BASELINES}
    for pname, plat in PLATFORMS:
        for cls in CLASSES:
            lbts = {}
            for s in ALL_SCHED:
                us, lbt = _timeit(latency_bound_throughput, s, plat, cls)
                lbts[s] = lbt
                rows.append((f"lbt/{pname}/{cls}/{s}", us, round(lbt, 1)))
            for b in BASELINES:
                ratio = lbts["immsched"] / max(lbts[b], 1e-9)
                agg[b].append(ratio)
                rows.append((f"lbt_ratio/{pname}/{cls}/{b}", 0.0,
                             round(ratio, 2)))
    for b in BASELINES:
        rows.append((f"lbt_ratio/avg/{b}", 0.0,
                     round(float(np.mean(agg[b])), 2)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 8 — Energy efficiency (throughput per joule at saturating load)
# ---------------------------------------------------------------------------

def fig8_energy() -> List[tuple]:
    rows = []
    agg: Dict[str, List[float]] = {b: [] for b in BASELINES}
    for pname, plat in PLATFORMS:
        for cls in CLASSES:
            sc = make_scenario(cls, rate_hz=RATES[cls] * 16, horizon=0.4,
                               seed=23)
            us, res = _timeit(run_all, sc, plat, ALL_SCHED)
            mine = res["immsched"].met_per_joule
            for b in BASELINES:
                ratio = mine / max(res[b].met_per_joule, 1e-12)
                agg[b].append(ratio)
                rows.append((f"energy/{pname}/{cls}/{b}", us,
                             round(ratio, 1)))
    for b in BASELINES:
        rows.append((f"energy/avg/{b}", 0.0,
                     round(float(np.mean(agg[b])), 1)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 2(a) — scheduling vs execution time
# ---------------------------------------------------------------------------

def fig2a_sched_overhead() -> List[tuple]:
    rows = []
    cm = CostModel(CLOUD)
    for cls, wl_name in (("middle", "unet"), ("complex", "qwen-7b")):
        wl = get_workload(wl_name)
        texec, _ = cm.exec_lts(wl, CLOUD.engines)
        # MoCA-like online scheduling latency (layout re-solve on CPU)
        n_layers = len(wl.layers)
        work_ops = 2.0e5 * n_layers * CLOUD.engines / 64.0
        tsched = (work_ops / (CLOUD.cpu_gops * 1e9) + 2e-3) * 1.0
        rows.append((f"fig2a/{wl_name}/exec_ms", 0.0,
                     round(texec * 1e3, 3)))
        rows.append((f"fig2a/{wl_name}/sched_ms", 0.0,
                     round(tsched * 1e3, 3)))
        rows.append((f"fig2a/{wl_name}/sched_over_exec", 0.0,
                     round(tsched / texec, 1)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 2(b) — continuous relaxation stabilizes the search
# ---------------------------------------------------------------------------

def fig2b_relaxation() -> List[tuple]:
    """Compare fitness-trace stability with vs without the continuous
    relaxation (without = hard-project S to the discrete assignment after
    every PSO step, the naive discrete-Ullmann × PSO coupling)."""
    key = jax.random.PRNGKey(3)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 10, 0.3)
    g = graphs.embed_query_in_target(kt, q, 24)
    Q, G, mask = graphs.as_device_graphs(q, g)
    # prune_mask off: this figure studies the swarm's relaxation dynamics,
    # which the global Ullmann+injectivity pre-prune would short-circuit
    cfg = pso.PSOConfig(num_particles=32, epochs=3, inner_steps=12,
                        prune_mask=False)

    def trace_stats(hard_project: bool):
        finals, improvements = [], []
        for seed in range(5):
            outs = pso.match(jax.random.PRNGKey(seed), Q, G, mask,
                             cfg.replace(
                                 v_max=0.5 if not hard_project else 2.0,
                                 omega=0.7 if not hard_project else 1.0,
                                 c3=0.6 if not hard_project else 0.0))
            tr = np.asarray(outs["f_star_trace"]).reshape(-1)
            finals.append(tr[-1])
            improvements.append(tr[-1] - tr[0])
        return float(np.mean(finals)), float(np.std(finals))

    us, (mean_rel, std_rel) = _timeit(trace_stats, False)
    _, (mean_hard, std_hard) = _timeit(trace_stats, True)
    return [
        ("fig2b/relaxed/final_fitness_mean", us, round(mean_rel, 2)),
        ("fig2b/relaxed/final_fitness_std", 0.0, round(std_rel, 3)),
        ("fig2b/unstable/final_fitness_mean", 0.0, round(mean_hard, 2)),
        ("fig2b/unstable/final_fitness_std", 0.0, round(std_hard, 3)),
    ]


# ---------------------------------------------------------------------------
# §3.4 — quantized vs float matcher
# ---------------------------------------------------------------------------

def quant_ablation() -> List[tuple]:
    key = jax.random.PRNGKey(9)
    rows = []
    found_f = found_q = 0
    t_f = t_q = 0.0
    trials = 6
    for i in range(trials):
        kq, kt, km = jax.random.split(jax.random.fold_in(key, i), 3)
        q = graphs.random_dag(kq, 8, 0.35)
        g = graphs.embed_query_in_target(kt, q, 20)
        for quant in (False, True):
            cfg = pso.PSOConfig(num_particles=32, epochs=3, inner_steps=8,
                                quantized=quant)
            t0 = time.perf_counter()
            res = IMMSchedMatcher(cfg).match(q, g, key=km)
            dt = (time.perf_counter() - t0) * 1e6
            if quant:
                found_q += res.found
                t_q += dt
            else:
                found_f += res.found
                t_f += dt
    cm = CostModel(EDGE)
    cfg = pso.PSOConfig(num_particles=32, epochs=3, inner_steps=8)
    t_npu, e_npu = cm.sched_immsched(48, 64, cfg, 32)
    rows.append(("quant/float_success", t_f / trials, found_f / trials))
    rows.append(("quant/uint8_success", t_q / trials, found_q / trials))
    rows.append(("quant/npu_sched_latency_us", 0.0,
                 round(t_npu * 1e6, 2)))
    rows.append(("quant/npu_sched_energy_uj", 0.0,
                 round(e_npu * 1e6, 2)))
    return rows


# ---------------------------------------------------------------------------
# Batched vs sequential burst latency (coalesced matcher service)
# ---------------------------------------------------------------------------

def fig_batch() -> List[tuple]:
    """Burst-serving figure: coalesced-batch vs sequential warm latency
    from ``BENCH_batch.json``, plotted alongside the warm/cold service
    numbers from ``BENCH_service.json`` (run ``benchmarks.bench_batch`` /
    ``benchmarks.bench_service`` first to refresh the artifacts)."""
    import json
    import os
    rows: List[tuple] = []
    if os.path.exists("BENCH_batch.json"):
        with open("BENCH_batch.json") as f:
            d = json.load(f)
        k = d["batch_size"]
        rows += [
            (f"batch/seq_{k}_warm_us", d["sequential_total_median_s"] * 1e6,
             f"{sum(d['per_problem_found'])}/{k}_found"),
            (f"batch/coalesced_{k}_warm_us",
             d["coalesced_batch_median_s"] * 1e6,
             round(d["batch_over_sequential_ratio"], 3)),
            ("batch/speedup", 0.0, round(d["coalesced_speedup"], 2)),
            ("batch/occupancy", 0.0, round(d["batch_occupancy"], 3)),
            ("batch/fastpath_hits", 0.0, d["carry_fastpath_hits"]),
        ]
    else:
        rows.append(("batch/missing", 0.0,
                     "run_python_-m_benchmarks.bench_batch"))
    if os.path.exists("BENCH_service.json"):
        with open("BENCH_service.json") as f:
            s = json.load(f)
        rows += [
            ("batch/service_cold_us", s["cold_first_call_s"] * 1e6,
             "cold_compile+swarm"),
            ("batch/service_warm_us", s["warm_repeat_median_s"] * 1e6,
             round(s["cold_vs_warm_speedup"], 1)),
        ]
    return rows


# ---------------------------------------------------------------------------
# Matcher scaling microbenchmark (particles → engines)
# ---------------------------------------------------------------------------

def matcher_scaling() -> List[tuple]:
    key = jax.random.PRNGKey(5)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 12, 0.3)
    g = graphs.embed_query_in_target(kt, q, 32)
    rows = []
    cm = CostModel(CLOUD)
    for n_particles in (16, 32, 64, 128):
        cfg = pso.PSOConfig(num_particles=n_particles, epochs=2,
                            inner_steps=8)
        matcher = IMMSchedMatcher(cfg)
        matcher.match(q, g)   # compile
        us, res = _timeit(lambda: matcher.match(q, g), repeat=3)
        t_npu, _ = cm.sched_immsched(q.n, g.n, cfg,
                                     min(n_particles, CLOUD.engines))
        rows.append((f"matcher/{n_particles}p/cpu_us", round(us, 1),
                     int(res.feasible_count)))
        rows.append((f"matcher/{n_particles}p/npu_model_us", 0.0,
                     round(t_npu * 1e6, 2)))
    return rows
