"""Public kernel API with backend dispatch and MXU-alignment padding.

Backends:
  "ref"       — jit'd pure-jnp oracle (ref.py). Default on CPU.
  "pallas"    — compiled Pallas TPU kernels. Default on TPU.
  "interpret" — Pallas kernels in interpret mode (CPU validation only).
  "auto"      — "pallas" on TPU else "ref".

All entry points accept *logical* (unpadded) shapes; padding to multiples of
128 (MXU tile) happens here and is provably exact for every kernel (zero
rows/cols contribute nothing — see per-kernel notes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.argmax_project import (greedy_project_pallas,
                                          masked_argmax_pallas)
from repro.kernels.epoch_fused import (epoch_fused_pallas,
                                       epoch_inner_reference)
from repro.kernels.finish_fused import (epoch_finish_pallas,
                                        epoch_finish_reference)
from repro.kernels.pso_fitness import (edge_fitness_pallas,
                                       edge_fitness_quantized_pallas)
from repro.kernels.prune_fixpoint import prune_fixpoint_pallas
from repro.kernels.pso_update import pso_update_pallas
from repro.kernels.ullmann_refine import ullmann_refine_step_pallas

MXU = 128


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def _pad_to(x: jax.Array, sizes: Tuple[int, ...]) -> jax.Array:
    """Zero-pad trailing dims of x up to the given sizes."""
    pads = [(0, 0)] * (x.ndim - len(sizes))
    pads += [(0, s - d) for s, d in zip(sizes, x.shape[x.ndim - len(sizes):])]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _round_up(v: int, mult: int = MXU) -> int:
    return ((v + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Fitness
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def edge_fitness(S: jax.Array, Q: jax.Array, G: jax.Array,
                 backend: str = "auto") -> jax.Array:
    """Batched fitness -||Q - S G S^T||^2. S: (B, n, m) -> (B,) f32."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return jax.vmap(ref.edge_fitness, in_axes=(0, None, None))(S, Q, G)
    n, m = S.shape[1], S.shape[2]
    np_, mp = _round_up(n), _round_up(m)
    Sp = _pad_to(S, (np_, mp))
    Qp = _pad_to(Q, (np_, np_))
    Gp = _pad_to(G, (mp, mp))
    return edge_fitness_pallas(Sp, Qp, Gp, interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("scale", "backend"))
def edge_fitness_quantized(S_q: jax.Array, Q: jax.Array, G: jax.Array,
                           scale: int = 255,
                           backend: str = "auto") -> jax.Array:
    """Fixed-point fitness (uint8 S, int32 accumulation). -> (B,) f32."""
    backend = resolve_backend(backend)
    if backend == "ref":
        f = jax.vmap(ref.edge_fitness_quantized,
                     in_axes=(0, None, None, None))(S_q, Q, G, scale)
        return f.astype(jnp.float32)
    n, m = S_q.shape[1], S_q.shape[2]
    np_, mp = _round_up(n), _round_up(m)
    Sp = _pad_to(S_q, (np_, mp))
    Qp = _pad_to(Q, (np_, np_))
    Gp = _pad_to(G, (mp, mp))
    return edge_fitness_quantized_pallas(
        Sp, Qp, Gp, scale=scale, interpret=(backend == "interpret"))


# ---------------------------------------------------------------------------
# Ullmann refinement
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def ullmann_refine_step(M: jax.Array, Q: jax.Array, G: jax.Array,
                        backend: str = "auto") -> jax.Array:
    """One refinement sweep, batched. M: (B, n, m) -> (B, n, m)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return jax.vmap(ref.ullmann_refine_step,
                        in_axes=(0, None, None))(M, Q, G)
    B, n, m = M.shape
    np_, mp = _round_up(n), _round_up(m)
    Mp = _pad_to(M, (np_, mp))
    Qp = _pad_to(Q, (np_, np_))
    Gp = _pad_to(G, (mp, mp))
    out = ullmann_refine_step_pallas(Mp, Qp, Gp,
                                     interpret=(backend == "interpret"))
    return out[:, :n, :m]


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def prune_fixpoint(maskb: jax.Array, Qb: jax.Array, Gb: jax.Array,
                   max_iters: int = 0, backend: str = "auto"):
    """Fused global pre-prune to fixpoint, batched over problems.

    ``maskb``: (B, n, m) compatibility masks; ``Qb``: (B, n, n);
    ``Gb``: (B, m, m) — each problem prunes against its OWN graphs (the
    batched matcher's layout; broadcast Q/G for the shared case). One
    fused iteration = Ullmann refinement sweep + injectivity propagation;
    ``max_iters=0`` iterates to convergence. Returns ``(pruned maskb,
    sweeps (B,) int32)`` where ``sweeps`` counts the fused iterations
    executed (the prune-latency observable).
    """
    backend = resolve_backend(backend)
    if backend == "ref":
        return jax.vmap(
            lambda mk, Q, G: ref.prune_fixpoint_count(mk, Q, G, max_iters)
        )(maskb, Qb, Gb)
    B, n, m = maskb.shape
    np_, mp = _round_up(n), _round_up(m)
    Mp = _pad_to(maskb, (np_, mp))
    Qp = _pad_to(Qb, (np_, np_))
    Gp = _pad_to(Gb, (mp, mp))
    out, sweeps = prune_fixpoint_pallas(Mp, Qp, Gp, max_iters=max_iters,
                                        interpret=(backend == "interpret"))
    return out[:, :n, :m], sweeps


# ---------------------------------------------------------------------------
# Fused PSO update
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("omega", "c1", "c2", "c3", "v_max", "backend"))
def pso_update(S, V, S_local, S_star, S_bar, mask, r,
               omega: float, c1: float, c2: float, c3: float,
               v_max: float = 1.0, backend: str = "auto"):
    """Batched fused PSO step. S/V/S_local: (B, n, m); S_star/S_bar/mask:
    (n, m); r: (B, 3) randoms. Returns (S_new, V_new)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        fn = functools.partial(ref.pso_update, omega=omega, c1=c1, c2=c2,
                               c3=c3, v_max=v_max)
        return jax.vmap(fn, in_axes=(0, 0, 0, None, None, None, 0))(
            S, V, S_local, S_star, S_bar, mask, r)
    B, n, m = S.shape
    np_, mp = _round_up(n), _round_up(m)
    Sp = _pad_to(S, (np_, mp))
    Vp = _pad_to(V, (np_, mp))
    Lp = _pad_to(S_local, (np_, mp))
    starp = _pad_to(S_star, (np_, mp))
    barp = _pad_to(S_bar, (np_, mp))
    maskp = _pad_to(mask, (np_, mp))
    r8 = _pad_to(r.astype(jnp.float32), (8,))
    s_new, v_new = pso_update_pallas(
        Sp, Vp, Lp, starp, barp, maskp, r8,
        omega=omega, c1=c1, c2=c2, c3=c3, v_max=v_max,
        interpret=(backend == "interpret"))
    return s_new[:, :n, :m], v_new[:, :n, :m]


# ---------------------------------------------------------------------------
# Fused epoch loop (PSO update → requantize → fitness → best tracking × K)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("omega", "c1", "c2", "c3", "v_max", "quantized",
                     "backend"))
def epoch_fused(S, V, S_local, f_local, S_star, f_star, S_bar, mask, Q, G,
                r_all, omega: float, c1: float, c2: float, c3: float,
                v_max: float, quantized: bool = False,
                backend: str = "auto"):
    """The entire K-step epoch inner loop, batched over problems.

    Particle state ``S/V/S_local`` (P, N, n, m) + ``f_local`` (P, N)
    stay device-resident for the whole loop (VMEM-resident on the fused
    path); ``S_star``/``S_bar``/``mask`` (P, n, m), ``f_star`` (P,),
    ``Q`` (P, n, n), ``G`` (P, m, m), ``r_all`` (P, K, N, 3) pre-drawn
    uniforms. Returns ``(S_final, S_star, f_star, f_trace (P, K),
    f_last (P, N))`` — ``f_last`` is the last step's per-particle
    fitness, threaded into ``epoch_finish`` instead of recomputed.

    Padding note: interpret mode runs UNPADDED so the fused body is
    bitwise-equal to the vmapped ref scan (zero-padding regroups f32
    reductions by a last ulp); the compiled TPU path MXU-pads n/m —
    exact for every integer op, allclose on the float-fitness path.
    Padded mask rows are all-zero, so they normalize to the zero
    fallback and contribute nothing to fitness.
    """
    backend = resolve_backend(backend)
    if backend == "ref":
        fn = functools.partial(epoch_inner_reference, omega=omega, c1=c1,
                               c2=c2, c3=c3, v_max=v_max,
                               quantized=quantized)
        return jax.vmap(fn)(S, V, S_local, f_local, S_star, f_star,
                            S_bar, mask, Q, G, r_all)
    kw = dict(omega=omega, c1=c1, c2=c2, c3=c3, v_max=v_max,
              quantized=quantized, interpret=(backend == "interpret"))
    if backend == "interpret":
        return epoch_fused_pallas(S, V, S_local, f_local, S_star, f_star,
                                  S_bar, mask, Q, G, r_all, **kw)
    P, N, n, m = S.shape
    np_, mp = _round_up(n), _round_up(m)
    s_fin, star_fin, fstar_fin, trace, f_last = epoch_fused_pallas(
        _pad_to(S, (np_, mp)), _pad_to(V, (np_, mp)),
        _pad_to(S_local, (np_, mp)), f_local,
        _pad_to(S_star, (np_, mp)), f_star, _pad_to(S_bar, (np_, mp)),
        _pad_to(mask, (np_, mp)), _pad_to(Q, (np_, np_)),
        _pad_to(G, (mp, mp)), _pad_to(r_all.astype(jnp.float32), (8,)),
        **kw)
    return (s_fin[:, :, :n, :m], star_fin[:, :n, :m], fstar_fin, trace,
            f_last)


# ---------------------------------------------------------------------------
# Fused epoch tail (projections → Ullmann refine → feasibility → consensus)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("gumbel_tau", "refine_threshold", "refine_iters",
                     "elite_k", "consensus_temp", "backend"))
def epoch_finish(S, f_final, gum, mask, Q, G, gumbel_tau: float,
                 refine_threshold: float, refine_iters: int, elite_k: int,
                 consensus_temp: float, backend: str = "auto"):
    """The entire epoch epilogue, batched over problems.

    ``S``: (P, N, n, m) final swarm state; ``f_final``: (P, N) the fused
    epoch kernel's last-step fitness (threaded through — the epilogue
    never recomputes it); ``gum``: (P, N, n, m) pre-drawn Gumbel noise
    or ``None`` when ``gumbel_tau == 0``; ``mask``: (P, n, m); ``Q``:
    (P, n, n); ``G``: (P, m, m). Returns ``(M_hat (P, N, n, m) uint8,
    feasible (P, N) bool, S_bar (P, n, m) f32)``.

    Padding note: interpret mode runs UNPADDED so the fused body is
    bitwise-equal to the vmapped ref epilogue (f32 reduction grouping);
    the compiled TPU path MXU-pads n/m — exact for the integer
    projection/refinement/feasibility pipeline (the construction loops
    run the logical ``n`` trips and padded mask columns never enter a
    candidate set), allclose on the f32 consensus.
    """
    backend = resolve_backend(backend)
    statics = dict(gumbel_tau=gumbel_tau,
                   refine_threshold=refine_threshold,
                   refine_iters=refine_iters, elite_k=elite_k,
                   consensus_temp=consensus_temp)
    if backend == "ref":
        fn = functools.partial(epoch_finish_reference, **statics)
        return jax.vmap(fn)(S, f_final, gum, mask, Q, G)
    P, N, n, m = S.shape
    if gum is None:
        # dummy block (never read when gumbel_tau == 0) — a (P, 1, 1, 1)
        # placeholder instead of a full (P, N, n, m) zeros array keeps
        # the kernel's HBM accounting honest
        gum = jnp.zeros((P, 1, 1, 1), jnp.float32)
    if backend == "interpret":
        m_hat, feas, s_bar = epoch_finish_pallas(
            S, f_final, gum, mask, Q, G, n_rows=n, interpret=True,
            **statics)
        return m_hat.astype(jnp.uint8), feas != 0, s_bar
    np_, mp = _round_up(n), _round_up(m)
    gum_p = gum if gum.shape[2] == 1 else _pad_to(gum, (np_, mp))
    m_hat, feas, s_bar = epoch_finish_pallas(
        _pad_to(S, (np_, mp)), f_final, gum_p,
        _pad_to(mask, (np_, mp)), _pad_to(Q, (np_, np_)),
        _pad_to(G, (mp, mp)), n_rows=n, interpret=False, **statics)
    return (m_hat[:, :, :n, :m].astype(jnp.uint8), feas != 0,
            s_bar[:, :n, :m])


# ---------------------------------------------------------------------------
# Projection / argmax
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def greedy_project(S: jax.Array, mask: jax.Array,
                   backend: str = "auto") -> jax.Array:
    """Project one relaxed (n, m) S to a discrete injective M̂ (uint8)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return ref.greedy_project(S, mask)
    n, m = S.shape
    np_, mp = _round_up(n), _round_up(m)
    Sp = _pad_to(S, (np_, mp))
    maskp = _pad_to(mask, (np_, mp))
    out = greedy_project_pallas(Sp, maskp, interpret=(backend == "interpret"))
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("backend",))
def masked_argmax(X: jax.Array, mask: jax.Array, backend: str = "auto"):
    """Masked argmax -> (value, flat index) over the *logical* shape."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return ref.masked_argmax(X, mask)
    n, m = X.shape
    np_, mp = _round_up(n), _round_up(m)
    Xp = _pad_to(X, (np_, mp))
    maskp = _pad_to(mask, (np_, mp))
    val, idx = masked_argmax_pallas(Xp, maskp,
                                    interpret=(backend == "interpret"))
    # translate padded flat index back to logical coordinates
    i, j = idx // mp, idx % mp
    return val, (i * m + j).astype(jnp.int32)
