"""Warm-restart benchmark: cold-restart vs snapshot/AOT-restored service.

The persistence layer exists for ONE number: what does the first burst
after a scheduler-process restart cost? Two experiments:

  1. **Service restart** — a burst of revalidatable problems is served
     by (a) a *cold-restarted* service (fresh process state, no
     persistence: the burst pays jit traces, XLA compiles and a cold
     CarryStore → full swarm) and (b) a *warm-restarted* service (same
     persist dir as a previous incarnation: executables deserialize from
     the on-disk AOT cache, carries restore from the snapshot → the
     whole burst re-validates at Tier 0 with ``jit_traces == 0``).
     Acceptance: warm-restart first-burst latency ≪ cold-restart, zero
     traces, all problems served at Tier 0/1, results bitwise equal to
     the pre-restart warm serve.
  2. **Simulator restart** — ``make_restart_scenario`` (identical
     traffic replayed after a mid-trace kill) through the event
     simulator with the real matcher, cold arm (no ``persist_dir``) vs
     warm arm (snapshot-before-kill + restore): post-restart scheduling
     behaviour (tier decision mix, restored state) is surfaced via
     ``warm_restart_stats`` / ``pipeline_tier_rates``.

Emits ``BENCH_restart.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_restart
           [--burst K] [--repeats N] [--smoke] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import shutil
import statistics
import tempfile
import time

import jax
import numpy as np

from repro.accel import EDGE
from repro.core import graphs, pso
from repro.core.service import MatcherService
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.metrics import pipeline_tier_rates, warm_restart_stats
from repro.sched.tasks import make_restart_scenario


def _planted(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _servable_problems(cfg: pso.PSOConfig, want: int, seed0: int = 100):
    """Planted problems whose stored carry re-validates on repeat (the
    warm traffic class a restarted service should serve at Tier 0).
    ``persist_dir=False`` everywhere below: the probe and cold arms must
    not pick up an operator's ``REPRO_PERSIST_DIR``."""
    svc = MatcherService(cfg, persist_dir=False)
    probs, keys, wks = [], [], []
    s = seed0
    while len(probs) < want and s < seed0 + 60 * want:
        q, g = _planted(s, 6, 12)
        key = jax.random.PRNGKey(s)
        wk = f"wl/{s}"
        r = svc.match(q, g, key=key, workload_key=wk)
        if r.found:
            r2 = svc.match(q, g, key=jax.random.PRNGKey(s + 999),
                           workload_key=wk)
            if r2.tier == 0:
                probs.append((q, g))
                keys.append(key)
                wks.append(wk)
        s += 1
    assert len(probs) == want, "not enough revalidatable planted problems"
    return probs, keys, wks


def bench_service_restart(cfg: pso.PSOConfig, burst: int, repeats: int):
    probs, keys, wks = _servable_problems(cfg, burst)

    # --- cold-restart arm FIRST: it must run before any persistent
    # service exists in this process, because enabling the persistent
    # XLA compilation cache is process-global — a cold arm measured
    # after the seed incarnation would have its XLA compiles served
    # from the seed's disk cache and understate the true cold cost.
    cold_lat, cold_traces = [], []
    for _ in range(repeats):
        svc = MatcherService(cfg, persist_dir=False,
                             batch_classes=(1, 2, 4, max(8, burst)))
        t0 = time.perf_counter()
        rs = svc.match_many(probs, keys=keys, workload_keys=wks)
        cold_lat.append(time.perf_counter() - t0)
        cold_traces.append(svc.stats.jit_traces)
        assert [r.found for r in rs] == [True] * burst

    # --- seed incarnation: serve the trace, export executables, snapshot
    seed_dir = tempfile.mkdtemp(prefix="bench_restart_seed_")
    svc_seed = MatcherService(cfg, persist_dir=seed_dir,
                              batch_classes=(1, 2, 4, max(8, burst)))
    svc_seed.match_many(probs, keys=keys, workload_keys=wks)   # cold
    warm_ref = svc_seed.match_many(probs, keys=keys, workload_keys=wks)
    svc_seed.save_snapshot()
    seed_stats = svc_seed.stats_dict()

    # --- warm-restart arm: restore snapshot + AOT executables
    warm_lat, warm_traces, warm_tiers = [], [], None
    bitwise_equal = True
    for _ in range(repeats):
        svc = MatcherService(cfg, persist_dir=seed_dir,
                             batch_classes=(1, 2, 4, max(8, burst)))
        restored = svc.restore_snapshot()
        assert restored is not None, "snapshot must restore"
        t0 = time.perf_counter()
        rs = svc.match_many(probs, keys=keys, workload_keys=wks)
        warm_lat.append(time.perf_counter() - t0)
        warm_traces.append(svc.stats.jit_traces)
        warm_tiers = [r.tier for r in rs]
        for a, b in zip(warm_ref, rs):
            if a.found != b.found or not np.array_equal(
                    np.asarray(a.mapping), np.asarray(b.mapping)):
                bitwise_equal = False
    shutil.rmtree(seed_dir, ignore_errors=True)

    cold_med = statistics.median(cold_lat)
    warm_med = statistics.median(warm_lat)
    return {
        "burst": burst,
        "cold_restart_first_burst_median_s": cold_med,
        "warm_restart_first_burst_median_s": warm_med,
        "warm_over_cold_ratio": warm_med / max(cold_med, 1e-12),
        "cold_restart_traces": max(cold_traces),
        "warm_restart_traces": max(warm_traces),
        "warm_tiers": warm_tiers,
        "tier0_served": sum(1 for t in warm_tiers if t == 0),
        "bitwise_equal_to_pre_restart": bitwise_equal,
        "seed_aot_exports": seed_stats["aot_exports"],
        "seed_snapshot_saves": seed_stats["snapshot_saves"],
        "pass": (max(warm_traces) == 0
                 and warm_med < cold_med
                 and bitwise_equal
                 and all(t <= 1 for t in warm_tiers)),
    }


def bench_simulator_restart(cfg: pso.PSOConfig, smoke: bool):
    sc = make_restart_scenario(
        "simple", rate_hz=25, phase_horizon=0.15 if smoke else 0.4,
        burst_size=4, burst_frac=0.6, seed=11)
    out = {"scenario": sc.name, "tasks": len(sc.tasks),
           "restart_at": sc.restarts}
    for label, persist_dir in (
            ("cold", None),
            ("warm", tempfile.mkdtemp(prefix="bench_restart_sim_"))):
        sim_cfg = SimConfig(platform=EDGE, matcher_mode="real",
                            pso_cfg=cfg, window_stages=2,
                            persist_dir=persist_dir)
        r = Simulator(sim_cfg, get_scheduler("immsched")).run(sc)
        out[label] = {
            "finished": r.finished, "total": r.total,
            "deadline_met": r.deadline_met,
            "avg_total_latency_s": r.avg_total_latency,
            "avg_sched_time_s": r.avg_sched_time,
            "restart": warm_restart_stats(r),
            "tier_rates": pipeline_tier_rates(r),
        }
        if persist_dir:
            shutil.rmtree(persist_dir, ignore_errors=True)
    w, c = out["warm"], out["cold"]
    out["warm_restored_state"] = (
        w["restart"]["snapshot_restores"] >= 1
        and w["restart"]["restart_restored_state_sigs"] > 0)
    out["pass"] = bool(out["warm_restored_state"]
                       and c["restart"]["snapshot_restores"] == 0)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--burst", type=int, default=6)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: small swarm, short runs")
    ap.add_argument("--out", default="BENCH_restart.json")
    args = ap.parse_args()

    if args.smoke:
        cfg = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)
        burst, repeats = 3, 1
    else:
        cfg = pso.PSOConfig(num_particles=32, epochs=2, inner_steps=8)
        burst, repeats = args.burst, max(args.repeats, 2)

    service = bench_service_restart(cfg, burst, repeats)
    sim = bench_simulator_restart(cfg, args.smoke)

    result = {
        "smoke": bool(args.smoke),
        "pso_cfg": {"num_particles": cfg.num_particles,
                    "epochs": cfg.epochs, "inner_steps": cfg.inner_steps},
        "service": service,
        "simulator": sim,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("name,us_per_call,derived")
    print(f"restart_cold_first_burst,"
          f"{service['cold_restart_first_burst_median_s'] * 1e6:.1f},"
          f"traces={service['cold_restart_traces']}")
    print(f"restart_warm_first_burst,"
          f"{service['warm_restart_first_burst_median_s'] * 1e6:.1f},"
          f"traces={service['warm_restart_traces']}"
          f"_tier0={service['tier0_served']}/{service['burst']}")
    print(f"restart_warm_over_cold,0.0,"
          f"ratio={service['warm_over_cold_ratio']:.4f}")
    print(f"restart_sim_warm_restored,0.0,"
          f"{'yes' if sim['warm_restored_state'] else 'no'}")
    ok = service["pass"] and sim["pass"]
    print(f"restart_acceptance,0.0,{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
