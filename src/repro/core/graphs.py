"""Graph abstractions for IMMSched subgraph-isomorphism scheduling.

The multi-DNN scheduling problem is abstracted (following IsoSched) as
matching a *query* DAG Q — tiles of the DNN workload(s) after
DAG-to-Pipeline + Layer Concatenate-and-Split — onto a *target* DAG G —
the preemptible PE/engine array of the accelerator.

Everything here is dense adjacency-matrix based: the matrices are what the
paper maps onto the accelerator's int8 MAC datapath, so dense uint8 is the
native representation, not an implementation shortcut.

Vertex "compute types" model the paper's compatibility notion (e.g.
convolution tiles must land on MAC-capable PEs, max-pool tiles on
comparison-capable PEs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Compute-type vocabulary shared by workloads and PEs. A PE with type t can
# execute a tile of type u iff COMPAT_TABLE[u, t] == 1.
TYPE_MAC = 0        # conv / matmul / attention tiles   -> MAC-array engines
TYPE_VECTOR = 1     # elementwise / norm / softmax      -> vector-capable PEs
TYPE_REDUCE = 2     # pooling / argmax / reductions     -> comparator-tree PEs
TYPE_ANY = 3        # control-ish tiles: run anywhere
NUM_TYPES = 4

# compat[tile_type, pe_type] — PEs are built as supersets: a MAC engine in a
# modern NPU also has the vector path, per the paper's "arbiters and
# selectors were added to the existing PEs".
_COMPAT = np.zeros((NUM_TYPES, NUM_TYPES), dtype=np.uint8)
_COMPAT[TYPE_MAC, TYPE_MAC] = 1
_COMPAT[TYPE_VECTOR, TYPE_MAC] = 1
_COMPAT[TYPE_VECTOR, TYPE_VECTOR] = 1
_COMPAT[TYPE_REDUCE, TYPE_REDUCE] = 1
_COMPAT[TYPE_REDUCE, TYPE_MAC] = 1
_COMPAT[TYPE_ANY, :] = 1


@dataclasses.dataclass(frozen=True)
class Graph:
    """A labelled DAG stored densely.

    adj[i, j] == 1  means a directed edge i -> j.
    types[i]        is the compute type of vertex i.
    weights[i]      optional per-vertex work estimate (MACs for tiles,
                    throughput for PEs); used by cost models, not matching.
    """

    adj: np.ndarray            # (n, n) uint8
    types: np.ndarray          # (n,)  int32
    weights: np.ndarray        # (n,)  float32

    def __post_init__(self):
        n = self.adj.shape[0]
        assert self.adj.shape == (n, n)
        assert self.types.shape == (n,)
        assert self.weights.shape == (n,)

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    @property
    def out_degree(self) -> np.ndarray:
        return self.adj.sum(axis=1).astype(np.int32)

    @property
    def in_degree(self) -> np.ndarray:
        return self.adj.sum(axis=0).astype(np.int32)

    def is_dag(self) -> bool:
        """Cheap acyclicity check via boolean matrix powers."""
        n = self.n
        reach = self.adj.astype(bool)
        power = reach.copy()
        for _ in range(max(n.bit_length(), 1)):
            power = power @ power
            reach = reach | power
        return not bool(np.any(np.diag(reach)))

    @staticmethod
    def build(adj, types=None, weights=None) -> "Graph":
        adj = np.asarray(adj, dtype=np.uint8)
        n = adj.shape[0]
        if types is None:
            types = np.full((n,), TYPE_ANY, dtype=np.int32)
        if weights is None:
            weights = np.ones((n,), dtype=np.float32)
        return Graph(adj=adj,
                     types=np.asarray(types, dtype=np.int32),
                     weights=np.asarray(weights, dtype=np.float32))


def type_compatibility(query_types: np.ndarray,
                       target_types: np.ndarray) -> np.ndarray:
    """(n, m) uint8: can tile-type i run on pe-type j."""
    return _COMPAT[np.asarray(query_types)[:, None],
                   np.asarray(target_types)[None, :]]


def compatibility_mask(query: Graph, target: Graph) -> np.ndarray:
    """Global compatibility mask Mask ∈ {0,1}^{n×m} (paper §3.2).

    mask[i, j] = 1 iff
      * target vertex j's in/out degree covers query vertex i's
        (a monomorphism needs every query edge present among the images), and
      * the compute types are compatible.
    """
    q_out = query.out_degree[:, None]
    q_in = query.in_degree[:, None]
    g_out = target.out_degree[None, :]
    g_in = target.in_degree[None, :]
    degree_ok = (q_out <= g_out) & (q_in <= g_in)
    types_ok = type_compatibility(query.types, target.types).astype(bool)
    return (degree_ok & types_ok).astype(np.uint8)


# ---------------------------------------------------------------------------
# Synthetic graph constructors (tests + benchmarks).
# ---------------------------------------------------------------------------

def line_graph(n: int, type_id: int = TYPE_ANY) -> Graph:
    adj = np.zeros((n, n), dtype=np.uint8)
    for i in range(n - 1):
        adj[i, i + 1] = 1
    return Graph.build(adj, types=np.full((n,), type_id, dtype=np.int32))


def grid_graph(rows: int, cols: int, type_id: int = TYPE_MAC,
               bidirectional: bool = False) -> Graph:
    """2-D mesh as used for the accelerator's NoC-connected engine array.

    Directed east/south edges by default (matches a systolic-forwarding
    dataflow); ``bidirectional=True`` adds the reverse links.
    """
    n = rows * cols
    adj = np.zeros((n, n), dtype=np.uint8)

    def idx(r, c):
        return r * cols + c

    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                adj[idx(r, c), idx(r, c + 1)] = 1
            if r + 1 < rows:
                adj[idx(r, c), idx(r + 1, c)] = 1
    if bidirectional:
        adj = np.maximum(adj, adj.T)
    return Graph.build(adj, types=np.full((n,), type_id, dtype=np.int32))


def random_dag(key: jax.Array, n: int, edge_prob: float = 0.3,
               num_types: int = 1) -> Graph:
    """Random DAG via upper-triangular thinning (always acyclic)."""
    k1, k2 = jax.random.split(key)
    upper = np.triu(
        np.asarray(jax.random.bernoulli(k1, edge_prob, (n, n)), dtype=np.uint8),
        k=1)
    types = np.asarray(
        jax.random.randint(k2, (n,), 0, num_types), dtype=np.int32)
    return Graph.build(upper, types=types)


def embed_query_in_target(key: jax.Array, query: Graph, m: int,
                          extra_edge_prob: float = 0.15) -> Graph:
    """Build a target graph of size m that provably contains ``query``.

    Used by tests/benchmarks so the matcher always has at least one feasible
    mapping to find. The query vertices are planted at a random injective
    position set; extra vertices/edges are noise (only edges consistent with
    a DAG ordering are added).
    """
    n = query.n
    assert m >= n
    k1, k2, k3 = jax.random.split(key, 3)
    perm = np.asarray(jax.random.permutation(k1, m))[:n]
    adj = np.zeros((m, m), dtype=np.uint8)
    types = np.full((m,), TYPE_ANY, dtype=np.int32)
    order = np.asarray(jax.random.permutation(k2, m))  # topological order
    rank = np.empty(m, dtype=np.int64)
    rank[order] = np.arange(m)
    # noise edges along the random topological order
    noise = np.asarray(
        jax.random.bernoulli(k3, extra_edge_prob, (m, m)), dtype=np.uint8)
    fwd = (rank[:, None] < rank[None, :]).astype(np.uint8)
    adj = noise * fwd
    # plant the query: orient each query edge along the DAG order by swapping
    # endpoint placements where needed
    placed = perm.copy()
    # sort query vertices topologically, then place in increasing rank order
    q_order = _topo_order(query.adj)
    target_slots = placed[np.argsort(rank[placed])]
    pos = np.empty(n, dtype=np.int64)
    pos[q_order] = target_slots
    for i in range(n):
        for j in range(n):
            if query.adj[i, j]:
                adj[pos[i], pos[j]] = 1
    types[pos] = query.types
    g = Graph.build(adj, types=types)
    assert g.is_dag(), "embedding must stay acyclic"
    return g


def _topo_order(adj: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    indeg = adj.sum(axis=0).astype(np.int64)
    order, stack = [], [i for i in range(n) if indeg[i] == 0]
    while stack:
        v = stack.pop()
        order.append(v)
        for w in range(n):
            if adj[v, w]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    stack.append(w)
    assert len(order) == n, "graph has a cycle"
    return np.asarray(order, dtype=np.int64)


def topological_relabel(g: Graph):
    """Relabel vertices in topological order; returns (graph, order).

    The constructive (adjacency-guided) projection places vertices in
    index order and requires predecessors placed first — both the direct
    matcher and the online service relabel queries through here so their
    orders (and the service's content-digest warm keys) stay identical.
    """
    order = _topo_order(g.adj)
    return Graph(adj=g.adj[np.ix_(order, order)], types=g.types[order],
                 weights=g.weights[order]), order


def as_device_graphs(query: Graph, target: Graph):
    """uint8 device copies of (Q, G, Mask) ready for the matcher."""
    mask = compatibility_mask(query, target)
    return (jnp.asarray(query.adj, dtype=jnp.uint8),
            jnp.asarray(target.adj, dtype=jnp.uint8),
            jnp.asarray(mask, dtype=jnp.uint8))
