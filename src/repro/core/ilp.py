"""ILP scheduling-tensor construction and validation (paper §3.1).

The paper formalizes multi-DNN scheduling with two binary tensors

    X ∈ {0,1}^{D×I×N×T×P}   compute mapping
    Y ∈ {0,1}^{D×I×K×T×L}   communication mapping

with D tasks, I tiles/task, N engines, T timesteps, P engine partitions,
K max NoC hops, L directed links. A subgraph matching M̂ (tile → engine)
plus the tile DAG's pipeline stages induce (X, Y); this module builds them
and checks the ILP constraints — the scheduler's *commit* step runs these
checks before activating a new mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.accel.platform import Platform
from repro.core.preemptible_dag import PreemptibleDAG


def _links(platform: Platform) -> Dict[Tuple[int, int], int]:
    """Directed NoC links of the engine mesh → link ids."""
    links: Dict[Tuple[int, int], int] = {}
    R, C = platform.noc_rows, platform.noc_cols

    def idx(r, c):
        return r * C + c

    for r in range(R):
        for c in range(C):
            for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < R and 0 <= cc < C:
                    links.setdefault((idx(r, c), idx(rr, cc)), len(links))
    return links


def xy_route(platform: Platform, src: int, dst: int) -> List[Tuple[int, int]]:
    """Deterministic XY routing on the engine mesh."""
    C = platform.noc_cols
    r0, c0 = divmod(src, C)
    r1, c1 = divmod(dst, C)
    hops = []
    r, c = r0, c0
    while c != c1:
        c2 = c + (1 if c1 > c else -1)
        hops.append((r * C + c, r * C + c2))
        c = c2
    while r != r1:
        r2 = r + (1 if r1 > r else -1)
        hops.append((r * C + c, r2 * C + c))
        r = r2
    return hops


@dataclasses.dataclass
class ScheduleTensors:
    X: np.ndarray            # (D, I, N, T, P) uint8
    Y: np.ndarray            # (D, I, K, T, L) uint8
    task_ids: List[int]
    link_ids: Dict[Tuple[int, int], int]


def build_schedule_tensors(pdag: PreemptibleDAG, mapping: np.ndarray,
                           platform: Platform,
                           partitions: int = 1) -> ScheduleTensors:
    """mapping: (n, m) assignment over *free-engine* target graph whose
    weights carry original engine ids."""
    tiles = pdag.tiles
    n = len(tiles)
    task_ids = sorted({t.task_id for t in tiles})
    tindex = {tid: d for d, tid in enumerate(task_ids)}
    D = len(task_ids)
    I = max(sum(1 for t in tiles if t.task_id == tid) for tid in task_ids)
    N = platform.engines
    T = max(t.stage for t in tiles) + 1 if tiles else 1
    links = _links(platform)
    L = len(links)

    # per-task tile index
    local_idx: Dict[int, int] = {}
    counters = {tid: 0 for tid in task_ids}
    for gi, t in enumerate(tiles):
        local_idx[gi] = counters[t.task_id]
        counters[t.task_id] += 1

    engine_of = {}
    for gi in range(n):
        js = np.where(mapping[gi])[0]
        if len(js):
            engine_of[gi] = int(js[0])

    K = platform.noc_rows + platform.noc_cols  # max XY hops
    X = np.zeros((D, I, N, T, partitions), dtype=np.uint8)
    Y = np.zeros((D, I, K, T, L), dtype=np.uint8)

    adj = pdag.graph.adj
    for gi, tile in enumerate(tiles):
        if gi not in engine_of:
            continue
        d, i = tindex[tile.task_id], local_idx[gi]
        X[d, i, engine_of[gi], tile.stage, 0] = 1
        # communications to consumers (next stages)
        for gj in np.where(adj[gi])[0]:
            if int(gj) not in engine_of:
                continue
            route = xy_route(platform, engine_of[gi], engine_of[int(gj)])
            for k, hop in enumerate(route):
                Y[d, i, k, tile.stage, links[hop]] = 1
    return ScheduleTensors(X=X, Y=Y, task_ids=task_ids, link_ids=links)


def validate_schedule(st: ScheduleTensors, pdag: PreemptibleDAG,
                      link_capacity: int = 4) -> List[str]:
    """Check the ILP constraints; returns a list of violation strings
    (empty = valid schedule)."""
    errs = []
    X, Y = st.X, st.Y
    # (1) each mapped tile occupies exactly one (engine, partition, time)
    per_tile = X.sum(axis=(2, 3, 4))
    if (per_tile > 1).any():
        errs.append("tile multi-assigned")
    # (2) engine occupancy: ≤ 1 tile per (engine, timestep, partition)
    occ = X.sum(axis=(0, 1))
    if (occ > 1).any():
        errs.append("engine over-subscribed")
    # (3) link capacity per timestep
    load = Y.sum(axis=(0, 1, 2))
    if (load > link_capacity).any():
        errs.append("link over capacity")
    # (4) precedence: consumer stage strictly after producer stage unless
    #     co-located (cascaded within the engine)
    tiles = pdag.tiles
    adj = pdag.graph.adj
    eng = {}
    stage = {}
    # recompute engine/stage from X directly, re-deriving local tile indices
    counters = {}
    for gi, t in enumerate(tiles):
        d = st.task_ids.index(t.task_id)
        i = counters.get(t.task_id, 0)
        counters[t.task_id] = i + 1
        loc = np.argwhere(X[d, i])
        if len(loc):
            eng[gi] = int(loc[0][0])
            stage[gi] = int(loc[0][1])
    for gi in range(len(tiles)):
        for gj in np.where(adj[gi])[0]:
            gj = int(gj)
            if gi in stage and gj in stage:
                if stage[gj] < stage[gi]:
                    errs.append(f"precedence violated {gi}->{gj}")
                # same-stage deps are split-sibling chains: wave-pipelined
                # within the stage, legal because the matcher guarantees
                # every Q-edge maps onto a NoC link (feasibility check)
    return errs
