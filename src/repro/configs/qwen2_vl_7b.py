"""Qwen2-VL-7B [arXiv:2409.12191]: GQA kv=4 backbone, M-RoPE; the vision
frontend is a STUB — input_specs provides precomputed patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, kv_heads=4, d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1000000.0, mrope=True,
    mrope_sections=(16, 24, 24), frontend="vision")
