"""Pallas TPU kernel: the fused epoch-tail (epilogue) mega-kernel.

PR 7 fused the swarm inner loop (``kernels/epoch_fused.py``), but every
epoch still exited to a host-visible epilogue: two vmapped projections,
an Ullmann candidate refinement, two feasibility checks, a redundant
fitness recompute, and the elite-consensus reduction — ~7 separate XLA
dispatches round-tripping the full particle state ``S`` (N, n, m)
through HBM per epoch per problem. This kernel closes that fusion
frontier: the ENTIRE epilogue of ``run_epoch`` runs in one body, so an
epoch is exactly two kernel launches (``epoch_fused`` → this) with no
host-visible intermediates between them.

Per problem the body computes, with ``S`` read from HBM once:

  1. (optionally Gumbel-perturbed) **structured projection** ``M_a`` —
     the adjacency-guided constructive embed of ``ref.structured_project``,
     batched over particles with one-hot row/column selects;
  2. **greedy projection** ``M_proj`` + **Ullmann candidate
     refinement** (``refine_iters`` matrix-form sweeps) + structured
     re-projection → ``M_b``;
  3. per-particle **feasibility** of both (rows/cols injective,
     ``M G Mᵀ ⊇ Q``) and the ``feas_a ? M_a : M_b`` merge;
  4. **elite consensus** ``S̄`` over the threaded-in final fitness
     (the fused epoch kernel's ``f_last`` — the fitness recompute the
     legacy epilogue did is gone).

Grid: ``(P,)`` problems, same layout discipline as the fused epoch
kernel. Outputs are ``M_hat`` (P, N, n, m) int32 0/1, ``feasible``
(P, N) int32 0/1 and ``S_bar`` (P, n, m) f32; the ops layer casts to
the public uint8/bool dtypes.

Bitwise-parity engineering (the acceptance bar is bitwise equality
with the pre-fusion epilogue on the ``ref`` ↔ ``interpret`` pair):

* **No gather/scatter/top_k in-kernel.** ``.at[i, j].set`` becomes a
  one-hot ``broadcasted_iota`` masked select (exact: values are 0/1
  ints or written whole rows); ``S_all[top_k(f)]`` becomes ``elite_k``
  statically-unrolled rounds of argmax + mask-to--inf, which matches
  ``jax.lax.top_k``'s stable ordering (ties broken by lower index)
  value-for-value and index-for-index.
* **Flat argmax decomposition.** ``ref.masked_argmax`` argmaxes the
  flattened (n·m,) array; in-kernel this is (row-max, row-argmax,
  argmax over row-maxes) — the same first-maximum in row-major order,
  so ``greedy_project`` picks identical pivots.
* **Batched int matmuls.** ``Q @ miss`` per particle becomes one
  ``dot_general`` producing (N, m, n) plus a transpose — int32
  accumulation is order-independent, hence exact even MXU-padded.
* **Reductions mirror the vmapped-ref lowering** (sum/max over the
  same axes with the same jnp ops), and the consensus softmax/einsum
  are literally the ref ops on bitwise-identical inputs. The ops layer
  runs interpret mode UNPADDED so f32 reduction grouping matches the
  ``ref`` path exactly; the compiled path MXU-pads (exact for the int
  projections/feasibility, allclose for the f32 consensus).
* **Padding correctness**: construction loops run ``n_rows`` (logical)
  trips, and the feasibility row check masks padded all-zero rows with
  a static ``iota >= n_rows`` escape; padded mask columns are zero so
  they never enter any candidate set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.pallas_compat import CompilerParams

_NEG = float(jnp.finfo(jnp.float32).min)


# ---------------------------------------------------------------------------
# Loose-jnp oracles (the ``ref`` backend path — the bitwise ground truth)
# ---------------------------------------------------------------------------

def ullmann_refine_candidates_reference(S, M_proj, Q, G, mask, *,
                                        refine_threshold: float,
                                        refine_iters: int):
    """Candidate refinement of the pre-fusion epilogue, verbatim (ONE
    problem, batched over particles): threshold ∪ projection candidate
    set, ``refine_iters`` Ullmann sweeps, structured re-projection with
    an empty-row fallback to ``M_proj``. Returns ``(M_hat uint8,
    cand uint8)``."""
    rowmax = S.max(axis=-1, keepdims=True)
    cand = ((S >= refine_threshold * rowmax) | (M_proj > 0))
    cand = (cand & (mask[None] > 0)).astype(jnp.uint8)

    def sweep(_, c):
        return jax.vmap(ref.ullmann_refine_step,
                        in_axes=(0, None, None))(c, Q, G)

    cand = jax.lax.fori_loop(0, refine_iters, sweep, cand)
    S_restricted = S * cand.astype(S.dtype)
    M_hat = jax.vmap(lambda s, c: ref.structured_project(s, Q, G, c))(
        S_restricted, cand)
    empty_rows = cand.sum(-1, keepdims=True) == 0
    M_hat = jnp.where(empty_rows, M_proj, M_hat)
    return M_hat.astype(jnp.uint8), cand


def elite_consensus_reference(S_all, f_all, *, elite_k: int,
                              consensus_temp: float):
    """S̄: softmax-weighted average of the ``elite_k`` fittest particles
    (paper line 24), exactly as the pre-fusion ``elite_consensus``
    computed it (top_k → normalized softmax → einsum). Returns
    ``(weighted, weight_total, w)`` so the distributed matcher can psum
    the parts before dividing."""
    f_top, idx = jax.lax.top_k(f_all, elite_k)
    f_norm = (f_top - f_top[0]) / consensus_temp
    w = jax.nn.softmax(f_norm)
    S_top = S_all[idx]
    weighted = jnp.einsum("k,knm->nm", w, S_top)
    return weighted, jnp.sum(w), w


def epoch_finish_reference(S, f_final, gum, mask, Q, G, *,
                           gumbel_tau: float, refine_threshold: float,
                           refine_iters: int, elite_k: int,
                           consensus_temp: float):
    """Loose-jnp oracle of the fused epoch tail (ONE problem).

    This is the pre-fusion ``pso._epoch_finish`` verbatim — gumbel
    perturbation, structured + greedy projections, Ullmann candidate
    refinement, feasibility, elite consensus — with the redundant
    ``_fitness(S)`` recompute replaced by the threaded-in ``f_final``
    (the fused epoch kernel's last-step fitness, bitwise the same
    value). ``gum`` is the pre-drawn (N, n, m) Gumbel noise (``None``
    when ``gumbel_tau == 0`` — the tau = 0 path never draws). Returns
    ``(M_hat uint8 (N, n, m), feasible bool (N,), S_bar f32 (n, m))``.
    """
    if gumbel_tau > 0:
        S_proj_a = jnp.log(jnp.clip(S.astype(jnp.float32), 1e-9, None)) \
            + gumbel_tau * gum
    else:
        S_proj_a = S
    M_a = jax.vmap(lambda s: ref.structured_project(s, Q, G, mask))(S_proj_a)
    feas_a = jax.vmap(ref.is_feasible, in_axes=(0, None, None))(M_a, Q, G)
    M_proj = jax.vmap(lambda s: ref.greedy_project(s, mask))(S)
    M_b, _ = ullmann_refine_candidates_reference(
        S, M_proj, Q, G, mask, refine_threshold=refine_threshold,
        refine_iters=refine_iters)
    feas_b = jax.vmap(ref.is_feasible, in_axes=(0, None, None))(M_b, Q, G)
    M_hat = jnp.where(feas_a[:, None, None], M_a, M_b)
    feasible = feas_a | feas_b
    S_bar, _, _ = elite_consensus_reference(
        S, f_final, elite_k=elite_k, consensus_temp=consensus_temp)
    return M_hat.astype(jnp.uint8), feasible, S_bar


# ---------------------------------------------------------------------------
# The fused Pallas body
# ---------------------------------------------------------------------------

def _batched_structured(Sf, avail0, Qi, Gi, n_rows: int):
    """``ref.structured_project`` batched over the particle axis.

    ``Sf``: (N, n, m) f32 scores; ``avail0``: (N, n, m) int32 0/1
    initial candidates; ``Qi``/``Gi``: shared int32 graphs. One-hot
    masked selects replace every ``.at[]`` scatter and ``G[j]`` gather
    (exact: whole int rows / 0-1 writes). Loops ``n_rows`` trips — the
    LOGICAL query size, so MXU row padding never adds iterations.
    """
    N, n, m = Sf.shape
    succ_need = jnp.sum(Qi, axis=1)                       # (n,) out-degree
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (N, m), 1)
    row_iota3 = jax.lax.broadcasted_iota(jnp.int32, (N, n, m), 1)
    col_iota3 = jax.lax.broadcasted_iota(jnp.int32, (N, n, m), 2)

    def body(i, state):
        avail, col_avail, out, img_rows = state
        preds = jax.lax.dynamic_index_in_dim(Qi, i, 1, keepdims=False)
        need = jnp.sum(preds)
        # support[p, j] = preds @ img_rows[p] — how many of i's placed
        # predecessors have an edge to j's image neighbourhood
        support = jnp.sum(img_rows * preds[None, :, None], axis=1)
        # forward checking: free out-neighbours of candidate j
        free_out = jax.lax.dot_general(
            col_avail, Gi, dimension_numbers=(((1,), (1,)), ((), ())))
        avail_i = jax.lax.dynamic_index_in_dim(avail, i, 1, keepdims=False)
        s_i = jax.lax.dynamic_index_in_dim(Sf, i, 1, keepdims=False)
        succ_i = jax.lax.dynamic_index_in_dim(succ_need, i, 0,
                                              keepdims=False)
        feas = ((avail_i > 0) & (support >= need) & (free_out >= succ_i))
        scores = jnp.where(feas, s_i, _NEG)               # (N, m)
        j = jnp.argmax(scores, axis=-1)                   # (N,)
        ok = jnp.max(scores, axis=-1) > _NEG              # (N,)
        col_kill = ((col_iota != j[:, None]) | (~ok[:, None]))
        new_avail = avail * col_kill[:, None, :].astype(jnp.int32)
        new_col = col_avail * col_kill.astype(jnp.int32)
        upd = ((row_iota3 == i) & (col_iota3 == j[:, None, None])
               & ok[:, None, None])
        new_out = jnp.where(upd, 1, out)
        # img_rows[p, i] = ok ? Gi[j[p]] : 0 — row gather as a one-hot
        # int matmul (picks exactly one row, int32 exact)
        col_oh = (col_iota == j[:, None]).astype(jnp.int32)
        Gi_j = jax.lax.dot_general(
            col_oh, Gi, dimension_numbers=(((1,), (0,)), ((), ())))
        new_val = jnp.where(ok[:, None], Gi_j, 0)          # (N, m)
        new_img = jnp.where(row_iota3 == i, new_val[:, None, :], img_rows)
        return new_avail, new_col, new_out, new_img

    col0 = jnp.ones((N, m), jnp.int32)
    out0 = jnp.zeros((N, n, m), jnp.int32)
    img0 = jnp.zeros((N, n, m), jnp.int32)
    _, _, out, _ = jax.lax.fori_loop(0, n_rows, body,
                                     (avail0, col0, out0, img0))
    return out


def _batched_greedy(Sf, avail0, n_rows: int):
    """``ref.greedy_project`` batched over particles: ``n_rows`` rounds
    of global masked argmax + row/column knockout. The flat (n·m,)
    argmax decomposes into (row-max, row-argmax, argmax over row-maxes)
    — the identical first-maximum in row-major order."""
    N, n, m = Sf.shape
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (N, n), 1)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (N, m), 1)
    row_iota3 = jax.lax.broadcasted_iota(jnp.int32, (N, n, m), 1)
    col_iota3 = jax.lax.broadcasted_iota(jnp.int32, (N, n, m), 2)

    def body(_, state):
        avail, out = state
        flat = jnp.where(avail != 0, Sf, _NEG)            # (N, n, m)
        row_max = jnp.max(flat, axis=-1)                  # (N, n)
        row_arg = jnp.argmax(flat, axis=-1)               # (N, n)
        i_star = jnp.argmax(row_max, axis=-1)             # (N,)
        val = jnp.max(row_max, axis=-1)                   # (N,)
        j_star = jnp.sum(
            jnp.where(row_iota == i_star[:, None], row_arg, 0), axis=-1)
        take = val > _NEG
        row_kill = ((row_iota != i_star[:, None]) | (~take[:, None]))
        col_kill = ((col_iota != j_star[:, None]) | (~take[:, None]))
        new_avail = (avail * row_kill[:, :, None].astype(jnp.int32)
                     * col_kill[:, None, :].astype(jnp.int32))
        upd = ((row_iota3 == i_star[:, None, None])
               & (col_iota3 == j_star[:, None, None])
               & take[:, None, None])
        return new_avail, jnp.where(upd, 1, out)

    out0 = jnp.zeros((N, n, m), jnp.int32)
    _, out = jax.lax.fori_loop(0, n_rows, body, (avail0, out0))
    return out


def _batched_sweep(Mi, Qi, Gi):
    """``ref.ullmann_refine_step`` batched: int32 dot_generals with the
    per-particle ``Q @ miss`` products built as (N, m, n) contractions
    plus a transpose (int accumulation — order-independent, exact)."""
    support_out = jax.lax.dot_general(
        Mi, Gi, dimension_numbers=(((2,), (1,)), ((), ())))
    support_in = jax.lax.dot_general(
        Mi, Gi, dimension_numbers=(((2,), (0,)), ((), ())))
    miss_out = (support_out == 0).astype(jnp.int32)
    miss_in = (support_in == 0).astype(jnp.int32)
    viol_out = jax.lax.dot_general(
        miss_out, Qi, dimension_numbers=(((1,), (1,)), ((), ())))
    viol_in = jax.lax.dot_general(
        miss_in, Qi, dimension_numbers=(((1,), (0,)), ((), ())))
    viol = (jnp.transpose(viol_out, (0, 2, 1))
            + jnp.transpose(viol_in, (0, 2, 1)))
    return Mi * (viol == 0).astype(jnp.int32)


def _batched_feasible(Mi, Qi, Gi, n_rows: int):
    """``ref.is_feasible`` batched over particles. Padded all-zero rows
    are excused from the rows-sum-to-one check via a static
    ``iota >= n_rows`` escape (vacuous unpadded)."""
    N, n, m = Mi.shape
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (N, n), 1)
    rows_sum = jnp.sum(Mi, axis=2)                        # (N, n)
    cols_sum = jnp.sum(Mi, axis=1)                        # (N, m)
    rows_ok = jnp.all((rows_sum == 1) | (row_iota >= n_rows), axis=-1)
    cols_ok = jnp.all(cols_sum <= 1, axis=-1)
    MG = jax.lax.dot_general(
        Mi, Gi, dimension_numbers=(((2,), (0,)), ((), ())))
    mapped = jax.lax.dot_general(
        MG, Mi, dimension_numbers=(((2,), (2,)), ((0,), (0,))))
    covers = jnp.all(mapped >= Qi[None], axis=(1, 2))
    return rows_ok & cols_ok & covers


def _finish_kernel(s_ref, f_ref, gum_ref, mask_ref, q_ref, g_ref,
                   m_out_ref, feas_out_ref, sbar_out_ref, *,
                   n_rows: int, gumbel_tau: float, refine_threshold: float,
                   refine_iters: int, elite_k: int, consensus_temp: float):
    S = s_ref[0].astype(jnp.float32)                      # (N, n, m)
    f_final = f_ref[0].astype(jnp.float32)                # (N,)
    mask_raw = mask_ref[0]                                # (n, m)
    Qi = q_ref[0].astype(jnp.int32)
    Gi = g_ref[0].astype(jnp.int32)
    N = S.shape[0]
    avail_mask = (mask_raw != 0).astype(jnp.int32)        # (n, m)
    avail0 = jnp.broadcast_to(avail_mask[None], S.shape).astype(jnp.int32)

    # 1. (Gumbel-perturbed) structured projection — the τ = 0 branch is
    # static, so the dummy gum block is never read when tau is off.
    if gumbel_tau > 0:
        gum = gum_ref[0].astype(jnp.float32)
        S_proj_a = jnp.log(jnp.clip(S, 1e-9, None)) + gumbel_tau * gum
    else:
        S_proj_a = S
    M_a = _batched_structured(S_proj_a, avail0, Qi, Gi, n_rows)
    feas_a = _batched_feasible(M_a, Qi, Gi, n_rows)

    # 2. greedy projection + Ullmann candidate refinement → M_b
    M_proj = _batched_greedy(S, avail0, n_rows)
    rowmax = jnp.max(S, axis=-1, keepdims=True)
    cand = (((S >= refine_threshold * rowmax) | (M_proj > 0))
            & (avail_mask[None] > 0)).astype(jnp.int32)
    cand = jax.lax.fori_loop(
        0, refine_iters, lambda _, c: _batched_sweep(c, Qi, Gi), cand)
    S_restricted = S * cand.astype(jnp.float32)
    M_b = _batched_structured(S_restricted, cand, Qi, Gi, n_rows)
    empty_rows = jnp.sum(cand, axis=-1, keepdims=True) == 0
    M_b = jnp.where(empty_rows, M_proj, M_b)
    feas_b = _batched_feasible(M_b, Qi, Gi, n_rows)

    # 3. merge + feasibility verdicts
    M_hat = jnp.where(feas_a[:, None, None], M_a, M_b)
    feasible = feas_a | feas_b

    # 4. elite consensus over the threaded-in final fitness: elite_k
    # statically-unrolled argmax+mask rounds stand in for top_k (stable
    # tie order matches); softmax/einsum are the literal ref ops on
    # bitwise-identical (f_top, S_top).
    part_iota = jax.lax.broadcasted_iota(jnp.int32, (N, 1, 1), 0)
    pid = part_iota[:, 0, 0]                              # (N,)
    f_work = f_final
    f_tops, s_tops = [], []
    for _ in range(elite_k):
        b = jnp.argmax(f_work)
        f_tops.append(jnp.max(f_work))
        sel = part_iota == b
        s_tops.append(jnp.sum(jnp.where(sel, S, 0.0), axis=0))
        f_work = jnp.where(pid == b, _NEG, f_work)
    f_top = jnp.stack(f_tops)                             # (k,)
    S_top = jnp.stack(s_tops)                             # (k, n, m)
    f_norm = (f_top - f_top[0]) / consensus_temp
    w = jax.nn.softmax(f_norm)
    S_bar = jnp.einsum("k,knm->nm", w, S_top)

    m_out_ref[0] = M_hat
    feas_out_ref[0] = feasible.astype(jnp.int32)
    sbar_out_ref[0] = S_bar


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "gumbel_tau", "refine_threshold",
                     "refine_iters", "elite_k", "consensus_temp",
                     "interpret"))
def epoch_finish_pallas(S, f_final, gum, mask, Q, G, *, n_rows: int,
                        gumbel_tau: float, refine_threshold: float,
                        refine_iters: int, elite_k: int,
                        consensus_temp: float, interpret: bool = False):
    """Fused batched epoch tail. ``S``: (P, N, n, m) final swarm;
    ``f_final``: (P, N) threaded-in last-step fitness; ``gum``:
    (P, N, n, m) pre-drawn Gumbel noise, or a (P, 1, 1, 1) dummy when
    ``gumbel_tau == 0`` (never read — keeps HBM accounting honest);
    ``mask``: (P, n, m); ``Q``: (P, n, n); ``G``: (P, m, m).
    ``n_rows`` is the LOGICAL query size (= n unpadded). Returns
    ``(M_hat (P, N, n, m) int32, feasible (P, N) int32, S_bar
    (P, n, m) f32)``; the ops layer casts to uint8/bool and crops.
    """
    P, N, n, m = S.shape
    gn, gm = gum.shape[2], gum.shape[3]
    kernel = functools.partial(
        _finish_kernel, n_rows=n_rows, gumbel_tau=gumbel_tau,
        refine_threshold=refine_threshold, refine_iters=refine_iters,
        elite_k=elite_k, consensus_temp=consensus_temp)
    m_hat, feas, s_bar = pl.pallas_call(
        kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N), lambda p: (p, 0)),
            pl.BlockSpec((1, gum.shape[1], gn, gm),
                         lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n, n), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, m, m), lambda p: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N), lambda p: (p, 0)),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, N, n, m), jnp.int32),
            jax.ShapeDtypeStruct((P, N), jnp.int32),
            jax.ShapeDtypeStruct((P, n, m), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(S.astype(jnp.float32), f_final.astype(jnp.float32),
      gum.astype(jnp.float32), mask, Q, G)
    return m_hat, feas, s_bar
