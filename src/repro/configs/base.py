"""Configuration schema: model architecture, run shapes, mesh, training.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro/configs/<id>.py``) with the exact published hyper-parameters; the
registry in ``repro.configs`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    shared_experts: int = 0          # deepseek-style always-on experts
    dense_residual_d_ff: int = 0     # arctic-style parallel dense FFN
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"             # "mamba2" | "xlstm"
    state_dim: int = 64
    expand: int = 2
    conv_dim: int = 4
    chunk: int = 256                 # chunkwise-parallel scan chunk
    # xlstm: one sLSTM block every ``slstm_period`` blocks (rest mLSTM)
    slstm_period: int = 8
    # zamba2: one *shared* full-attention block applied every period blocks
    shared_attn_period: int = 6


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (seamless): encoder layer count; frontend stub
    encoder_layers: int = 0
    frontend: str = "none"           # none|audio|vision
    mrope: bool = False              # qwen2-vl M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # memory/precision policy (production knobs)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"             # none|block|full
    # dry-run probes: fully unroll layer scans so cost_analysis counts
    # every layer (XLA counts while bodies once) — see benchmarks/roofline
    unroll: bool = False
    # attention context policy for sub-quadratic archs
    sub_quadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train|prefill|decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # adamw|adafactor
    opt_state_dtype: str = "float32"  # bfloat16 for memory-tight giants
    microbatches: int = 1             # gradient accumulation
    grad_compression: bool = False    # int8 error-feedback DP compression
    z_loss: float = 1e-4


def shapes_for(cfg: ModelConfig):
    """The shape cells this architecture runs (harness skip rules)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out
