"""Pallas TPU kernel: one Ullmann refinement sweep, batched over particles.

The refinement is the feasibility-pruning workhorse of the matcher and is
"feasibility verification through matrix multiplication" (paper §3.3): all
four products below are {0,1}/small-int matmuls that map onto the MXU's
int8×int8→int32 path.

Per particle p with candidate matrix M (n, m):
    support_out = M @ G^T          # candidates of u adjacent *from* j
    support_in  = M @ G            # candidates of u adjacent *to* j
    viol        = Q @ [support_out == 0] + Q^T @ [support_in == 0]
    M'          = M ⊙ [viol == 0]

Tiling: grid = (B,); each step keeps one particle's full M plus Q and G in
VMEM. Scheduler-scale graphs (n, m ≤ 512 after padding) need
512·512·(1+1+1) int8 + int32 temporaries ≈ 4 MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _refine_kernel(m_ref, q_ref, g_ref, o_ref):
    m_in = m_ref[0].astype(jnp.int32)                  # (n, m)
    q = q_ref[...].astype(jnp.int32)                   # (n, n)
    g = g_ref[...].astype(jnp.int32)                   # (m, m)

    support_out = jax.lax.dot_general(
        m_in, g, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)              # M @ G^T
    support_in = jnp.dot(m_in, g, preferred_element_type=jnp.int32)

    miss_out = (support_out == 0).astype(jnp.int32)
    miss_in = (support_in == 0).astype(jnp.int32)

    viol = (jnp.dot(q, miss_out, preferred_element_type=jnp.int32)
            + jax.lax.dot_general(
                q, miss_in, dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.int32))     # Q^T @ miss_in

    o_ref[0] = (m_in * (viol == 0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ullmann_refine_step_pallas(M: jax.Array, Q: jax.Array, G: jax.Array,
                               interpret: bool = False) -> jax.Array:
    """M: (B, n, m) uint8 candidates; Q: (n, n); G: (m, m). -> (B, n, m).

    Padding requirements (ops.py enforces): padded entries of M must be 0,
    padded rows/cols of Q and G zero — the sweep is then exact w.r.t. the
    unpadded semantics (zero Q rows contribute no violations).
    """
    B, n, m = M.shape
    out = pl.pallas_call(
        _refine_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, n, m), lambda b: (b, 0, 0)),
            pl.BlockSpec((n, n), lambda b: (0, 0)),
            pl.BlockSpec((m, m), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n, m), M.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(M, Q, G)
    return out
