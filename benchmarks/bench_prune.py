"""Fused pre-prune benchmark: per-backend kernel latency + cold share.

The global Ullmann+injectivity pre-prune runs before any swarm epoch, so
it is pure cold-start latency. Two experiments, each run **per kernel
backend** (no single ambient-backend number standing in for all of
them):

  1. **Fused vs loose prune.** Batched pre-prune of B planted problems
     through the backend seam (``KernelBackend.prune_fixpoint_batch`` —
     the fused single-dispatch kernel with the in-kernel convergence
     flag) against the legacy loose-jnp path
     (``jax.jit(vmap(ref.prune_mask_fixpoint))`` — the pre-PR-4
     alternation). The loose baseline is backend-independent and is
     timed once. On CPU the ``ref`` ratio is near 1 (both lower through
     XLA) and ``interpret`` is orders slower (it is an emulator, timed
     for completeness, not a perf claim); the ``pallas`` row only
     appears on a real TPU.
  2. **Cold-start share.** Median wall time of a cold ``pso.match``
     (prune on) vs the prune launch alone: the fraction of a cold
     decision the pre-prune accounts for.

Each backend block also cross-checks the fused kernel against the
legacy oracle on every measured problem (``parity_ok``) and reports the
mean in-kernel sweep count. Top-level ``parity_ok`` is the AND over all
measured backends.

Emits ``BENCH_prune.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_prune
           [--batch B] [--n N] [--m M] [--repeats R]
           [--backend ref|pallas|interpret|comma-list|all] [--smoke]
           [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graphs, pso
from repro.kernels import get_backend, ref

#: Backends measured when --backend is omitted / "all": always the jnp
#: reference and the Pallas interpreter (both run anywhere); the
#: compiled Pallas backend joins only when a TPU is attached.
def default_backends() -> list:
    names = ["ref", "interpret"]
    if jax.default_backend() == "tpu":
        names.append("pallas")
    return names


def _planted_problem(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return graphs.as_device_graphs(q, g)


def _stack_problems(batch: int, n: int, m: int):
    Qs, Gs, Ms = [], [], []
    for b in range(batch):
        Q, G, mask = _planted_problem(100 + b, n, m)
        Qs.append(Q)
        Gs.append(G)
        Ms.append(mask)
    return jnp.stack(Qs), jnp.stack(Gs), jnp.stack(Ms)


def _median_wall(fn, repeats: int) -> float:
    fn()                                   # warm-up (compile)
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


def bench_backend(backend: str, Qb, Gb, maskb, legacy_mask,
                  legacy_s: float, repeats: int, smoke: bool) -> dict:
    """One backend's fused-prune latency, parity, and cold-start share."""
    bk = get_backend(backend)

    def fused():
        out, sweeps = bk.prune_fixpoint_batch(maskb, Qb, Gb)
        jax.block_until_ready(out)
        return out, sweeps

    fused_s = _median_wall(fused, repeats)
    pruned, sweeps = fused()
    parity_ok = bool(np.array_equal(np.asarray(pruned), legacy_mask))
    avg_sweeps = float(np.asarray(sweeps).mean())

    cfg = pso.PSOConfig(num_particles=16 if smoke else 32,
                        epochs=1 if smoke else 2,
                        inner_steps=4 if smoke else 8,
                        backend=backend)
    Q0, G0, mask0 = Qb[0], Gb[0], maskb[0]
    key = jax.random.PRNGKey(0)

    def cold_match():
        outs = pso.match(key, Q0, G0, mask0, cfg)
        jax.block_until_ready(outs["f_star"])

    def prune_one():
        out, _ = bk.prune_fixpoint(mask0, Q0, G0)
        jax.block_until_ready(out)

    cold_s = _median_wall(cold_match, repeats)
    prune_one_s = _median_wall(prune_one, repeats)
    share = min(max(prune_one_s / max(cold_s, 1e-12), 0.0), 1.0)
    return {
        "parity_ok": parity_ok,
        "avg_prune_sweeps": avg_sweeps,
        "fused_prune_median_s": fused_s,
        "fused_over_jnp_ratio": fused_s / max(legacy_s, 1e-12),
        "cold_match_median_s": cold_s,
        "prune_only_median_s": prune_one_s,
        "prune_share_of_cold": share,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--m", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--backend", type=str, default=None,
                    help="backend(s) to measure: a name, a comma list, "
                         "or 'all' (default: ref+interpret, plus pallas "
                         "on TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", type=str, default="BENCH_prune.json")
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.n, args.m, args.repeats = 4, 10, 20, 5

    if args.backend in (None, "all"):
        backends = default_backends()
    else:
        backends = [b.strip() for b in args.backend.split(",") if b.strip()]

    Qb, Gb, maskb = _stack_problems(args.batch, args.n, args.m)

    # Loose-jnp baseline: backend-independent, timed once.
    legacy_fn = jax.jit(jax.vmap(ref.prune_mask_fixpoint))

    def legacy():
        out = legacy_fn(maskb, Qb, Gb)
        jax.block_until_ready(out)
        return out

    legacy_s = _median_wall(legacy, args.repeats)
    legacy_mask = np.asarray(legacy())

    per_backend = {}
    for backend in backends:
        per_backend[backend] = bench_backend(
            backend, Qb, Gb, maskb, legacy_mask, legacy_s,
            args.repeats, args.smoke)

    result = {
        "smoke": bool(args.smoke),
        "batch": args.batch,
        "shape": [args.n, args.m],
        "repeats": args.repeats,
        "jnp_prune_median_s": legacy_s,
        "backends": per_backend,
        "parity_ok": all(b["parity_ok"] for b in per_backend.values()),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("backend,metric,value")
    print(f"-,jnp_prune_median_s,{legacy_s:.6g}")
    for backend, blk in per_backend.items():
        for k in ("fused_prune_median_s", "fused_over_jnp_ratio",
                  "avg_prune_sweeps", "cold_match_median_s",
                  "prune_share_of_cold"):
            print(f"{backend},{k},{blk[k]:.6g}")
        print(f"{backend},parity_ok,{blk['parity_ok']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
