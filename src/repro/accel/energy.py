"""Latency & energy cost model (45 nm-class constants, paper §4.1.1).

Sources: NoC per-hop energy 0.64 pJ/bit (paper, McPAT 1.3); SRAM/DRAM access
energies CACTI-P/Horowitz-class; int8 MAC ≈ 0.23 pJ @45 nm. The *relative*
LTS-vs-TSS and CPU-vs-NPU gaps — which drive every paper figure — come from
these ratios, not absolute calibration.

All methods return seconds / joules.
"""
from __future__ import annotations

import dataclasses

from repro.accel.platform import Platform
from repro.core.pso import PSOConfig
from repro.workloads.layers import WorkloadGraph

PJ = 1e-12


@dataclasses.dataclass(frozen=True)
class CostModel:
    platform: Platform
    e_mac_int8: float = 0.23 * PJ           # per MAC
    e_sram_byte: float = 1.5 * PJ           # on-chip tile buffer access
    e_dram_byte: float = 160.0 * PJ         # off-chip access
    e_noc_byte_hop: float = 5.12 * PJ       # 0.64 pJ/bit × 8
    engine_util_dnn: float = 0.70           # sustained MAC utilization
    engine_util_matcher: float = 0.45       # small matrices → lower util
    cpu_watts: float = 4.0
    engine_idle_watts: float = 0.025
    avg_hops: float = 3.0                   # mean NoC distance (XY route)

    # ---------------- execution (per-task) ----------------

    def exec_tss(self, wl: WorkloadGraph, engines: int):
        """Tile-cascaded spatial execution: activations stay on-chip."""
        p = self.platform
        rate = engines * p.macs_per_engine * p.clock_hz * self.engine_util_dnn
        t_compute = wl.total_macs / rate
        t_noc = wl.total_bytes * self.avg_hops / (
            p.noc_link_bw_bytes * max(engines // 2, 1))
        t = max(t_compute, t_noc)  # overlapped
        e = (wl.total_macs * self.e_mac_int8
             + wl.total_bytes * (2 * self.e_sram_byte
                                 + self.avg_hops * self.e_noc_byte_hop))
        return t, e

    def exec_lts(self, wl: WorkloadGraph, engines: int,
                 overlap: float = 0.0):
        """Layer-temporal execution: every layer boundary round-trips DRAM.
        ``overlap`` ∈ [0,1) models cross-layer overlapping (CD-MSA-like)."""
        p = self.platform
        rate = engines * p.macs_per_engine * p.clock_hz * self.engine_util_dnn
        t_compute = wl.total_macs / rate
        dram_bytes = 2.0 * wl.total_bytes          # write + read back
        t_dram = dram_bytes / p.dram_bw_bytes
        t = t_compute + t_dram * (1.0 - overlap)
        e = (wl.total_macs * self.e_mac_int8
             + dram_bytes * self.e_dram_byte
             + wl.total_bytes * self.e_sram_byte)
        return t, e

    def preemption_cost_lts(self, live_bytes: float):
        """Context save+restore through DRAM at a layer boundary."""
        t = 2.0 * live_bytes / self.platform.dram_bw_bytes
        e = 2.0 * live_bytes * self.e_dram_byte
        return t, e

    def preemption_cost_tss(self, live_bytes: float):
        """Tile context drains over the NoC to neighbour engines' SRAM."""
        t = live_bytes / self.platform.noc_link_bw_bytes
        e = live_bytes * (self.e_noc_byte_hop * self.avg_hops
                          + self.e_sram_byte)
        return t, e

    # ---------------- scheduling (the paper's subject) ----------------

    def matcher_work_macs(self, n: int, m: int, cfg: PSOConfig) -> float:
        """Analytic MAC count of Algorithm 1 (per full match call)."""
        fitness = n * m * m + n * n * m            # S·G then (S·G)·Sᵀ
        update = 8.0 * n * m                       # fused elementwise pass
        per_step = cfg.num_particles * (fitness + update)
        refine = cfg.refine_iters * cfg.num_particles * (
            2 * n * m * m + 2 * n * n * m)
        project = cfg.num_particles * float(n) * n * m  # n argmax sweeps
        per_epoch = cfg.inner_steps * per_step + refine + project
        return cfg.epochs * per_epoch

    def matcher_prune_macs(self, n: int, m: int, sweeps: int = 4) -> float:
        """Analytic MAC count of the fused global pre-prune: per fused
        iteration one Ullmann refinement sweep (four {0,1}/int matmuls)
        plus one injectivity-propagation pass (row/col reductions)."""
        refine = 2.0 * n * m * m + 2.0 * n * n * m
        inject = 3.0 * n * m
        return max(sweeps, 1) * (refine + inject)

    def sched_immsched_prune(self, n: int, m: int,
                             engines_for_sched: int = 1,
                             sweeps: int = 4):
        """Fused pre-prune of the global compatibility mask ON the
        accelerator (one kernel launch, mask resident in on-chip memory
        for the whole fixpoint loop): the cold-start cost every Tier-2
        (swarm) decision pays before its first epoch. ``sweeps`` is the
        observed/assumed fused-iteration count (the kernels'
        ``prune_sweeps`` observable); the pruned mask (n·m bytes, uint8)
        ships once over the NoC."""
        p = self.platform
        macs = self.matcher_prune_macs(n, m, sweeps)
        rate = (max(engines_for_sched, 1) * p.macs_per_engine * p.clock_hz
                * self.engine_util_matcher)
        t = macs / rate + n * m * self.avg_hops / p.noc_link_bw_bytes
        e = (macs * self.e_mac_int8
             + n * m * self.avg_hops * self.e_noc_byte_hop)
        return t, e

    def sched_immsched(self, n: int, m: int, cfg: PSOConfig,
                       engines_for_sched: int):
        """IMMSched: matcher runs ON the accelerator (int8 datapath),
        particles parallel across engines; consensus via NoC."""
        p = self.platform
        macs = self.matcher_work_macs(n, m, cfg)
        rate = (engines_for_sched * p.macs_per_engine * p.clock_hz
                * self.engine_util_matcher)
        t_compute = macs / rate
        # per-epoch consensus: each engine ships one S (n·m bytes, uint8)
        consensus_bytes = cfg.epochs * engines_for_sched * n * m
        t_noc = consensus_bytes * self.avg_hops / (
            p.noc_link_bw_bytes * max(engines_for_sched // 2, 1))
        e = (macs * self.e_mac_int8
             + consensus_bytes * self.avg_hops * self.e_noc_byte_hop)
        return t_compute + t_noc, e

    def sched_immsched_revalidate(self, n: int, m: int,
                                  engines_for_sched: int = 1,
                                  batch: int = 1):
        """Tier-0/1 pipeline decision: carry rebase + ONE structured
        projection + one feasibility/fitness verification on the
        accelerator — no swarm epochs. A batch of B revalidations spreads
        across the scheduling engines (the problems are independent), so
        latency grows with ceil(B/engines) while energy scales with B;
        only the verified mapping (n·m bytes) ships over the NoC."""
        p = self.platform
        project = float(n) * n * m                 # n masked-argmax sweeps
        verify = float(n) * m * m + float(n) * n * m   # M G Mᵀ ⊇ Q check
        macs_per = project + verify
        rate = p.macs_per_engine * p.clock_hz * self.engine_util_matcher
        eng = max(engines_for_sched, 1)
        rounds = (max(batch, 1) + eng - 1) // eng
        t_compute = rounds * macs_per / rate
        result_bytes = max(batch, 1) * n * m
        t_noc = result_bytes * self.avg_hops / p.noc_link_bw_bytes
        e = (max(batch, 1) * macs_per * self.e_mac_int8
             + result_bytes * self.avg_hops * self.e_noc_byte_hop)
        return t_compute + t_noc, e

    def sched_serial_cpu(self, mac_ops: float, nodes_visited: int):
        """IsoSched-like: serial subgraph matching on the host CPU
        (float32 ops, branchy backtracking)."""
        p = self.platform
        t = (mac_ops / (p.cpu_gops * 1e9)
             + nodes_visited * p.cpu_dispatch_overhead_s)
        e = t * self.cpu_watts
        return t, e

    def sched_lts_heuristic(self, num_tasks: int):
        """PREMA/Planaria/MoCA/CD-MSA-like: priority arithmetic + mapping
        tables on the CPU. Cheap per decision but still host-side."""
        t = 50e-6 + 10e-6 * num_tasks
        return t, t * self.cpu_watts
