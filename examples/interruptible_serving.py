"""Interruptible multi-DNN serving: an urgent task arrives unannounced
while the array is saturated; IMMSched preempts by largest slack, runs the
REAL PSO-Ullmann matcher on the freed engine subgraph, and the urgent task
meets its deadline. The same scenario under the serial-matching baseline
(IsoSched-like) and an LTS baseline (MoCA-like) is shown for contrast.

    PYTHONPATH=src python examples/interruptible_serving.py
"""
from repro.accel import EDGE
from repro.core.pso import PSOConfig
from repro.sched import SimConfig, Simulator, get_scheduler
from repro.sched.tasks import fixed_scenario
from repro.workloads import get_workload


def main():
    # three background nets saturate the array, then an urgent MobileNet
    workloads = [get_workload("unet"), get_workload("resnet50"),
                 get_workload("unet"), get_workload("mobilenetv2")]
    scenario = fixed_scenario(workloads, urgent_last=True)
    urgent = [t for t in scenario.tasks if t.urgent][0]
    print(f"urgent task: {urgent.name} arrives t={urgent.arrival * 1e3:.2f} ms "
          f"deadline t={urgent.deadline * 1e3:.2f} ms")

    for name, mode in (("immsched", "real"), ("isosched", "analytic"),
                       ("moca", "analytic")):
        cfg = SimConfig(platform=EDGE, matcher_mode=mode,
                        pso_cfg=PSOConfig(num_particles=32, epochs=2,
                                          inner_steps=6),
                        window_stages=2)
        r = Simulator(cfg, get_scheduler(name)).run(scenario)
        print(f"{name:9s} urgent deadline met: {r.urgent_met}/{r.urgent_total}"
              f"  mean latency {r.avg_total_latency * 1e3:8.3f} ms"
              f"  mean sched time {r.avg_sched_time * 1e6:9.1f} us"
              f"  energy/task {r.work_energy_per_task * 1e3:8.4f} mJ")


if __name__ == "__main__":
    main()
