"""Fused swarm-epoch benchmark: mega-kernel vs loose scan, per backend.

The fused epoch kernel (``kernels/epoch_fused.py``) runs the entire
inner-step loop of ``run_epoch`` — PSO update → requantize → fitness →
best tracking — as ONE launch with the particle state resident in VMEM,
where the loose path re-dispatches the per-step kernels inside a
``lax.scan`` and round-trips the state through HBM every step. This
bench times both, cold (first call: trace + compile + run) and warm
(median of repeats), **per kernel backend**, and cross-checks the fused
outputs bitwise against the loose ``ref`` oracle.

The loose baseline is reconstructed per backend exactly as the
pre-fusion ``run_epoch`` inner loop was written: ``bk.pso_update`` +
``pso._maybe_requantize`` + ``pso._fitness`` scanned over pre-drawn
per-step randoms, so on a TPU it genuinely issues K separate kernel
launches per epoch — the dispatch pattern the mega-kernel replaces.

Parity note: fused outputs are compared against the loose **ref**
oracle. ``ref`` and ``interpret`` fused paths are engineered bitwise
(asserted here, and in the test suite); the compiled ``pallas`` path on
TPU is recorded as both bitwise and allclose since float reduction
grouping on real hardware is not contractual.

The quantized-path rows also embed the analytic roofline
(``benchmarks.roofline.epoch_roofline``): MXU FLOPs and HBM bytes per
epoch, achieved FLOP/s at the measured warm latency, and utilization
against the TPU v5e roof (informational when measured on CPU — it
locates the wall-clock against a v5e roof, it does not rate the CPU).

Besides the inner loop, the bench times the epoch *tail* (projection,
Ullmann refinement, feasibility, elite consensus) as one fused launch
(``kernels/finish_fused.py``) against the split pre-fusion epilogue
(~8 loose dispatches including a redundant fitness recompute), the
end-to-end two-launch epoch against the fully split one, counts actual
seam launches per epoch via an instrumented backend (fused pipeline:
exactly 2 after the prologue), and embeds the analytic fused-vs-split
HBM byte model (``benchmarks.roofline.tail_hbm_bytes``).

Emits ``BENCH_epoch.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_epoch
           [--particles N] [--n N] [--m M] [--steps K] [--repeats R]
           [--backend ref|pallas|interpret|comma-list|all] [--smoke]
           [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import (epoch_e2e_hbm_bytes, epoch_roofline,
                                 tail_hbm_bytes)
from repro.core import graphs, pso
from repro.kernels import get_backend

_HYPER = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)


def default_backends() -> list:
    names = ["ref", "interpret"]
    if jax.default_backend() == "tpu":
        names.append("pallas")
    return names


def _epoch_inputs(seed: int, num_particles: int, n: int, m: int,
                  inner_steps: int):
    """Planted problem + a mid-swarm particle state for one epoch."""
    key = jax.random.PRNGKey(seed)
    kq, kt, k1, k2, k3, k4 = jax.random.split(key, 6)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    Q, G, mask = graphs.as_device_graphs(q, g)
    u = jax.random.uniform(k1, (num_particles, n, m)) \
        * mask[None].astype(jnp.float32)
    S = u / jnp.maximum(u.sum(-1, keepdims=True), 1e-9)
    V = jax.random.normal(k2, (num_particles, n, m)) * 0.1
    f_local = -jax.random.uniform(k3, (num_particles,)) * 100
    r_all = jax.random.uniform(k4, (inner_steps, num_particles, 3))
    return (S, V, S, f_local, S[0], jnp.float32(-1e6), S.mean(0),
            mask, Q, G, r_all)


def _make_loose_fn(backend: str, quantized: bool, num_particles: int,
                   inner_steps: int):
    """The pre-fusion run_epoch inner loop, dispatching per-step kernels
    through the given backend (K launches per epoch, state in HBM)."""
    cfg = pso.PSOConfig(num_particles=num_particles,
                        inner_steps=inner_steps, quantized=quantized,
                        backend=backend, **_HYPER)
    bk = get_backend(backend)

    @jax.jit
    def loose(S, V, S_local, f_local, S_star, f_star, S_bar,
              mask, Q, G, r_all):
        def inner(state, r):
            S, V, S_local, f_local, S_star, f_star, _ = state
            S, V = bk.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                                 **_HYPER)
            S = pso._maybe_requantize(S, mask, cfg)
            f = pso._fitness(S, Q, G, cfg)
            improved = f > f_local
            S_local = jnp.where(improved[:, None, None], S, S_local)
            f_local = jnp.maximum(f, f_local)
            b = jnp.argmax(f_local)
            better = f_local[b] > f_star
            S_star = jnp.where(better, S_local[b], S_star)
            f_star = jnp.where(better, f_local[b], f_star)
            return (S, V, S_local, f_local, S_star, f_star, f), f_star

        state0 = (S, V, S_local, f_local, S_star, f_star,
                  f_local.astype(jnp.float32))
        (S, V, S_local, f_local, S_star, f_star, f_last), trace = \
            jax.lax.scan(inner, state0, r_all)
        return S, S_star, f_star, trace, f_last

    return loose


def _make_split_tail_fn(backend: str, quantized: bool,
                        num_particles: int):
    """The pre-fusion epoch epilogue, verbatim: two structured
    projections, a greedy projection, the Ullmann refinement loop, two
    feasibility checks, a full fitness RECOMPUTE of the final swarm,
    and the top_k elite consensus — ~8 loose dispatches per epoch, the
    pattern the fused tail replaces."""
    cfg = pso.PSOConfig(num_particles=num_particles, quantized=quantized,
                        backend=backend)
    bk = get_backend(backend)

    @jax.jit
    def split_tail(S, mask, Q, G):
        M_a = jax.vmap(lambda s: bk.structured_project(s, Q, G, mask))(S)
        feas_a = jax.vmap(bk.is_feasible,
                          in_axes=(0, None, None))(M_a, Q, G)
        M_proj = jax.vmap(lambda s: bk.greedy_project(s, mask))(S)
        M_b, _ = bk.ullmann_refine_candidates(
            S, M_proj, Q, G, mask,
            refine_threshold=cfg.refine_threshold,
            refine_iters=cfg.refine_iters)
        feas_b = jax.vmap(bk.is_feasible,
                          in_axes=(0, None, None))(M_b, Q, G)
        M_hat = jnp.where(feas_a[:, None, None], M_a, M_b)
        feasible = feas_a | feas_b
        f_final = pso._fitness(S, Q, G, cfg)   # the eliminated launch
        k = max(1, int(round(cfg.elite_frac * num_particles)))
        S_bar, _, _ = bk.elite_consensus(
            S, f_final, elite_k=k, consensus_temp=cfg.consensus_temp)
        return M_hat.astype(jnp.uint8), feasible, S_bar

    return split_tail


def _count_epoch_launches(backend: str, quantized: bool, inputs) -> dict:
    """Seam-call census of one ``run_epoch``: wrap every KernelBackend
    entry point with a counter and run a real epoch through it. With
    the fused tail, everything after the prologue's initial fitness is
    exactly TWO launches (epoch_fused + epoch_finish_batch)."""
    import collections

    from repro.kernels import backend as kb

    counts = collections.Counter()

    class Counting(kb.KernelBackend):
        pass

    for name in kb.KERNEL_NAMES:
        def _wrap(n=name, inner=getattr(kb.KernelBackend, name)):
            def meth(self, *a, **k):
                counts[n] += 1
                return inner(self, *a, **k)
            meth.__doc__ = inner.__doc__
            return meth
        setattr(Counting, name, _wrap())

    S, V, _, f_local, S_star, f_star, S_bar, mask, Q, G, r_all = inputs
    try:
        kb.register_backend(Counting("bench-counting",
                                     ops_backend=backend))
        cfg = pso.PSOConfig(num_particles=S.shape[0],
                            inner_steps=r_all.shape[0],
                            quantized=quantized,
                            backend="bench-counting")
        carry0 = (S_star, f_star, S_bar)
        pso.run_epoch(carry0, jax.random.PRNGKey(0), Q, G, mask, cfg)
    finally:
        kb._REGISTRY.pop("bench-counting", None)

    # the single-problem epoch_fused/epoch_finish wrappers delegate to
    # the batch entry points — count each launch once, not twice
    total = (sum(counts.values()) - counts["epoch_finish"]
             - counts["epoch_fused"])
    prologue = (counts["quantize_s"] + counts["edge_fitness_quantized"]
                if quantized else counts["edge_fitness"])
    return {
        "seam_calls": dict(counts),
        "launches_total": int(total),
        "launches_prologue": int(prologue),
        "launches_epoch": int(total - prologue),
    }


def _time_cold_warm(fn, repeats: int):
    """(cold_s, warm_median_s): first call includes trace+compile."""
    t0 = time.perf_counter()
    fn()
    cold = time.perf_counter() - t0
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return cold, statistics.median(walls)


def _leaves(outs):
    return [np.asarray(x) for x in outs]


def bench_path(backend: str, quantized: bool, inputs, oracle,
               num_particles: int, inner_steps: int,
               repeats: int) -> dict:
    """Fused vs loose latency + parity for one (backend, dtype) path."""
    bk = get_backend(backend)

    # Jit the seam call: in production run_epoch invokes it under
    # pso.match's jit, so the wrapper's batching reshapes are traced
    # away — measuring it eagerly would time dispatch overhead instead
    # of the kernel.
    fused_jit = jax.jit(lambda *a: bk.epoch_fused(
        *a, quantized=quantized, **_HYPER))

    def fused():
        outs = fused_jit(*inputs)
        jax.block_until_ready(outs[2])
        return outs

    loose_fn = _make_loose_fn(backend, quantized, num_particles,
                              inner_steps)

    def loose():
        outs = loose_fn(*inputs)
        jax.block_until_ready(outs[2])
        return outs

    cold_fused, warm_fused = _time_cold_warm(fused, repeats)
    cold_loose, warm_loose = _time_cold_warm(loose, repeats)
    got = _leaves(fused())
    bitwise = all(np.array_equal(a, b) for a, b in zip(got, oracle))
    close = all(np.allclose(a, b, rtol=1e-5, atol=1e-4)
                for a, b in zip(got, oracle))
    return {
        "cold_fused_s": cold_fused,
        "warm_fused_median_s": warm_fused,
        "cold_loose_s": cold_loose,
        "warm_loose_median_s": warm_loose,
        "fused_over_loose_ratio": warm_fused / max(warm_loose, 1e-12),
        "parity_bitwise_vs_ref_oracle": bitwise,
        "parity_allclose_vs_ref_oracle": close,
    }


_TAIL_STATICS = dict(gumbel_tau=0.0, refine_threshold=0.5,
                     refine_iters=6, elite_k=8, consensus_temp=25.0)


def bench_tail(backend: str, quantized: bool, inputs, tail_oracle,
               num_particles: int, repeats: int) -> dict:
    """Fused tail vs split (pre-fusion) epilogue for one backend path.

    The fused tail consumes the threaded last-step fitness; the split
    tail recomputes it — that recompute launch is part of what fusion
    eliminates, so it is (deliberately) inside the split timing.

    The tail's correctness GATE is ``parity_allclose_vs_ref_oracle``:
    the kernel-body program and the ref program can group the elite
    consensus einsum differently at some shapes (a 1-ulp ``S_bar``
    difference, input-dependent — the parity-sweep shapes in
    ``tests/test_backend.py`` stay bitwise), so strict equality is
    reported as a ``_diagnostic`` leaf that ``bench_report`` skips."""
    bk = get_backend(backend)
    cfg = pso.PSOConfig(num_particles=num_particles,
                        quantized=quantized, backend=backend)
    S, _, _, _, _, _, _, mask, Q, G, _ = inputs
    statics = dict(_TAIL_STATICS,
                   elite_k=max(1, int(round(cfg.elite_frac
                                            * num_particles))))
    f_final = pso._fitness(S, Q, G, cfg)
    fused_jit = jax.jit(lambda s, f, mk, q, g: bk.epoch_finish(
        s, f, None, mk, q, g, **statics))

    def fused():
        outs = fused_jit(S, f_final, mask, Q, G)
        jax.block_until_ready(outs[2])
        return outs

    split_fn = _make_split_tail_fn(backend, quantized, num_particles)

    def split():
        outs = split_fn(S, mask, Q, G)
        jax.block_until_ready(outs[2])
        return outs

    cold_fused, warm_fused = _time_cold_warm(fused, repeats)
    cold_split, warm_split = _time_cold_warm(split, repeats)
    got = _leaves(fused())
    bitwise = all(np.array_equal(a, b)
                  for a, b in zip(got, tail_oracle))
    close = all(np.allclose(a, b, rtol=1e-5, atol=1e-4)
                for a, b in zip(got, tail_oracle))
    return {
        "cold_fused_s": cold_fused,
        "warm_fused_median_s": warm_fused,
        "cold_split_s": cold_split,
        "warm_split_median_s": warm_split,
        "fused_over_split_ratio": warm_fused / max(warm_split, 1e-12),
        "bitwise_vs_ref_oracle_diagnostic": bitwise,
        "parity_allclose_vs_ref_oracle": close,
    }


def bench_e2e(backend: str, quantized: bool, inputs,
              num_particles: int, repeats: int) -> dict:
    """End-to-end epoch latency: the two-launch fused pipeline
    (epoch_fused → epoch_finish) vs the fully split pre-fusion one
    (K-step loose scan → ~8-dispatch epilogue)."""
    bk = get_backend(backend)
    cfg = pso.PSOConfig(num_particles=num_particles,
                        quantized=quantized, backend=backend)
    statics = dict(_TAIL_STATICS,
                   elite_k=max(1, int(round(cfg.elite_frac
                                            * num_particles))))

    fused_jit = jax.jit(lambda *a: bk.epoch_fused(
        *a, quantized=quantized, **_HYPER))
    tail_jit = jax.jit(lambda s, f, mk, q, g: bk.epoch_finish(
        s, f, None, mk, q, g, **statics))
    mask, Q, G = inputs[7], inputs[8], inputs[9]

    def fused():
        S, _, _, _, f_last = fused_jit(*inputs)
        outs = tail_jit(S, f_last, mask, Q, G)
        jax.block_until_ready(outs[2])
        return outs

    loose_fn = _make_loose_fn(backend, quantized, num_particles,
                              inputs[10].shape[0])
    split_fn = _make_split_tail_fn(backend, quantized, num_particles)

    def split():
        S, _, _, _, _ = loose_fn(*inputs)
        outs = split_fn(S, mask, Q, G)
        jax.block_until_ready(outs[2])
        return outs

    cold_fused, warm_fused = _time_cold_warm(fused, repeats)
    cold_split, warm_split = _time_cold_warm(split, repeats)
    return {
        "cold_fused_s": cold_fused,
        "warm_fused_median_s": warm_fused,
        "cold_split_s": cold_split,
        "warm_split_median_s": warm_split,
        "fused_over_split_ratio": warm_fused / max(warm_split, 1e-12),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=32)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--m", type=int, default=48)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--backend", type=str, default=None,
                    help="backend(s) to measure: a name, a comma list, "
                         "or 'all' (default: ref+interpret, plus pallas "
                         "on TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", type=str, default="BENCH_epoch.json")
    args = ap.parse_args()
    if args.smoke:
        args.particles, args.n, args.m = 8, 10, 20
        args.steps, args.repeats = 4, 3

    if args.backend in (None, "all"):
        backends = default_backends()
    else:
        backends = [b.strip() for b in args.backend.split(",") if b.strip()]

    inputs = _epoch_inputs(7, args.particles, args.n, args.m, args.steps)

    # Bitwise oracles: the loose ref scan and the split ref tail (the
    # pre-fusion semantics of the inner loop and the epilogue).
    oracle = {}
    tail_oracle = {}
    for quantized in (False, True):
        ref_loose = _make_loose_fn("ref", quantized, args.particles,
                                   args.steps)
        oracle[quantized] = _leaves(ref_loose(*inputs))
        ref_split = _make_split_tail_fn("ref", quantized, args.particles)
        tail_oracle[quantized] = _leaves(
            ref_split(inputs[0], inputs[7], inputs[8], inputs[9]))

    per_backend = {}
    roofline = {}
    launches = {}
    for backend in backends:
        blk = {}
        tail_blk = {}
        e2e_blk = {}
        for quantized in (False, True):
            path = "quantized" if quantized else "float"
            blk[path] = bench_path(backend, quantized, inputs,
                                   oracle[quantized], args.particles,
                                   args.steps, args.repeats)
            tail_blk[path] = bench_tail(backend, quantized, inputs,
                                        tail_oracle[quantized],
                                        args.particles, args.repeats)
            e2e_blk[path] = bench_e2e(backend, quantized, inputs,
                                      args.particles, args.repeats)
        per_backend[backend] = dict(blk, tail=tail_blk, e2e=e2e_blk)
        roofline[backend] = epoch_roofline(
            args.particles, args.n, args.m, args.steps, quantized=True,
            measured_s=blk["quantized"]["warm_fused_median_s"])
        launches[backend] = _count_epoch_launches(backend, False, inputs)

    strict = [b for b in backends if b in ("ref", "interpret")]
    parity_ok = all(
        per_backend[b][p]["parity_bitwise_vs_ref_oracle"]
        for b in strict for p in ("float", "quantized")) and all(
        per_backend[b][p]["parity_allclose_vs_ref_oracle"]
        for b in backends for p in ("float", "quantized"))
    tail_parity_ok = all(
        per_backend[b]["tail"][p]["parity_allclose_vs_ref_oracle"]
        for b in backends for p in ("float", "quantized"))

    tail_hbm = tail_hbm_bytes(args.particles, args.n, args.m,
                              refine_iters=6)
    e2e_hbm = epoch_e2e_hbm_bytes(args.particles, args.n, args.m,
                                  args.steps, refine_iters=6)

    result = {
        "smoke": bool(args.smoke),
        "particles": args.particles,
        "shape": [args.n, args.m],
        "inner_steps": args.steps,
        "repeats": args.repeats,
        "backends": per_backend,
        "roofline_quantized": roofline,
        "tail_hbm_bytes": tail_hbm,
        "e2e_hbm_bytes": e2e_hbm,
        "launches_per_epoch": launches,
        "parity_ok": parity_ok,
        "tail_parity_ok": tail_parity_ok,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("backend,path,metric,value")
    for backend, blk in per_backend.items():
        for path in ("float", "quantized"):
            row = blk[path]
            for k in ("cold_fused_s", "warm_fused_median_s",
                      "warm_loose_median_s", "fused_over_loose_ratio"):
                print(f"{backend},{path},{k},{row[k]:.6g}")
            print(f"{backend},{path},parity_bitwise,"
                  f"{row['parity_bitwise_vs_ref_oracle']}")
            trow = blk["tail"][path]
            print(f"{backend},{path},tail_warm_fused_s,"
                  f"{trow['warm_fused_median_s']:.6g}")
            print(f"{backend},{path},tail_fused_over_split,"
                  f"{trow['fused_over_split_ratio']:.6g}")
            erow = blk["e2e"][path]
            print(f"{backend},{path},e2e_warm_fused_s,"
                  f"{erow['warm_fused_median_s']:.6g}")
            print(f"{backend},{path},e2e_fused_over_split,"
                  f"{erow['fused_over_split_ratio']:.6g}")
        rf = roofline[backend]
        print(f"{backend},quantized,mxu_utilization_vs_v5e,"
              f"{rf['mxu_utilization_vs_v5e']:.3e}")
        print(f"{backend},-,launches_epoch,"
              f"{launches[backend]['launches_epoch']}")
    print(f"tail_hbm_fused_over_split,"
          f"{tail_hbm['fused_bytes'] / tail_hbm['split_bytes']:.4g}")
    print(f"parity_ok,{parity_ok}")
    print(f"tail_parity_ok,{tail_parity_ok}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
