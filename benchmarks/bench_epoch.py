"""Fused swarm-epoch benchmark: mega-kernel vs loose scan, per backend.

The fused epoch kernel (``kernels/epoch_fused.py``) runs the entire
inner-step loop of ``run_epoch`` — PSO update → requantize → fitness →
best tracking — as ONE launch with the particle state resident in VMEM,
where the loose path re-dispatches the per-step kernels inside a
``lax.scan`` and round-trips the state through HBM every step. This
bench times both, cold (first call: trace + compile + run) and warm
(median of repeats), **per kernel backend**, and cross-checks the fused
outputs bitwise against the loose ``ref`` oracle.

The loose baseline is reconstructed per backend exactly as the
pre-fusion ``run_epoch`` inner loop was written: ``bk.pso_update`` +
``pso._maybe_requantize`` + ``pso._fitness`` scanned over pre-drawn
per-step randoms, so on a TPU it genuinely issues K separate kernel
launches per epoch — the dispatch pattern the mega-kernel replaces.

Parity note: fused outputs are compared against the loose **ref**
oracle. ``ref`` and ``interpret`` fused paths are engineered bitwise
(asserted here, and in the test suite); the compiled ``pallas`` path on
TPU is recorded as both bitwise and allclose since float reduction
grouping on real hardware is not contractual.

The quantized-path rows also embed the analytic roofline
(``benchmarks.roofline.epoch_roofline``): MXU FLOPs and HBM bytes per
epoch, achieved FLOP/s at the measured warm latency, and utilization
against the TPU v5e roof (informational when measured on CPU — it
locates the wall-clock against a v5e roof, it does not rate the CPU).

Emits ``BENCH_epoch.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_epoch
           [--particles N] [--n N] [--m M] [--steps K] [--repeats R]
           [--backend ref|pallas|interpret|comma-list|all] [--smoke]
           [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import epoch_roofline
from repro.core import graphs, pso
from repro.kernels import get_backend

_HYPER = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)


def default_backends() -> list:
    names = ["ref", "interpret"]
    if jax.default_backend() == "tpu":
        names.append("pallas")
    return names


def _epoch_inputs(seed: int, num_particles: int, n: int, m: int,
                  inner_steps: int):
    """Planted problem + a mid-swarm particle state for one epoch."""
    key = jax.random.PRNGKey(seed)
    kq, kt, k1, k2, k3, k4 = jax.random.split(key, 6)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    Q, G, mask = graphs.as_device_graphs(q, g)
    u = jax.random.uniform(k1, (num_particles, n, m)) \
        * mask[None].astype(jnp.float32)
    S = u / jnp.maximum(u.sum(-1, keepdims=True), 1e-9)
    V = jax.random.normal(k2, (num_particles, n, m)) * 0.1
    f_local = -jax.random.uniform(k3, (num_particles,)) * 100
    r_all = jax.random.uniform(k4, (inner_steps, num_particles, 3))
    return (S, V, S, f_local, S[0], jnp.float32(-1e6), S.mean(0),
            mask, Q, G, r_all)


def _make_loose_fn(backend: str, quantized: bool, num_particles: int,
                   inner_steps: int):
    """The pre-fusion run_epoch inner loop, dispatching per-step kernels
    through the given backend (K launches per epoch, state in HBM)."""
    cfg = pso.PSOConfig(num_particles=num_particles,
                        inner_steps=inner_steps, quantized=quantized,
                        backend=backend, **_HYPER)
    bk = get_backend(backend)

    @jax.jit
    def loose(S, V, S_local, f_local, S_star, f_star, S_bar,
              mask, Q, G, r_all):
        def inner(state, r):
            S, V, S_local, f_local, S_star, f_star = state
            S, V = bk.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                                 **_HYPER)
            S = pso._maybe_requantize(S, mask, cfg)
            f = pso._fitness(S, Q, G, cfg)
            improved = f > f_local
            S_local = jnp.where(improved[:, None, None], S, S_local)
            f_local = jnp.maximum(f, f_local)
            b = jnp.argmax(f_local)
            better = f_local[b] > f_star
            S_star = jnp.where(better, S_local[b], S_star)
            f_star = jnp.where(better, f_local[b], f_star)
            return (S, V, S_local, f_local, S_star, f_star), f_star

        (S, V, S_local, f_local, S_star, f_star), trace = jax.lax.scan(
            inner, (S, V, S_local, f_local, S_star, f_star), r_all)
        return S, S_star, f_star, trace

    return loose


def _time_cold_warm(fn, repeats: int):
    """(cold_s, warm_median_s): first call includes trace+compile."""
    t0 = time.perf_counter()
    fn()
    cold = time.perf_counter() - t0
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return cold, statistics.median(walls)


def _leaves(outs):
    return [np.asarray(x) for x in outs]


def bench_path(backend: str, quantized: bool, inputs, oracle,
               num_particles: int, inner_steps: int,
               repeats: int) -> dict:
    """Fused vs loose latency + parity for one (backend, dtype) path."""
    bk = get_backend(backend)

    # Jit the seam call: in production run_epoch invokes it under
    # pso.match's jit, so the wrapper's batching reshapes are traced
    # away — measuring it eagerly would time dispatch overhead instead
    # of the kernel.
    fused_jit = jax.jit(lambda *a: bk.epoch_fused(
        *a, quantized=quantized, **_HYPER))

    def fused():
        outs = fused_jit(*inputs)
        jax.block_until_ready(outs[2])
        return outs

    loose_fn = _make_loose_fn(backend, quantized, num_particles,
                              inner_steps)

    def loose():
        outs = loose_fn(*inputs)
        jax.block_until_ready(outs[2])
        return outs

    cold_fused, warm_fused = _time_cold_warm(fused, repeats)
    cold_loose, warm_loose = _time_cold_warm(loose, repeats)
    got = _leaves(fused())
    bitwise = all(np.array_equal(a, b) for a, b in zip(got, oracle))
    close = all(np.allclose(a, b, rtol=1e-5, atol=1e-4)
                for a, b in zip(got, oracle))
    return {
        "cold_fused_s": cold_fused,
        "warm_fused_median_s": warm_fused,
        "cold_loose_s": cold_loose,
        "warm_loose_median_s": warm_loose,
        "fused_over_loose_ratio": warm_fused / max(warm_loose, 1e-12),
        "parity_bitwise_vs_ref_oracle": bitwise,
        "parity_allclose_vs_ref_oracle": close,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=32)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--m", type=int, default=48)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--backend", type=str, default=None,
                    help="backend(s) to measure: a name, a comma list, "
                         "or 'all' (default: ref+interpret, plus pallas "
                         "on TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", type=str, default="BENCH_epoch.json")
    args = ap.parse_args()
    if args.smoke:
        args.particles, args.n, args.m = 8, 10, 20
        args.steps, args.repeats = 4, 3

    if args.backend in (None, "all"):
        backends = default_backends()
    else:
        backends = [b.strip() for b in args.backend.split(",") if b.strip()]

    inputs = _epoch_inputs(7, args.particles, args.n, args.m, args.steps)

    # Bitwise oracle: the loose ref scan (the pre-fusion semantics).
    oracle = {}
    for quantized in (False, True):
        ref_loose = _make_loose_fn("ref", quantized, args.particles,
                                   args.steps)
        oracle[quantized] = _leaves(ref_loose(*inputs))

    per_backend = {}
    roofline = {}
    for backend in backends:
        blk = {}
        for quantized in (False, True):
            path = "quantized" if quantized else "float"
            blk[path] = bench_path(backend, quantized, inputs,
                                   oracle[quantized], args.particles,
                                   args.steps, args.repeats)
        per_backend[backend] = blk
        roofline[backend] = epoch_roofline(
            args.particles, args.n, args.m, args.steps, quantized=True,
            measured_s=blk["quantized"]["warm_fused_median_s"])

    strict = [b for b in backends if b in ("ref", "interpret")]
    parity_ok = all(
        per_backend[b][p]["parity_bitwise_vs_ref_oracle"]
        for b in strict for p in ("float", "quantized")) and all(
        per_backend[b][p]["parity_allclose_vs_ref_oracle"]
        for b in backends for p in ("float", "quantized"))

    result = {
        "smoke": bool(args.smoke),
        "particles": args.particles,
        "shape": [args.n, args.m],
        "inner_steps": args.steps,
        "repeats": args.repeats,
        "backends": per_backend,
        "roofline_quantized": roofline,
        "parity_ok": parity_ok,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("backend,path,metric,value")
    for backend, blk in per_backend.items():
        for path, row in blk.items():
            for k in ("cold_fused_s", "warm_fused_median_s",
                      "warm_loose_median_s", "fused_over_loose_ratio"):
                print(f"{backend},{path},{k},{row[k]:.6g}")
            print(f"{backend},{path},parity_bitwise,"
                  f"{row['parity_bitwise_vs_ref_oracle']}")
        rf = roofline[backend]
        print(f"{backend},quantized,mxu_utilization_vs_v5e,"
              f"{rf['mxu_utilization_vs_v5e']:.3e}")
    print(f"parity_ok,{parity_ok}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
