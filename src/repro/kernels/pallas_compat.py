"""Version-compat shims for Pallas TPU symbols + capability probes.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` on 0.4.x, ``CompilerParams`` later). Kernel modules
import ``CompilerParams`` from here instead of reaching into
``jax.experimental.pallas.tpu`` directly.

This module also hosts the **buffer-donation capability probes** the
service's device-resident drain pipeline gates on. Donation
(``jax.jit(..., donate_argnums=...)``) is a documented API but its
*effect* varies by backend and release: some platforms silently ignore
donation (with a warning), and a ``jax.export`` round trip may or may
not preserve the input/output aliasing. Rather than pinning behaviour to
version numbers, :func:`donation_supported` and
:func:`export_preserves_donation` each run a one-shot empirical probe
(a tiny jit on this process's default backend) and cache the verdict, so
callers — and tests — can skip cleanly where the toolchain degrades.
``requirements-dev.txt`` pins the JAX lower bound where the probes are
meaningful at all (donate_argnums + ``jax.export`` interop).
"""
from __future__ import annotations

import functools
import warnings

import numpy as np

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:
    CompilerParams = pltpu.TPUCompilerParams


def _probe_donation(call_through_export: bool) -> bool:
    """Shared probe body: donate a buffer into a tiny jit (optionally
    round-tripped through ``jax.export`` serialize/deserialize) and
    report whether the input buffer was actually consumed."""
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x: x + jnp.float32(1.0), donate_argnums=(0,))
    x = jax.device_put(np.ones((8,), np.float32))
    with warnings.catch_warnings():
        # platforms that ignore donation warn about unused donations;
        # the probe's verdict is the deletion check, not the warning
        warnings.simplefilter("ignore")
        if call_through_export:
            from jax import export as jax_export
            exported = jax_export.export(fn)(x)
            rebuilt = jax_export.deserialize(
                bytearray(exported.serialize()))
            y = rebuilt.call(x)
        else:
            y = fn(x)
        jax.block_until_ready(y)
    deleted = getattr(x, "is_deleted", None)
    return bool(deleted()) if callable(deleted) else False


@functools.lru_cache(maxsize=None)
def donation_supported() -> bool:
    """True when ``donate_argnums`` actually consumes input buffers on
    this process's default backend (probed once, cached). False means
    donation is a silent no-op here — the service then skips threading
    donation through its executables, losing only the in-place-update
    memory saving, never correctness."""
    try:
        return _probe_donation(call_through_export=False)
    except Exception:  # pragma: no cover - exotic backends/builds
        return False


@functools.lru_cache(maxsize=None)
def export_preserves_donation() -> bool:
    """True when a ``jax.export`` serialize → deserialize → call round
    trip keeps the donated-input aliasing of the original jit (probed
    once, cached). When False, AOT-cached executables run correctly but
    without the in-place carry update — the service warns loudly instead
    of silently losing the memory benefit across restarts."""
    try:
        return _probe_donation(call_through_export=True)
    except Exception:  # pragma: no cover - export-less jax builds
        return False
