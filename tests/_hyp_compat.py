"""Hypothesis compatibility shim for environments without `hypothesis`.

Exports ``given``, ``settings`` and ``st`` — the real thing when the
package is installed (see requirements-dev.txt), otherwise a minimal
deterministic fallback covering the subset these tests use:

  * ``st.integers(lo, hi)``  — uniform integer draws
  * ``st.booleans()``        — fair coin
  * ``st.floats(lo, hi)``    — uniform float draws
  * ``st.sampled_from(seq)`` — uniform choice from a sequence
  * ``st.lists(elem, ...)``  — lists of another strategy's draws
  * ``st.just(value)``       — constant
  * ``st.randoms()``         — a seeded ``random.Random`` instance
  * ``st.composite``         — ``fn(draw, ...)``-style composite
    strategies (the scenario-spec fuzzer builds on this)
  * ``@settings(max_examples=N, deadline=...)`` — example-count control
    (place ABOVE ``@given`` in the fallback; unknown keywords like
    ``derandomize`` are accepted and ignored)
  * ``@given(*strategies)``  — runs the test once per seeded example

The fallback is exhaustive-deterministic (fixed seed per example index),
so failures reproduce without hypothesis's shrinking machinery. Import as

    from _hyp_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random

    _BASE_SEED = 0x1A55C0DE

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rnd):
            return self._draw_fn(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rnd: elements[rnd.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None, **_kw):
            def draw(rnd):
                hi = max_size if max_size is not None else min_size + 8
                return [elements.draw(rnd)
                        for _ in range(rnd.randint(min_size, hi))]
            return _Strategy(draw)

        @staticmethod
        def just(value):
            return _Strategy(lambda rnd: value)

        @staticmethod
        def randoms(**_kw):
            return _Strategy(
                lambda rnd: random.Random(rnd.randint(0, 2**31 - 1)))

        @staticmethod
        def composite(fn):
            # mirrors hypothesis: `@st.composite def s(draw, *a)` makes
            # `s(*a)` a strategy; the injected `draw` resolves nested
            # strategies against the current example's RNG
            def make(*args, **kw):
                return _Strategy(
                    lambda rnd: fn(lambda s: s.draw(rnd), *args, **kw))
            return make

    st = _Strategies()

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — the wrapper must expose a
            # zero-argument signature or pytest mistakes the strategy
            # parameters for fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                for example in range(n):
                    rnd = random.Random(_BASE_SEED + 7919 * example)
                    drawn = [s.draw(rnd) for s in strategies]
                    fn(*drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
