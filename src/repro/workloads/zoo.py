"""Workload zoo: the paper's nine evaluation models + LM-config lowering.

Simple  (AR/VR):  MobileNetV2, ResNet50, UNet
Middle  (NAS):    EfficientNet-B0, NASNet-A, PNASNet-5
Complex (LLM):    DeepSeek-7B, Qwen-7B, Llama-3-8B

Layer graphs are structural models (kinds, MAC counts, activation bytes,
branch topology) — faithful enough for scheduling/energy studies; they are
*not* the numerics (the numerics live in ``repro.models``). LM workloads can
also be generated from any ``repro.configs`` architecture via
``lm_workload_from_config`` — this is how the framework's 10 assigned
architectures plug into the paper's scheduler as first-class workloads.
"""
from __future__ import annotations

from typing import Dict

from repro.workloads.layers import (Builder, LayerKind, WorkloadGraph,
                                    conv_macs, conv_out_bytes)

K = LayerKind


# ---------------------------------------------------------------------------
# Simple
# ---------------------------------------------------------------------------

def mobilenet_v2(res: int = 224) -> WorkloadGraph:
    b = Builder("mobilenetv2")
    h = res // 2
    b.add("stem", K.CONV, conv_macs(3, 32, 3, h, h), conv_out_bytes(32, h, h))
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    cin = 32
    for t, c, n, s in cfg:
        for i in range(n):
            stride = s if i == 0 else 1
            h = max(h // stride, 7)
            hid = cin * t
            p = b.add(f"ir{c}_{i}.expand", K.CONV,
                      conv_macs(cin, hid, 1, h, h), conv_out_bytes(hid, h, h))
            b.add(f"ir{c}_{i}.dw", K.CONV, 9.0 * hid * h * h,
                  conv_out_bytes(hid, h, h))
            b.add(f"ir{c}_{i}.project", K.CONV,
                  conv_macs(hid, c, 1, h, h), conv_out_bytes(c, h, h))
            if stride == 1 and cin == c:
                b.add(f"ir{c}_{i}.add", K.ELEMENTWISE, c * h * h,
                      conv_out_bytes(c, h, h), preds=[p - 1, len(b.layers) - 1])
            cin = c
    b.add("head", K.CONV, conv_macs(cin, 1280, 1, 7, 7),
          conv_out_bytes(1280, 7, 7))
    b.add("pool", K.POOL, 1280 * 49, 1280)
    b.add("fc", K.MATMUL, 1280 * 1000, 1000)
    return b.build()


def resnet50(res: int = 224) -> WorkloadGraph:
    b = Builder("resnet50")
    h = res // 4
    b.add("stem", K.CONV, conv_macs(3, 64, 7, res // 2, res // 2),
          conv_out_bytes(64, h, h))
    b.add("maxpool", K.POOL, 64 * h * h, conv_out_bytes(64, h, h))
    cin = 64
    for stage, (c, n) in enumerate([(64, 3), (128, 4), (256, 6), (512, 3)]):
        if stage:
            h = h // 2
        for i in range(n):
            inp = len(b.layers) - 1
            b.add(f"s{stage}b{i}.c1", K.CONV, conv_macs(cin, c, 1, h, h),
                  conv_out_bytes(c, h, h), preds=[inp])
            b.add(f"s{stage}b{i}.c2", K.CONV, conv_macs(c, c, 3, h, h),
                  conv_out_bytes(c, h, h))
            b.add(f"s{stage}b{i}.c3", K.CONV, conv_macs(c, 4 * c, 1, h, h),
                  conv_out_bytes(4 * c, h, h))
            b.add(f"s{stage}b{i}.add", K.ELEMENTWISE, 4 * c * h * h,
                  conv_out_bytes(4 * c, h, h),
                  preds=[inp, len(b.layers) - 1])
            cin = 4 * c
    b.add("pool", K.POOL, cin * h * h, cin)
    b.add("fc", K.MATMUL, cin * 1000, 1000)
    return b.build()


def unet(res: int = 256) -> WorkloadGraph:
    b = Builder("unet")
    enc_out = []
    h, cin = res, 3
    for d, c in enumerate([64, 128, 256, 512]):
        b.add(f"enc{d}.c1", K.CONV, conv_macs(cin, c, 3, h, h),
              conv_out_bytes(c, h, h))
        i = b.add(f"enc{d}.c2", K.CONV, conv_macs(c, c, 3, h, h),
                  conv_out_bytes(c, h, h))
        enc_out.append((i, c, h))
        b.add(f"enc{d}.pool", K.POOL, c * h * h, conv_out_bytes(c, h // 2,
                                                                h // 2))
        cin, h = c, h // 2
    b.add("mid.c1", K.CONV, conv_macs(cin, 1024, 3, h, h),
          conv_out_bytes(1024, h, h))
    b.add("mid.c2", K.CONV, conv_macs(1024, 1024, 3, h, h),
          conv_out_bytes(1024, h, h))
    cin = 1024
    for d, (skip, c, sh) in enumerate(reversed(enc_out)):
        h = h * 2
        b.add(f"dec{d}.up", K.CONV, conv_macs(cin, c, 2, h, h),
              conv_out_bytes(c, h, h))
        b.add(f"dec{d}.cat", K.ELEMENTWISE, c * h * h,
              conv_out_bytes(2 * c, h, h), preds=[skip, len(b.layers) - 1])
        b.add(f"dec{d}.c1", K.CONV, conv_macs(2 * c, c, 3, h, h),
              conv_out_bytes(c, h, h))
        b.add(f"dec{d}.c2", K.CONV, conv_macs(c, c, 3, h, h),
              conv_out_bytes(c, h, h))
        cin = c
    b.add("head", K.CONV, conv_macs(cin, 2, 1, h, h), conv_out_bytes(2, h, h))
    return b.build()


# ---------------------------------------------------------------------------
# Middle (NAS family) — cell-based topologies with branchy DAGs
# ---------------------------------------------------------------------------

def _nas_cell(b: Builder, name: str, cin: int, c: int, h: int,
              branches: int, inputs) -> int:
    outs = []
    for j in range(branches):
        src = inputs[j % len(inputs)]
        b.add(f"{name}.b{j}.sep", K.CONV, conv_macs(cin, c, 3, h, h) * 0.35,
              conv_out_bytes(c, h, h), preds=[src])
        o = b.add(f"{name}.b{j}.pw", K.CONV, conv_macs(c, c, 1, h, h),
                  conv_out_bytes(c, h, h))
        outs.append(o)
    return b.add(f"{name}.concat", K.ELEMENTWISE, c * branches * h * h,
                 conv_out_bytes(c * branches, h, h), preds=outs)


def efficientnet_b0(res: int = 224) -> WorkloadGraph:
    b = Builder("efficientnet")
    h = res // 2
    b.add("stem", K.CONV, conv_macs(3, 32, 3, h, h), conv_out_bytes(32, h, h))
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 40, 2, 2), (6, 80, 3, 2),
           (6, 112, 3, 1), (6, 192, 4, 2), (6, 320, 1, 1)]
    cin = 32
    for t, c, n, s in cfg:
        for i in range(n):
            h = max(h // (s if i == 0 else 1), 7)
            hid = cin * t
            b.add(f"mb{c}_{i}.expand", K.CONV, conv_macs(cin, hid, 1, h, h),
                  conv_out_bytes(hid, h, h))
            b.add(f"mb{c}_{i}.dw", K.CONV, 25.0 * hid * h * h,
                  conv_out_bytes(hid, h, h))
            b.add(f"mb{c}_{i}.se", K.REDUCE, hid * h * h,
                  conv_out_bytes(hid, 1, 1))
            b.add(f"mb{c}_{i}.project", K.CONV, conv_macs(hid, c, 1, h, h),
                  conv_out_bytes(c, h, h))
            cin = c
    b.add("head", K.CONV, conv_macs(cin, 1280, 1, 7, 7),
          conv_out_bytes(1280, 7, 7))
    b.add("pool", K.POOL, 1280 * 49, 1280)
    b.add("fc", K.MATMUL, 1280 * 1000, 1000)
    return b.build()


def nasnet_a(res: int = 224) -> WorkloadGraph:
    b = Builder("nasnet")
    h = res // 4
    prev = b.add("stem", K.CONV, conv_macs(3, 96, 3, res // 2, res // 2),
                 conv_out_bytes(96, h, h))
    cin = 96
    for stage, (c, n) in enumerate([(168, 4), (336, 4), (672, 4)]):
        if stage:
            h = max(h // 2, 7)
        for i in range(n):
            prev2 = max(prev - 1, 0)
            prev = _nas_cell(b, f"s{stage}c{i}", cin, c // 4, h, 5,
                             [prev, prev2])
            cin = c * 5 // 4
    b.add("pool", K.POOL, cin * h * h, cin)
    b.add("fc", K.MATMUL, cin * 1000, 1000)
    return b.build()


def pnasnet_5(res: int = 224) -> WorkloadGraph:
    b = Builder("pnasnet")
    h = res // 4
    prev = b.add("stem", K.CONV, conv_macs(3, 96, 3, res // 2, res // 2),
                 conv_out_bytes(96, h, h))
    cin = 96
    for stage, (c, n) in enumerate([(270, 3), (540, 3), (1080, 3)]):
        if stage:
            h = max(h // 2, 7)
        for i in range(n):
            prev2 = max(prev - 1, 0)
            prev = _nas_cell(b, f"s{stage}c{i}", cin, c // 5, h, 5,
                             [prev, prev2])
            cin = c
    b.add("pool", K.POOL, cin * h * h, cin)
    b.add("fc", K.MATMUL, cin * 1000, 1000)
    return b.build()


# ---------------------------------------------------------------------------
# Complex (LLM decode-step workloads: per-token transformer DAGs)
# ---------------------------------------------------------------------------

def _llm_workload(name: str, layers: int, d_model: int, d_ff: int,
                  n_heads: int, kv_heads: int, vocab: int,
                  seq_ctx: int = 2048, qkv_bias: bool = False,
                  block_group: int = 0) -> WorkloadGraph:
    """Per-token decode DAG for the full model (``block_group`` > 0
    truncates to that many blocks — used when callers want just a
    scheduler-window-sized graph). The preemptible-DAG window bounds the
    matcher size regardless, so the default models all layers."""
    b = Builder(name)
    head_dim = d_model // n_heads
    act = 2.0  # bf16 activation bytes
    b.add("embed", K.EMBED, d_model, d_model * act)
    for l in range(block_group if block_group > 0 else layers):
        b.add(f"l{l}.ln1", K.NORM, d_model, d_model * act)
        q = b.add(f"l{l}.q", K.MATMUL, d_model * d_model, d_model * act)
        kv = b.add(f"l{l}.kv", K.MATMUL,
                   2 * d_model * kv_heads * head_dim,
                   2 * kv_heads * head_dim * act, preds=[q - 1])
        b.add(f"l{l}.attn", K.ATTN, 2.0 * seq_ctx * d_model,
              d_model * act, preds=[q, kv])
        b.add(f"l{l}.o", K.MATMUL, d_model * d_model, d_model * act)
        r1 = b.add(f"l{l}.res1", K.ELEMENTWISE, d_model, d_model * act,
                   preds=[q - 1, len(b.layers) - 1])
        b.add(f"l{l}.ln2", K.NORM, d_model, d_model * act)
        g = b.add(f"l{l}.ffn_gate", K.MATMUL, d_model * d_ff, d_ff * act)
        u = b.add(f"l{l}.ffn_up", K.MATMUL, d_model * d_ff, d_ff * act,
                  preds=[g - 1])
        b.add(f"l{l}.ffn_mul", K.ELEMENTWISE, d_ff, d_ff * act, preds=[g, u])
        b.add(f"l{l}.ffn_down", K.MATMUL, d_ff * d_model, d_model * act)
        b.add(f"l{l}.res2", K.ELEMENTWISE, d_model, d_model * act,
              preds=[r1, len(b.layers) - 1])
    b.add("final_ln", K.NORM, d_model, d_model * act)
    b.add("lm_head", K.MATMUL, d_model * vocab, vocab * act)
    wg = b.build()
    wg.name = name
    return wg


def deepseek_7b() -> WorkloadGraph:
    return _llm_workload("deepseek-7b", 30, 4096, 11008, 32, 32, 102400)


def qwen_7b() -> WorkloadGraph:
    return _llm_workload("qwen-7b", 32, 4096, 11008, 32, 32, 151936,
                         qkv_bias=True)


def llama3_8b_workload() -> WorkloadGraph:
    return _llm_workload("llama3-8b", 32, 4096, 14336, 32, 8, 128256)


def lm_workload_from_config(cfg, seq_ctx: int = 2048,
                            block_group: int = 0) -> WorkloadGraph:
    """Lower any repro.configs model config to a scheduler workload —
    the bridge between the training/serving framework and the paper's
    scheduler."""
    d_ff = cfg.d_ff if cfg.d_ff else 4 * cfg.d_model
    return _llm_workload(cfg.name, cfg.num_layers, cfg.d_model, d_ff,
                         cfg.num_heads, cfg.kv_heads, cfg.vocab_size,
                         seq_ctx=seq_ctx, block_group=block_group)


WORKLOAD_ZOO: Dict[str, object] = {
    "mobilenetv2": mobilenet_v2,
    "resnet50": resnet50,
    "unet": unet,
    "efficientnet": efficientnet_b0,
    "nasnet": nasnet_a,
    "pnasnet": pnasnet_5,
    "deepseek-7b": deepseek_7b,
    "qwen-7b": qwen_7b,
    "llama3-8b-wl": llama3_8b_workload,
}

_COMPLEXITY = {
    "simple": ["mobilenetv2", "resnet50", "unet"],
    "middle": ["efficientnet", "nasnet", "pnasnet"],
    "complex": ["deepseek-7b", "qwen-7b", "llama3-8b-wl"],
}


def get_workload(name: str) -> WorkloadGraph:
    return WORKLOAD_ZOO[name]()


def workload_complexity_class(cls: str):
    return [get_workload(n) for n in _COMPLEXITY[cls]]
