"""Kernel-backend layer: registry/selection precedence, and the parity
sweep — every kernel registered in ``KERNEL_NAMES`` must agree between the
Pallas suite (interpret mode) and the jnp oracle suite across shapes ×
mask dtypes, bitwise for integer outputs and allclose for float ones.
The sweep is driven off the registry itself: registering a kernel without
a parity case fails ``test_every_registered_kernel_has_parity_case``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pso
from repro.kernels import (ENV_VAR, KERNEL_NAMES, KernelBackend,
                           get_backend, register_backend,
                           registered_backends, resolve_backend_name)
from repro.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(1, 8, 16), (2, 40, 72)]
MASK_DTYPES = [jnp.uint8, jnp.int32]


class _Problem:
    """One random matching instance with planted singleton rows (so the
    injectivity half of the fused prune has work to do)."""

    def __init__(self, seed, B, n, m, mask_dtype):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        S = jax.random.uniform(k1, (B, n, m))
        self.S = S / S.sum(-1, keepdims=True)
        self.S_q = ref.quantize_s(self.S)
        Q = jax.random.bernoulli(k2, 0.3, (n, n)).astype(jnp.uint8)
        self.Q = jnp.triu(Q, k=1)                      # DAG
        G = jax.random.bernoulli(k3, 0.4, (m, m)).astype(jnp.uint8)
        self.G = jnp.triu(G, k=1)
        mask = jax.random.bernoulli(k4, 0.8, (n, m))
        mask = mask.at[:, 0].set(True)                 # no empty rows
        # plant singletons: rows 0 and n//2 keep exactly one candidate,
        # claiming their columns from every other row on the first
        # injectivity propagation
        for i, j in ((0, 1), (n // 2, min(3, m - 1))):
            mask = mask.at[i, :].set(False).at[i, j].set(True)
        self.mask = mask.astype(mask_dtype)
        self.Mb = jnp.broadcast_to(self.mask, (B, n, m)
                                   ).astype(mask_dtype)
        self.V = jax.random.normal(k5, (B, n, m)) * 0.1
        self.r = jax.random.uniform(k1, (B, 3))
        # a projected assignment for the feasibility kernel
        self.M_hat = ref.greedy_project(self.S[0], self.mask)


_HYPER = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)

# Every registered kernel gets one invocation recipe; outputs are compared
# leaf-by-leaf across backends.
KERNEL_CASES = {
    "edge_fitness": lambda bk, p: bk.edge_fitness(p.S, p.Q, p.G),
    "edge_fitness_quantized":
        lambda bk, p: bk.edge_fitness_quantized(p.S_q, p.Q, p.G),
    "pso_update": lambda bk, p: bk.pso_update(
        p.S, p.V, p.S, p.S[0], p.S.mean(0), p.mask, p.r, **_HYPER),
    "ullmann_refine_step":
        lambda bk, p: bk.ullmann_refine_step(p.Mb, p.Q, p.G),
    "greedy_project": lambda bk, p: bk.greedy_project(p.S[0], p.mask),
    "masked_argmax": lambda bk, p: bk.masked_argmax(p.S[0], p.mask),
    "structured_project":
        lambda bk, p: bk.structured_project(p.S[0], p.Q, p.G, p.mask),
    "injectivity_prune": lambda bk, p: bk.injectivity_prune(p.mask),
    "is_feasible": lambda bk, p: bk.is_feasible(p.M_hat, p.Q, p.G),
    "prune_fixpoint": lambda bk, p: bk.prune_fixpoint(p.mask, p.Q, p.G),
    "prune_fixpoint_batch":
        lambda bk, p: bk.prune_fixpoint_batch(p.Mb, p.Q[None].repeat(
            p.Mb.shape[0], 0), p.G[None].repeat(p.Mb.shape[0], 0)),
    "quantize_s": lambda bk, p: bk.quantize_s(p.S),
    "dequantize_s": lambda bk, p: bk.dequantize_s(p.S_q),
    "row_normalize_quantized":
        lambda bk, p: bk.row_normalize_quantized(p.S_q[0], p.mask),
}


def _assert_leaves_match(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape
        if np.issubdtype(w.dtype, np.floating):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)
        else:
            np.testing.assert_array_equal(g, w)


def test_every_registered_kernel_has_parity_case():
    assert set(KERNEL_CASES) == set(KERNEL_NAMES)
    # and every backend actually provides every entry point
    for name in registered_backends():
        bk = get_backend(name)
        for k in KERNEL_NAMES:
            assert callable(getattr(bk, k))


@pytest.mark.parametrize("mask_dtype", MASK_DTYPES)
@pytest.mark.parametrize("B,n,m", SHAPES)
@pytest.mark.parametrize("kernel", sorted(KERNEL_CASES))
def test_backend_parity(kernel, B, n, m, mask_dtype):
    p = _Problem(hash((kernel, B, n, m)) % (2 ** 31), B, n, m, mask_dtype)
    got = KERNEL_CASES[kernel](get_backend("interpret"), p)
    want = KERNEL_CASES[kernel](get_backend("ref"), p)
    _assert_leaves_match(got, want)


# ---------------------- fused prune semantics ------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_prune_matches_legacy_alternation(backend):
    """The fused kernel must reproduce the original loose-jnp fixpoint
    (refine sweep alternating with injectivity prune) exactly, on a mask
    with planted singletons, and report ≥ 1 sweep."""
    p = _Problem(7, 1, 12, 20, jnp.uint8)
    legacy = ref.prune_mask_fixpoint(p.mask, p.Q, p.G)
    got, sweeps = get_backend(backend).prune_fixpoint(p.mask, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))
    assert int(sweeps) >= 1
    # idempotent: a fixpoint re-prunes to itself in one sweep
    again, sweeps2 = get_backend(backend).prune_fixpoint(got, p.Q, p.G)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(got))
    assert int(sweeps2) == 1


def test_fused_prune_sweep_counts_agree_across_backends():
    p = _Problem(11, 1, 10, 16, jnp.uint8)
    _, s_ref = get_backend("ref").prune_fixpoint(p.mask, p.Q, p.G)
    _, s_int = get_backend("interpret").prune_fixpoint(p.mask, p.Q, p.G)
    assert int(s_ref) == int(s_int)


def test_fused_prune_respects_iteration_budget():
    p = _Problem(13, 1, 12, 20, jnp.uint8)
    for bk_name in ("ref", "interpret"):
        bk = get_backend(bk_name)
        one, sweeps = bk.prune_fixpoint(p.mask, p.Q, p.G, max_iters=1)
        want = ref.injectivity_prune(
            ref.ullmann_refine_step(p.mask, p.Q, p.G))
        np.testing.assert_array_equal(np.asarray(one), np.asarray(want))
        assert int(sweeps) <= 1


# ---------------------- registry + selection precedence --------------------

def test_selection_precedence(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # 4. platform default (CPU → ref)
    assert resolve_backend_name() == "ref"
    assert resolve_backend_name(config=pso.PSOConfig()) == "ref"
    # 3. env override beats the default (and "auto" configs)
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert resolve_backend_name() == "interpret"
    assert resolve_backend_name(config=pso.PSOConfig(backend="auto")) \
        == "interpret"
    # 2. an explicit config beats the env
    assert resolve_backend_name(config=pso.PSOConfig(backend="ref")) == "ref"
    # 1. an explicit argument beats everything
    assert resolve_backend_name(
        "pallas", config=pso.PSOConfig(backend="ref")) == "pallas"
    assert get_backend("interpret").name == "interpret"


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(KeyError, match="registered"):
        get_backend("no-such-backend")


def test_register_custom_backend_roundtrip():
    class Custom(KernelBackend):
        pass

    try:
        register_backend(Custom("custom-test", ops_backend="ref"))
        assert "custom-test" in registered_backends()
        bk = get_backend("custom-test")
        assert isinstance(bk, Custom)
        p = _Problem(3, 1, 8, 16, jnp.uint8)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("custom-test", None)


def test_register_custom_backend_defaults_and_casing():
    """The documented recipe must work as written: a suite registered
    with no ops_backend runs its inherited kernels on the platform
    default path, and mixed-case names resolve through every selection
    route (names are normalized)."""
    try:
        register_backend(KernelBackend("MySuite"))
        bk = get_backend("MySuite")          # arg path, caller's casing
        assert bk.name == "mysuite"
        assert get_backend(config=pso.PSOConfig(backend="MySuite")) is bk
        p = _Problem(5, 1, 8, 16, jnp.uint8)
        # inherited kernel: platform default ("auto" → ref on CPU)
        _assert_leaves_match(bk.edge_fitness(p.S, p.Q, p.G),
                             get_backend("ref").edge_fitness(p.S, p.Q, p.G))
    finally:
        from repro.kernels.backend import _REGISTRY
        _REGISTRY.pop("mysuite", None)
    # an explicit dispatch tag the ops layer cannot honour fails loudly
    with pytest.raises(ValueError, match="dispatch tag"):
        KernelBackend("broken", ops_backend="no-such-tag")


# ---------------------- the seam end-to-end --------------------------------

@pytest.mark.slow
def test_match_runs_on_interpret_backend():
    """The whole Algorithm-1 program compiles and solves a planted
    instance with every kernel routed through the Pallas-interpret
    suite — the seam reaches every call site, not just the leaf tests."""
    from repro.core import graphs
    key = jax.random.PRNGKey(0)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, 4, 0.4)
    g = graphs.embed_query_in_target(kt, q, 8)
    Q, G, mask = graphs.as_device_graphs(q, g)
    cfg = pso.PSOConfig(num_particles=4, epochs=1, inner_steps=2,
                        refine_iters=2, backend="interpret")
    outs = pso.match(key, Q, G, mask, cfg)
    ref_cfg = cfg.replace(backend="ref")
    outs_ref = pso.match(key, Q, G, mask, ref_cfg)
    # same pruned search space, same sweep count, and both find the
    # planted embedding
    assert int(outs["prune_sweeps"]) == int(outs_ref["prune_sweeps"])
    assert bool(np.asarray(outs["feasible"]).any())
    assert bool(np.asarray(outs_ref["feasible"]).any())
