"""Pallas TPU kernel: the fused swarm-epoch mega-kernel.

Pre-fusion, one epoch of Algorithm 1 ran its K inner steps as a
``lax.scan`` over ~6 separate XLA ops (PSO update, optional requantize,
fitness, local/global best tracking), round-tripping the full particle
state ``(S, V, S_local, f_local)`` — three (N, n, m) float arrays plus a
fitness vector — through HBM on *every* inner step. At matcher problem
sizes the per-op launch overhead and that HBM traffic dominate epoch
latency (the RESPECT/edge-TPU setting the paper targets), so the loose
pipeline never approaches the MXU roofline.

This kernel runs the ENTIRE inner-step loop in one body: an in-kernel
``fori_loop`` over the K inner steps with ``S/V/S_local/f_local`` and
the pruned compatibility mask resident in VMEM for the whole epoch.
Only the epoch products ever leave the core: the final swarm ``S``
(consumed by projection/consensus), the global best ``(S_star,
f_star)`` and the per-step ``f_star`` trace. Per problem that replaces
``K × (3 reads + 3 writes)`` of the particle state with one read and
one write.

Grid: ``(P,)`` problems (the batched matcher's leading axis; a single
``run_epoch`` is P = 1), one grid step per problem so
``match_batch``/``revalidate_batch`` reuse the same body without a
vmap-of-pallas_call. Blocks are ``(1, N, n, m)`` for particle state,
``(1, n, m)`` for the controller state and mask, ``(1, K, N, r)`` for
the pre-drawn step randoms; ``f_star`` (in/out) and the ``(K,)`` trace
live in SMEM. VMEM at service scale (N = 64, n = m = 128 padded):
3 × 4 MB particle state + graphs + randoms ≈ 13 MB — inside a v5e
core's 16 MB. Larger problems need a particle-tiled variant (ROADMAP).

Bitwise-parity engineering (the acceptance bar is *bitwise* equality
with the loose scan on the ``ref`` ↔ ``interpret`` pair, including
``f_star_trace`` and RNG-draw order):

* **RNG**: ``jax.random`` cannot be called in-kernel, so the caller
  pre-draws ``r_all[k] = uniform(split(k_steps, K)[k], (N, 3))`` — a
  vmap over the same split keys the legacy scan consumed per step,
  which produces value-identical draws in the same order.
* **Normalization** uses real division (``S / max(row_sum, EPS)``)
  exactly like ``ref.pso_update`` — NOT the reciprocal-multiply of
  ``pso_update_pallas``, which is only allclose.
* **Global-best selection** replaces ``S_local[argmax(f_local)]`` with
  a one-hot masked sum (adding 0.0 is exact and S has no -0.0) and
  ``f_local[argmax]`` with ``max(f_local)`` (the same element).
* **Reductions** mirror the vmapped-ref lowering: one
  ``sum(axis=(1, 2))`` over the (N, n, n) residual, row sums over the
  last axis only. The ops layer therefore runs interpret mode
  UNPADDED; MXU padding (real TPU) preserves exactness of every
  integer op and is allclose on the float path (zero-padding can
  regroup f32 reductions by a last ulp).

The quantized path (§3.4) mirrors ``ref.quantize_s`` /
``ref.row_normalize_quantized`` / ``ref.edge_fitness_quantized`` in
int32 (uint8 values, wider registers): integer MACs and the Q1.15
reciprocal-multiply renormalize are order-independent, so they are
bitwise-safe even padded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.pallas_compat import CompilerParams


def epoch_inner_reference(S, V, S_local, f_local, S_star, f_star, S_bar,
                          mask, Q, G, r_all, *, omega, c1, c2, c3, v_max,
                          quantized=False):
    """Loose-jnp oracle of the fused epoch loop (ONE problem).

    This is the pre-fusion ``run_epoch`` inner ``lax.scan`` verbatim,
    with the per-step PRNG draws hoisted into ``r_all`` (K, N, 3) —
    value-identical to splitting inside the scan, see module docstring.
    Composed from the same ``ref.*`` building blocks the dispatch
    layer's ``ref`` backend uses, so it is the bitwise ground truth the
    Pallas body is tested against. Returns
    ``(S_final, S_star, f_star, f_trace, f_last)`` where ``f_last`` is
    the per-particle fitness of ``S_final`` — the value the epoch
    epilogue previously recomputed from scratch. It initializes from
    the ``f_local`` input (which equals ``fitness(S)`` for the real
    caller, ``_epoch_start``), so a degenerate K = 0 epoch still
    returns the fitness of the state it hands the epilogue.
    """
    upd = functools.partial(ref.pso_update, omega=omega, c1=c1, c2=c2,
                            c3=c3, v_max=v_max)

    def fitness(S):
        if quantized:
            S_q = ref.quantize_s(S)
            f = jax.vmap(ref.edge_fitness_quantized,
                         in_axes=(0, None, None))(S_q, Q, G)
            return f.astype(jnp.float32) / (255.0 ** 4)
        return jax.vmap(ref.edge_fitness, in_axes=(0, None, None))(S, Q, G)

    def inner(state, r):
        S, V, S_local, f_local, S_star, f_star, _ = state
        S, V = jax.vmap(upd, in_axes=(0, 0, 0, None, None, None, 0))(
            S, V, S_local, S_star, S_bar, mask, r)
        if quantized:
            S_q = jax.vmap(ref.row_normalize_quantized, in_axes=(0, None))(
                ref.quantize_s(S), mask)
            S = ref.dequantize_s(S_q)
        f = fitness(S)
        improved = f > f_local
        S_local = jnp.where(improved[:, None, None], S, S_local)
        f_local = jnp.maximum(f, f_local)
        b = jnp.argmax(f_local)
        better = f_local[b] > f_star
        S_star = jnp.where(better, S_local[b], S_star)
        f_star = jnp.where(better, f_local[b], f_star)
        return (S, V, S_local, f_local, S_star, f_star, f), f_star

    f_last0 = f_local.astype(jnp.float32)
    (S, V, S_local, f_local, S_star, f_star, f_last), f_trace = jax.lax.scan(
        inner, (S, V, S_local, f_local, S_star, f_star, f_last0), r_all)
    return S, S_star, f_star, f_trace, f_last


def _epoch_kernel(r_ref, s_ref, v_ref, sl_ref, fl_ref, star_ref, fstar_ref,
                  sbar_ref, mask_ref, q_ref, g_ref,
                  s_out_ref, star_out_ref, fstar_out_ref, trace_ref,
                  flast_out_ref, *,
                  inner_steps: int, omega: float, c1: float, c2: float,
                  c3: float, v_max: float, quantized: bool):
    r_all = r_ref[0]                               # (K, N, r_pad) f32
    mask_raw = mask_ref[0]                         # (n, m) as given
    maskf = mask_raw.astype(jnp.float32)
    maskq = mask_raw != 0
    s_bar = sbar_ref[0].astype(jnp.float32)        # (n, m)
    N = s_ref.shape[1]

    # per-row constants of the normalize fallback (ref.pso_update)
    mask_rows = jnp.sum(maskf, axis=-1, keepdims=True)          # (n, 1)
    uniform = maskf / jnp.maximum(mask_rows, 1.0)               # (n, m)
    # quantized-renormalize fallback (ref.row_normalize_quantized)
    mask_rows_q = jnp.sum(maskq.astype(jnp.int32), axis=-1, keepdims=True)
    uniform_q = jnp.where(
        maskq, jnp.clip(255 // jnp.maximum(mask_rows_q, 1), 1, 255), 0)

    if quantized:
        q_i = q_ref[0].astype(jnp.int32)
        g_i = g_ref[0].astype(jnp.int32)
    else:
        q_f = q_ref[0].astype(jnp.float32)
        g_f = g_ref[0].astype(jnp.float32)

    def fitness(S):
        """Per-particle -||Q - S G Sᵀ||², one (1, 2)-axis reduce."""
        if quantized:
            S_q = jnp.clip(jnp.round(S * 255.0), 0, 255).astype(jnp.int32)
            SG = jax.lax.dot_general(
                S_q, g_i, dimension_numbers=(((2,), (0,)), ((), ())))
            SGS = jax.lax.dot_general(
                SG, S_q, dimension_numbers=(((2,), (2,)), ((0,), (0,))))
            resid = (q_i * (255 * 255) - SGS).astype(jnp.float32)
            return -jnp.sum(resid * resid, axis=(1, 2)) / (255.0 ** 4)
        SG = jax.lax.dot_general(
            S, g_f, dimension_numbers=(((2,), (0,)), ((), ())))
        SGS = jax.lax.dot_general(
            SG, S, dimension_numbers=(((2,), (2,)), ((0,), (0,))))
        resid = q_f - SGS
        return -jnp.sum(resid * resid, axis=(1, 2))

    def step(i, state):
        S, V, S_local, f_local, S_star, f_star, _ = state
        r = jax.lax.dynamic_index_in_dim(r_all, i, 0, keepdims=False)
        r0 = r[:, 0][:, None, None]
        r1 = r[:, 1][:, None, None]
        r2 = r[:, 2][:, None, None]
        # ref.pso_update, batched over the resident particle dim
        V = (omega * V
             + c1 * r0 * (S_local - S)
             + c2 * r1 * (S_star[None] - S)
             + c3 * r2 * (s_bar[None] - S))
        V = jnp.clip(V, -v_max, v_max)
        S = jnp.clip(S + V, 0.0, None) * maskf[None]
        row_sum = jnp.sum(S, axis=-1, keepdims=True)
        S = jnp.where(row_sum > ref.EPS,
                      S / jnp.maximum(row_sum, ref.EPS), uniform[None])
        if quantized:
            # straight-through requantize: quantize_s →
            # row_normalize_quantized (Q1.15 reciprocal) → dequantize_s,
            # all integer ops in int32 holding uint8-range values
            S_q = jnp.clip(jnp.round(S * 255.0), 0, 255).astype(jnp.int32)
            row = jnp.sum(S_q, axis=-1, keepdims=True)
            recip_q15 = jnp.round((1 << 15) / jnp.maximum(row, 1)
                                  ).astype(jnp.int32)
            prod = S_q * recip_q15 * 255
            out = jnp.clip((prod + (1 << 14)) >> 15, 0, 255)
            S_q = jnp.where(row > 0, out * maskq[None], uniform_q[None])
            S = S_q.astype(jnp.float32) / 255
        f = fitness(S)
        improved = f > f_local
        S_local = jnp.where(improved[:, None, None], S, S_local)
        f_local = jnp.maximum(f, f_local)
        # global best: one-hot select of S_local[argmax] (exact — adding
        # 0.0 is exact and S has no -0.0); f_local[argmax] == max(f_local)
        b = jnp.argmax(f_local)
        f_best = jnp.max(f_local)
        sel = jax.lax.broadcasted_iota(jnp.int32, (N, 1, 1), 0) == b
        S_best = jnp.sum(jnp.where(sel, S_local, 0.0), axis=0)
        better = f_best > f_star
        S_star = jnp.where(better, S_best, S_star)
        f_star = jnp.where(better, f_best, f_star)
        trace_ref[0, i] = f_star
        return S, V, S_local, f_local, S_star, f_star, f

    # f_last carries the fitness of the CURRENT S (the value the epoch
    # epilogue consumes instead of recomputing); it initializes from the
    # f_local input, which is fitness(S) for the real caller.
    state0 = (s_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
              sl_ref[0].astype(jnp.float32), fl_ref[0].astype(jnp.float32),
              star_ref[0].astype(jnp.float32), fstar_ref[0, 0],
              fl_ref[0].astype(jnp.float32))
    S, V, S_local, f_local, S_star, f_star, f_last = jax.lax.fori_loop(
        0, inner_steps, step, state0)
    s_out_ref[0] = S
    star_out_ref[0] = S_star
    fstar_out_ref[0, 0] = f_star
    flast_out_ref[0] = f_last


@functools.partial(
    jax.jit,
    static_argnames=("omega", "c1", "c2", "c3", "v_max", "quantized",
                     "interpret"))
def epoch_fused_pallas(S, V, S_local, f_local, S_star, f_star, S_bar,
                       mask, Q, G, r_all, *, omega: float, c1: float,
                       c2: float, c3: float, v_max: float,
                       quantized: bool = False, interpret: bool = False):
    """Fused batched epoch loop. Particle state ``S/V/S_local``:
    (P, N, n, m); ``f_local``: (P, N); controller ``S_star``/``S_bar``
    and ``mask``: (P, n, m); ``f_star``: (P,); ``Q``: (P, n, n); ``G``:
    (P, m, m); ``r_all``: (P, K, N, r) pre-drawn step randoms (only
    ``r[..., :3]`` is consumed — the ops layer lane-pads the rest).
    Returns ``(S_final (P, N, n, m), S_star (P, n, m), f_star (P,),
    f_trace (P, K), f_last (P, N))`` — ``f_last`` is the fitness of
    ``S_final``, threaded out so the epoch epilogue never recomputes
    it; the single-problem case is just P = 1.
    """
    P, N, n, m = S.shape
    K, r_dim = r_all.shape[1], r_all.shape[3]
    kernel = functools.partial(
        _epoch_kernel, inner_steps=K, omega=omega, c1=c1, c2=c2, c3=c3,
        v_max=v_max, quantized=quantized)
    s_fin, star_fin, fstar_fin, trace, f_last = pl.pallas_call(
        kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, K, N, r_dim), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, N), lambda p: (p, 0)),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, 1), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n, n), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, m, m), lambda p: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, n, m), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, 1), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, K), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda p: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, N, n, m), jnp.float32),
            jax.ShapeDtypeStruct((P, n, m), jnp.float32),
            jax.ShapeDtypeStruct((P, 1), jnp.float32),
            jax.ShapeDtypeStruct((P, K), jnp.float32),
            jax.ShapeDtypeStruct((P, N), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(r_all.astype(jnp.float32), S, V, S_local,
      f_local.astype(jnp.float32), S_star,
      f_star.astype(jnp.float32).reshape(P, 1), S_bar, mask, Q, G)
    return s_fin, star_fin, fstar_fin[:, 0], trace, f_last
