"""Device-resident drain pipeline: the host-sync census (one blocking
fetch per all-warm drain), pipelined-vs-serial bitwise parity, carry
buffer donation, the device carry pool's row lifecycle, the pooled
popcount index bookkeeping, and device-side best-feasible selection."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graphs, pso
from repro.core.service import (CarryStore, DeviceCarryPool, MatcherService,
                                ServiceStats)
from repro.kernels import pallas_compat

jax.config.update("jax_platform_name", "cpu")

CFG = pso.PSOConfig(num_particles=24, epochs=3, inner_steps=8,
                    early_exit=True)

# two distinct shape buckets: (8, 16) and (8, 32)
BUCKET_ARGS = ((6, 12), (5, 24))


def _planted(seed, n, m, edge_prob=0.35):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, edge_prob)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def _burst(svc, specs):
    """Submit [(seed, n, m), ...] and drain; deterministic keys."""
    for seed, n, m in specs:
        q, g = _planted(seed, n, m)
        svc.submit(q, g, key=jax.random.PRNGKey(seed),
                   workload_key=(f"w{n}x{m}", seed))
    return svc.drain()


def _warm_specs(svc, per_bucket=2, max_seeds=12):
    """Problem specs across both buckets whose carries revalidate (the
    all-warm drain workload): cold-drains candidates, keeps the ones a
    repeat drain serves at Tier 0."""
    specs = []
    for n, m in BUCKET_ARGS:
        cands = [(s, n, m) for s in range(max_seeds)]
        _burst(svc, cands)
        warm = _burst(svc, cands)
        good = [c for c, r in zip(cands, warm) if r.tier == 0 and r.found]
        assert len(good) >= per_bucket, f"no warm problems for {(n, m)}"
        specs.extend(good[:per_bucket])
    return specs


# ---------------------------------------------------------------------------
# host-sync census / transfer guard
# ---------------------------------------------------------------------------

def test_warm_drain_costs_one_host_sync():
    """An all-warm multi-bucket pipelined drain resolves through exactly
    ONE blocking device→host fetch — asserted by the census counter, and
    additionally run under JAX's implicit-transfer guard (which traps
    stray ``np.asarray`` round trips on accelerator backends; CPU arrays
    are host-resident, so the counter is the hard assertion)."""
    svc = MatcherService(CFG)
    specs = _warm_specs(svc)
    # problem construction (host-side RNG sampling) happens before the
    # guard: only the submit+drain round must be implicit-transfer-free
    probs = [(_planted(seed, n, m), seed, n, m) for seed, n, m in specs]
    syncs0, drains0 = svc.stats.host_syncs, svc.stats.drains
    with jax.transfer_guard_device_to_host("disallow"):
        for (q, g), seed, n, m in probs:
            svc.submit(q, g, key=jax.random.PRNGKey(seed),
                       workload_key=(f"w{n}x{m}", seed))
        results = svc.drain()
    assert svc.stats.drains - drains0 == 1
    assert svc.stats.host_syncs - syncs0 == 1
    assert all(r.tier == 0 and r.found for r in results)
    assert svc.stats.host_bytes_transferred > 0
    assert svc.stats.host_sync_wall_s >= 0.0


def test_serial_arm_pays_a_sync_per_launch_and_per_carry():
    """``pipelined=False`` restores the legacy drain discipline: one
    blocking fetch per Tier-0 launch PLUS host numpy staging of every
    stored carry — three ``np.asarray`` transfers per warm item (S*, f*,
    S̄ are all device-pool residents). Two buckets → two launches → two
    explicit fetches, and 3 implicit syncs per warm item on top."""
    svc = MatcherService(CFG, pipelined=False)
    specs = _warm_specs(svc)
    syncs0 = svc.stats.host_syncs
    t0_launches0 = svc.stats.tier0.launches
    results = _burst(svc, specs)
    assert all(r.tier == 0 for r in results)
    launches = svc.stats.tier0.launches - t0_launches0
    assert launches == 2
    assert svc.stats.host_syncs - syncs0 == launches + 3 * len(specs)


def test_stats_dict_exports_census():
    svc = MatcherService(CFG)
    _burst(svc, [(0, 6, 12)])
    d = svc.stats_dict()
    for k in ("drains", "host_syncs", "host_syncs_per_drain",
              "host_bytes_transferred", "host_sync_wall_s",
              "donated_launches", "pool_puts", "pool_gathers",
              "pool_live_rows"):
        assert k in d, k
    assert d["drains"] == 1
    assert d["host_syncs"] >= 1


# ---------------------------------------------------------------------------
# pipelined vs serial parity
# ---------------------------------------------------------------------------

def _result_fingerprint(r):
    return (None if r.mapping is None else np.asarray(r.mapping).tobytes(),
            r.found, r.tier, r.f_star, r.epochs_run)


def test_pipelined_matches_serial_bitwise():
    """Async dispatch must not change a single bit of any result: a
    mixed easy/hard two-bucket burst produces identical mappings, tiers,
    f* and epoch counts through both drain arms, cold AND warm."""
    specs = [(s, n, m) for n, m in BUCKET_ARGS for s in range(5)]
    pipe = MatcherService(CFG)
    ser = MatcherService(CFG, pipelined=False)
    for _round in range(3):
        rp = _burst(pipe, specs)
        rs = _burst(ser, specs)
        for a, b in zip(rp, rs):
            assert _result_fingerprint(a) == _result_fingerprint(b)


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------

def test_donation_does_not_change_results():
    """donate_buffers only changes buffer lifetime, never values; the
    donated arm actually donates when the toolchain supports it and the
    opted-out arm never counts a donated launch."""
    specs = [(s, 6, 12) for s in range(5)]
    on = MatcherService(CFG, donate_buffers=True)
    off = MatcherService(CFG, donate_buffers=False)
    for _round in range(2):
        ra = _burst(on, specs)
        rb = _burst(off, specs)
        for a, b in zip(ra, rb):
            assert _result_fingerprint(a) == _result_fingerprint(b)
    assert off.stats.donated_launches == 0
    if pallas_compat.donation_supported():
        assert on.stats.donated_launches > 0


def test_donation_probe_is_cached_bool():
    assert isinstance(pallas_compat.donation_supported(), bool)
    assert isinstance(pallas_compat.export_preserves_donation(), bool)
    assert pallas_compat.donation_supported() \
        == pallas_compat.donation_supported()


# ---------------------------------------------------------------------------
# DeviceCarryPool lifecycle
# ---------------------------------------------------------------------------

def _carry(n=4, m=8, fill=1.0, f=2.5):
    S = np.full((n, m), fill, np.float32)
    return (S, np.float32(f), S * 0.5)


def test_pool_put_gather_roundtrip():
    pool = DeviceCarryPool(block=4)
    carries = [_carry(fill=float(i), f=float(i)) for i in range(3)]
    handles = [pool.put(c) for c in carries]
    S, f, C = pool.gather(handles)
    assert S.shape == (3, 4, 8)
    np.testing.assert_array_equal(np.asarray(f),
                                  np.asarray([0.0, 1.0, 2.0], np.float32))
    for i, h in enumerate(handles):
        s_i, f_i, c_i = h.materialize()
        np.testing.assert_array_equal(np.asarray(s_i), carries[i][0])
        np.testing.assert_array_equal(np.asarray(c_i), carries[i][2])
    assert pool.gathers == 1
    assert pool.puts == 3


def test_pool_rows_recycle_on_release():
    pool = DeviceCarryPool(block=2)
    h1, h2 = pool.put(_carry(fill=1.0)), pool.put(_carry(fill=2.0))
    cap0 = pool._slabs[(4, 8)]["cap"]
    row1 = h1.row
    h1.retain()
    h1.release()                       # last ref -> row back to free list
    assert pool.live_rows == 1
    h3 = pool.put(_carry(fill=3.0))    # reuses the freed row, no growth
    assert h3.row == row1
    assert pool._slabs[(4, 8)]["cap"] == cap0
    assert pool.live_rows == 2
    np.testing.assert_array_equal(np.asarray(h3.materialize()[0]),
                                  np.full((4, 8), 3.0, np.float32))
    np.testing.assert_array_equal(np.asarray(h2.materialize()[0]),
                                  np.full((4, 8), 2.0, np.float32))


def test_pool_slab_grows_geometrically():
    pool = DeviceCarryPool(block=2)
    handles = [pool.put(_carry(fill=float(i))) for i in range(5)]
    assert pool._slabs[(4, 8)]["cap"] >= 5
    for i, h in enumerate(handles):
        assert float(np.asarray(h.materialize()[0])[0, 0]) == float(i)


def test_store_eviction_frees_pool_rows():
    """Warm-store evictions release their handles, so the pool's live
    rows stay bounded by the store capacities however many problems
    flow through the service."""
    svc = MatcherService(CFG, warm_capacity=3, sim_capacity=2)
    specs = [(s, 6, 12) for s in range(8)]
    _burst(svc, specs)
    _burst(svc, specs)
    # 3 exact + 2 sim + 1 pinned pad handle upper-bounds the live rows
    assert svc._pool.live_rows <= 3 + 2 + len(svc._pad_handles)
    assert len(svc._carries) <= 3


# ---------------------------------------------------------------------------
# CarryStore: popcount-at-ingest + handle refcounts
# ---------------------------------------------------------------------------

class _FakeHandle:
    def __init__(self):
        self.refs = 0

    def retain(self):
        self.refs += 1

    def release(self):
        self.refs -= 1


def test_store_retains_and_releases_handles():
    cs = CarryStore(capacity=2, sim_capacity=2, stats=ServiceStats())
    h1, h2, h3 = _FakeHandle(), _FakeHandle(), _FakeHandle()
    cs.put("a", h1)
    cs.put("b", h2)
    assert (h1.refs, h2.refs) == (1, 1)
    cs.put("a", h3)                    # overwrite releases the old value
    assert (h1.refs, h3.refs) == (0, 1)
    # put does not refresh recency (only get does), so "a" is still the
    # LRU entry and its new handle is released on eviction
    cs.put("c", _FakeHandle())
    assert h3.refs == 0
    cs.clear()
    assert h2.refs == 0


def test_sim_popcount_computed_once_at_ingest():
    cs = CarryStore(capacity=4, sim_capacity=2, stats=ServiceStats())
    sigs = [bytes([0b1010]), bytes([0b1110]), bytes([0b0001])]
    for i, sig in enumerate(sigs):
        cs.put_similar("qd", (8, 16), sig, i)
    # capacity 2: first entry evicted, index/popcount cache follow along
    assert cs.sim_entries == 2
    assert set(cs._sim_pop) == set(cs._sim)
    for key, pc in cs._sim_pop.items():
        assert pc == int(cs._sim[key][0].sum())
    nb = cs.nearest("qd", (8, 16), bytes([0b0110]))
    assert nb is not None and nb[1] == 1  # overlaps the 0b1110 entry


# ---------------------------------------------------------------------------
# device-side best_feasible
# ---------------------------------------------------------------------------

def _outs(feasible, fitness, maps):
    return {"feasible": jnp.asarray(feasible),
            "fitness": jnp.asarray(fitness, jnp.float32),
            "mappings": jnp.asarray(maps, jnp.uint8)}


def test_best_feasible_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    for _ in range(10):
        P = 6
        feas = rng.random(P) < 0.5
        fit = rng.standard_normal(P).astype(np.float32)
        maps = rng.integers(0, 2, (P, 3, 5)).astype(np.uint8)
        got = pso.best_feasible(_outs(feas, fit, maps))
        if not feas.any():
            assert got is None
            continue
        idx = np.where(feas)[0]
        want = maps[idx[np.argmax(fit[idx])]]
        np.testing.assert_array_equal(np.asarray(got), want)


def test_best_feasible_neginf_feasible_still_wins():
    """A feasible particle at f=-inf must beat infeasible slots (the
    masked score floor cannot shadow real entries)."""
    maps = np.stack([np.eye(3, 5, dtype=np.uint8) * i for i in range(3)])
    got = pso.best_feasible(_outs(
        [False, True, False], [1.0, -np.inf, 2.0], maps))
    np.testing.assert_array_equal(np.asarray(got), maps[1])


def test_best_feasible_none_when_infeasible():
    maps = np.zeros((2, 3, 5), np.uint8)
    assert pso.best_feasible(_outs([False, False], [0.0, 1.0], maps)) is None


# ---------------------------------------------------------------------------
# snapshot round trip keeps the single-sync warm drain
# ---------------------------------------------------------------------------

def test_restored_snapshot_warm_drain_single_sync(tmp_path):
    svc = MatcherService(CFG, persist_dir=str(tmp_path))
    specs = _warm_specs(svc, per_bucket=2)
    _burst(svc, specs)
    svc.save_snapshot()

    svc2 = MatcherService(CFG, persist_dir=str(tmp_path))
    assert svc2.restore_snapshot() is not None
    syncs0 = svc2.stats.host_syncs
    results = _burst(svc2, specs)
    assert all(r.tier == 0 and r.found for r in results)
    assert svc2.stats.host_syncs - syncs0 == 1
