"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder; the speech
frontend is a STUB — input_specs provides precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    encoder_layers=12, d_model=1024, num_heads=16, kv_heads=16, d_ff=4096,
    vocab_size=256206, frontend="audio", rope_theta=10000.0)
