"""Streaming-scale benchmark: ~10^6 arrivals through the event loop.

Four experiments, one per acceptance claim of the streaming simulator +
async service front end:

  1. **Headline stream** — a generator-backed ``make_streaming_scenario``
     replaying ~1e6 Poisson arrivals (smoke: ~2e4) through the
     heap-scheduled event loop at a sustainable rate. Reports
     p50/p99/p999 scheduling + total latency, simulated and wall-clock
     throughput, and the bounded-memory evidence: ``peak_live_tasks``
     (tasks held simultaneously) and process peak RSS — neither scales
     with the arrival count. A ``truncated`` result aborts the benchmark
     with a non-zero exit: truncated numbers are a prefix, not a run.
  2. **Throughput vs load** — small fixed-arrival-count arms at load
     multipliers spanning the saturation knee; per arm: offered vs
     finished rate, urgent hit rate, latency percentiles, peak backlog.
  3. **Async front end** — a real ``MatcherService`` behind
     ``AsyncServiceFrontEnd``: a deadline-striped request stream drives
     batch-full / deadline-slack / flush drain triggers and shed-policy
     admission control; reports the ``fe_*`` counter block.
  4. **Loop equivalence** — the streaming heap loop vs the legacy
     full-scan loop on materialized scenarios, compared field-for-field
     (bitwise; no tolerance) — the oracle check that the rebuild changed
     complexity, not results.

Emits ``BENCH_scale.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_scale
           [--arrivals N] [--rate-hz R] [--smoke] [--out FILE]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import resource
import sys
import time

import jax

from repro.accel import EDGE
from repro.core import graphs, pso
from repro.core.service import AsyncServiceFrontEnd, MatcherService
from repro.sched import (SimConfig, Simulator, build_scenario,
                         get_scheduler, make_burst_scenario,
                         make_scenario, make_streaming_scenario)
from repro.sched.metrics import frontend_stats


def _maxrss_mb() -> float:
    """Process peak RSS in MiB (ru_maxrss is KiB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _require_complete(r, label: str) -> None:
    if r.truncated:
        print(f"FATAL: {label} truncated at {r.events} events "
              f"(max_events too small) — refusing to report a prefix "
              f"as a result", file=sys.stderr)
        sys.exit(1)


def _run_stream(rate_hz: float, horizon: float, *, scheduler: str,
                seed: int, validate: bool = False):
    sc = make_streaming_scenario("simple", rate_hz=rate_hz,
                                 horizon=horizon, seed=seed)
    cfg = SimConfig(platform=EDGE, matcher_mode="analytic",
                    max_events=None, validate=validate)
    t0 = time.perf_counter()
    r = Simulator(cfg, get_scheduler(scheduler)).run(sc)
    wall = time.perf_counter() - t0
    return r, wall


def bench_headline(rate_hz: float, arrivals: int, scheduler: str,
                   seed: int = 11):
    horizon = arrivals / rate_hz
    r, wall = _run_stream(rate_hz, horizon, scheduler=scheduler, seed=seed)
    _require_complete(r, "headline stream")
    return {
        "scheduler": scheduler,
        "rate_hz": rate_hz,
        "horizon_s": horizon,
        "arrivals": r.total,
        "finished": r.finished,
        "events": r.events,
        "truncated": r.truncated,
        "urgent_hit_rate": r.urgent_hit_rate,
        "all_hit_rate": r.all_hit_rate,
        "avg_total_latency_s": r.avg_total_latency,
        "avg_sched_time_s": r.avg_sched_time,
        "percentiles": r.percentiles,
        "alloc_conflicts": r.alloc_conflicts,
        "peak_live_tasks": r.peak_live_tasks,
        "peak_rss_mb": _maxrss_mb(),
        "wall_s": wall,
        "wall_events_per_s": r.events / max(wall, 1e-9),
        "wall_arrivals_per_s": r.total / max(wall, 1e-9),
        "sim_throughput_tasks_per_s": r.finished / max(r.sim_horizon, 1e-9),
        "pass": (not r.truncated and r.finished == r.total
                 and r.alloc_conflicts == 0),
    }


def bench_load_sweep(base_rate_hz: float, arrivals_per_arm: int,
                     multipliers, scheduler: str, seed: int = 23):
    arms = []
    for mult in multipliers:
        rate = base_rate_hz * mult
        horizon = arrivals_per_arm / rate
        r, wall = _run_stream(rate, horizon, scheduler=scheduler,
                              seed=seed)
        _require_complete(r, f"load sweep x{mult}")
        arms.append({
            "load_multiplier": mult,
            "offered_rate_hz": rate,
            "arrivals": r.total,
            "finished": r.finished,
            "finished_frac": r.finished / max(r.total, 1),
            "urgent_hit_rate": r.urgent_hit_rate,
            "all_hit_rate": r.all_hit_rate,
            "sim_throughput_tasks_per_s":
                r.finished / max(r.sim_horizon, 1e-9),
            "latency_p50_s": r.percentiles.get("latency_p50", 0.0),
            "latency_p999_s": r.percentiles.get("latency_p999", 0.0),
            "sched_p999_s": r.percentiles.get("sched_p999", 0.0),
            "peak_live_tasks": r.peak_live_tasks,
            "wall_s": wall,
        })
    # the curve must actually cross the knee: the heaviest arm should
    # show a worse deadline hit-rate than the lightest
    ok = arms[-1]["all_hit_rate"] <= arms[0]["all_hit_rate"]
    return {"base_rate_hz": base_rate_hz,
            "arrivals_per_arm": arrivals_per_arm,
            "scheduler": scheduler, "arms": arms, "pass": ok}


def _planted(seed: int, n: int = 8, m: int = 16):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def bench_frontend(cfg: pso.PSOConfig, requests: int):
    svc = MatcherService(cfg, batch_classes=(1, 2, 4))
    fe = AsyncServiceFrontEnd(svc, max_depth=8, policy="shed",
                              slack_threshold_s=0.05)
    probs = [_planted(i % 6) for i in range(requests)]
    # warm the batch path so the timed loop measures steady state
    fe.submit(*probs[0], deadline=0.0, now=0.0)
    fe.flush(now=0.0)

    t0 = time.perf_counter()
    rids = []
    now = 0.0
    for i, (q, g) in enumerate(probs):
        now = i * 0.01
        # stripe deadlines: every 5th request is tight (drives the
        # deadline trigger); the loose runs between them are long enough
        # to fill the largest batch class (drives the batch trigger)
        dl = now + (0.02 if i % 5 == 0 else 10.0)
        rids.append(fe.submit(q, g, deadline=dl, now=now))
        fe.poll(now=now + 0.005)
    fe.flush(now=now + 1.0)
    wall = time.perf_counter() - t0
    served = sum(1 for rid in rids if fe.take_result(rid) is not None)
    fes = frontend_stats(
        type("R", (), {"matcher_stats": svc.stats_dict()})())
    return {
        "requests": requests,
        "served": served,
        "wall_s": wall,
        "stats": fes,
        "pass": (fes["fe_submitted"] == requests + 1
                 and fes["fe_admitted"] + fes["fe_shed"]
                 == fes["fe_submitted"]
                 and fes["fe_drain_deadline"] > 0
                 and fes["fe_drain_batch_full"] > 0
                 and fes["fe_drains"] > 0),
    }


def bench_equivalence(scheduler_names=("immsched", "prema")):
    scens = [make_scenario("simple", rate_hz=40, horizon=1.0, seed=5),
             make_burst_scenario("simple", rate_hz=20, horizon=1.0,
                                 seed=6)]
    checks = []
    for name in scheduler_names:
        for sc in scens:
            cfg = SimConfig(platform=EDGE, matcher_mode="analytic")
            a = Simulator(cfg, get_scheduler(name)).run(sc)
            b = Simulator(cfg, get_scheduler(name)).run_legacy(sc)
            da, db = dataclasses.asdict(a), dataclasses.asdict(b)
            diff = sorted(k for k in da if da[k] != db[k])
            checks.append({"scheduler": name, "scenario": sc.name,
                           "tasks": len(sc.tasks), "equal": not diff,
                           "diff_fields": diff})
    return {"checks": checks,
            "bitwise_legacy_equal": all(c["equal"] for c in checks)}


def bench_registry_equivalence():
    """Preset ≡ explicit registry spec: the scenarios every arm above
    runs are built through ``build_scenario``, and an explicit spec with
    the same knobs reproduces the preset's tasks byte-for-byte."""
    preset = make_scenario("simple", rate_hz=40, horizon=1.0, seed=5)
    explicit = build_scenario({
        "name": "simple-poisson", "seed": 5, "horizon": 1.0,
        "streams": [{
            "arrival": {"kind": "poisson", "rate_hz": 40},
            "workload": {"kind": "uniform", "complexity": "simple"},
            "urgency": {"kind": "bernoulli", "urgent_frac": 0.4},
            "deadline": {"kind": "slack", "deadline_slack": 2.0,
                         "urgent_slack": 1.25,
                         "base_exec_estimate": 5e-3},
        }],
    })
    def rec(t):
        return (t.task_id, t.name, t.workload.name, t.arrival.hex(),
                t.deadline.hex(), t.priority, t.urgent)

    equal = (preset.name == explicit.name
             and len(preset.tasks) == len(explicit.tasks)
             and all(rec(a) == rec(b)
                     for a, b in zip(preset.tasks, explicit.tasks)))
    return {"preset_tasks": len(preset.tasks),
            "preset_spec_equal": equal}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arrivals", type=int, default=1_000_000,
                    help="headline stream length (expected arrivals)")
    ap.add_argument("--rate-hz", type=float, default=5000.0,
                    help="headline arrival rate (sustainable on EDGE)")
    ap.add_argument("--scheduler", default="immsched")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: ~2e4 arrivals, short sweep")
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args()

    if args.smoke:
        arrivals, arrivals_per_arm = 20_000, 600
        multipliers = (0.5, 1.0, 2.5)
        fe_cfg = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)
        fe_requests = 12
    else:
        arrivals, arrivals_per_arm = args.arrivals, 3_000
        multipliers = (0.25, 0.5, 1.0, 1.6, 2.0, 2.4)
        fe_cfg = pso.PSOConfig(num_particles=16, epochs=2, inner_steps=8)
        fe_requests = 48

    headline = bench_headline(args.rate_hz, arrivals, args.scheduler)
    sweep = bench_load_sweep(args.rate_hz * 0.8, arrivals_per_arm,
                             multipliers, args.scheduler)
    frontend = bench_frontend(fe_cfg, fe_requests)
    equiv = bench_equivalence()
    registry = bench_registry_equivalence()

    result = {
        "smoke": bool(args.smoke),
        "platform": EDGE.name,
        "headline": headline,
        "load_sweep": sweep,
        "frontend": frontend,
        "equivalence": equiv,
        "registry": registry,
        "pass": (headline["pass"] and sweep["pass"] and frontend["pass"]
                 and equiv["bitwise_legacy_equal"]
                 and registry["preset_spec_equal"]),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    p = headline["percentiles"]
    print("name,value,derived")
    print(f"scale_arrivals,{headline['arrivals']},"
          f"peak_live={headline['peak_live_tasks']}"
          f"_rss_mb={headline['peak_rss_mb']:.0f}")
    print(f"scale_wall_arrivals_per_s,"
          f"{headline['wall_arrivals_per_s']:.0f},"
          f"events_per_s={headline['wall_events_per_s']:.0f}")
    print(f"scale_sched_p50_us,{p.get('sched_p50', 0.0) * 1e6:.1f},"
          f"p99={p.get('sched_p99', 0.0) * 1e6:.1f}"
          f"_p999={p.get('sched_p999', 0.0) * 1e6:.1f}")
    print(f"scale_latency_p999_ms,"
          f"{p.get('latency_p999', 0.0) * 1e3:.3f},"
          f"urgent_hit={headline['urgent_hit_rate']:.4f}")
    for arm in sweep["arms"]:
        print(f"scale_load_x{arm['load_multiplier']},"
              f"{arm['sim_throughput_tasks_per_s']:.0f},"
              f"hit={arm['all_hit_rate']:.3f}"
              f"_p999_ms={arm['latency_p999_s'] * 1e3:.2f}")
    fes = frontend["stats"]
    print(f"scale_frontend_drains,{fes['fe_drains']},"
          f"deadline={fes['fe_drain_deadline']}"
          f"_batch={fes['fe_drain_batch_full']}"
          f"_flush={fes['fe_drain_flush']}_shed={fes['fe_shed']}")
    print(f"scale_registry_preset_equal,"
          f"{int(registry['preset_spec_equal'])},"
          f"tasks={registry['preset_tasks']}")
    ok = result["pass"]
    print(f"scale_acceptance,0,{'PASS' if ok else 'FAIL'}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
