"""Target graph G: the accelerator's preemptible engine array as a DAG.

Engines are vertices; NoC mesh links (east/south forwarding, matching the
tile-cascaded TSS dataflow) are edges. A boolean ``free`` mask restricts G
to preemptible/idle engines — this is also the fault-tolerance hook: drop
failed engines from the mask and re-match (see DESIGN.md §8).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.accel.platform import Platform
from repro.core import graphs


def target_graph(platform: Platform,
                 bidirectional: bool = True) -> graphs.Graph:
    g = graphs.grid_graph(platform.noc_rows, platform.noc_cols,
                          type_id=graphs.TYPE_MAC,
                          bidirectional=bidirectional)
    # engines are general-purpose after the paper's PE modifications:
    # MAC + elementwise + comparator-tree → TYPE_ANY compatibility target.
    types = np.full((g.n,), graphs.TYPE_MAC, dtype=np.int32)
    weights = np.full((g.n,), platform.macs_per_engine, dtype=np.float32)
    return graphs.Graph(adj=g.adj, types=types, weights=weights)


def free_engine_graph(platform: Platform, free: Sequence[bool],
                      bidirectional: bool = True) -> graphs.Graph:
    """Subgraph of the engine array restricted to free engines, preserving
    original engine indices via ``weights`` (weights[i] = engine id).

    Vertices keep ascending engine-id order, so two calls with the same
    free set produce byte-identical graphs — the stability the online
    matcher service's shape-bucketed compile cache and content-hashed
    warm-start keys rely on.
    """
    full = target_graph(platform, bidirectional)
    free = np.asarray(free, dtype=bool)
    assert free.shape == (full.n,)
    idx = np.where(free)[0]
    adj = full.adj[np.ix_(idx, idx)]
    types = full.types[idx]
    return graphs.Graph(adj=adj, types=types,
                        weights=idx.astype(np.float32))


def free_engine_signature(free: Sequence[bool]) -> bytes:
    """Compact, stable platform-state key: the free-engine bitmask.

    Used (together with the workload name) to scope the matcher service's
    warm-start entries to a (workload, platform-state) class.
    """
    return np.packbits(np.asarray(free, dtype=bool)).tobytes()


def signature_bits(sig: bytes) -> np.ndarray:
    """Unpacked bit vector of a ``free_engine_signature``.

    The single decode point for every consumer that compares platform
    states by engine-set overlap (the service's similarity-keyed carry
    store and the scheduler's analytic tier predictor must agree on the
    packing), so a change to the signature encoding lands in one place.
    """
    return np.unpackbits(np.frombuffer(sig, dtype=np.uint8))
