"""Analytic roofline for the matcher kernels (EXPERIMENTS.md §Roofline).

Earlier revisions of this file carried a layer-stack methodology for LM
architectures (probe-corrected while-loop FLOP counts etc.) that had
nothing to do with this repo's workload. That is gone. The roofline now
targets the kernels this repo actually ships — the ``KernelBackend``
entry points of the PSO/Ullmann matcher — with *analytic* FLOP and HBM
byte counts derived from the algorithm (Alg. 1 / §3.4 of the paper), not
from HLO cost analysis.

Model, per swarm epoch of ``K`` inner steps over ``N`` particles on an
``n×m`` assignment problem:

* **MXU work** is the edge-consistency fitness: two batched contractions
  per particle per step, ``S·G`` (2·n·m² FLOPs) and ``(SG)·Sᵀ``
  (2·n²·m FLOPs), plus an O(n²) residual reduction. The PSO
  velocity/position update and the §3.4 requantize are elementwise VPU
  work, O(n·m) per particle per step.
* **HBM traffic** is where the fused epoch kernel wins: the loose
  ``lax.scan`` path round-trips the particle state
  (``S``, ``V``, ``S_local`` — 3 · N·n·m f32 arrays) through HBM on
  every one of the K steps, while the fused kernel
  (``kernels/epoch_fused.py``) reads the state once, keeps it resident
  in VMEM for the whole epoch, and writes back only
  ``(S_final, S_star, f_star, f_trace)``.

Peak numbers are TPU v5e per-core datasheet values. The f32 peak is
taken as half the bf16 MXU rate; the quantized path issues int32 MACs
which we bound by the int8 peak (an upper bound — int32 lowering is
slower), so quantized utilization figures are conservative lower bounds
on distance-from-roof. When run on CPU the "achieved" column is still
measured honestly, but the utilization column is reported against the
v5e roof and labelled as such — it answers "how far from a v5e roof is
this wall-clock", not "how efficient is this CPU".

Usage:
    PYTHONPATH=src python -m benchmarks.roofline
        [--particles N] [--n N] [--m M] [--steps K] [--repeats R]
        [--backend ref|pallas|interpret] [--no-measure] [--smoke]
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Dict, Optional

# TPU v5e, per core.
PEAK_BF16_FLOPS = 197e12
PEAK_F32_FLOPS = PEAK_BF16_FLOPS / 2
PEAK_INT8_OPS = 394e12
HBM_BW = 819e9
VMEM_BYTES = 128 * 2**20


def fitness_flops(n: int, m: int) -> float:
    """MXU FLOPs of one edge-consistency fitness eval for one particle.

    ``SG = S·G`` is an (n,m)×(m,m) contraction; ``SGS = SG·Sᵀ`` is an
    (n,m)×(n,m) contraction over m; the Q-residual square/sum adds
    ~3·n² VPU FLOPs which we fold in here (it is <1% of the matmuls).
    """
    return 2.0 * n * m * m + 2.0 * n * n * m + 3.0 * n * n


def pso_update_flops(n: int, m: int) -> float:
    """VPU FLOPs of one PSO velocity/position update for one particle.

    Three fused multiply-adds per velocity term, clip, position add,
    mask multiply, and the row-sum normalize: ~16 ops per S element.
    """
    return 16.0 * n * m


def requantize_flops(n: int, m: int) -> float:
    """VPU int ops of one §3.4 requantize round trip for one particle."""
    return 10.0 * n * m


def epoch_flops(num_particles: int, n: int, m: int, inner_steps: int,
                quantized: bool) -> Dict[str, float]:
    """Analytic FLOPs of one full swarm epoch (K steps, N particles)."""
    per_particle_step = fitness_flops(n, m) + pso_update_flops(n, m)
    if quantized:
        per_particle_step += requantize_flops(n, m)
    mxu = inner_steps * num_particles * fitness_flops(n, m)
    total = inner_steps * num_particles * per_particle_step
    return {"mxu_flops": mxu, "total_flops": total}


def epoch_hbm_bytes(num_particles: int, n: int, m: int,
                    inner_steps: int) -> Dict[str, float]:
    """HBM bytes per epoch: fused (state resident) vs loose (scan).

    f32 throughout; the graph operands (Q, G, mask, S_star, S_bar) and
    the pre-drawn randoms are counted once for both paths — the scan
    keeps them live too. The loose path re-reads and re-writes the
    3-array particle state plus f_local every step.
    """
    state = 3 * 4 * num_particles * n * m + 4 * num_particles
    consts = 4 * (3 * n * m + n * n + m * m) \
        + 4 * inner_steps * num_particles * 3
    out = 4 * num_particles * n * m + 4 * n * m + 4 * (inner_steps + 1)
    fused = state + consts + out
    loose = inner_steps * 2 * state + consts + out
    return {"fused_bytes": float(fused), "loose_bytes": float(loose)}


def tail_hbm_bytes(num_particles: int, n: int, m: int,
                   refine_iters: int,
                   gumbel: bool = False) -> Dict[str, float]:
    """HBM bytes of one epoch *epilogue*: fused tail vs the split tail.

    The fused tail (``kernels/finish_fused.py``) reads the final swarm
    once — S (f32), the threaded last-step fitness, the optional Gumbel
    field, and the uint8 graph operands — and writes only the decisions
    (M_hat, feasible, S_bar). The split tail is the pre-fusion dispatch
    sequence (two structured projections, a greedy projection,
    ``refine_iters`` Ullmann sweeps, two feasibility checks, a full
    fitness recompute, and the top_k consensus), each launch
    round-tripping its (N, n, m)-sized operands and intermediates
    through HBM.
    """
    N = num_particles
    s_f32 = 4 * N * n * m            # the swarm, f32
    cand = N * n * m                 # uint8 candidate / mapping planes
    graphs_u8 = n * m + n * n + m * m
    out = cand + 4 * N + 4 * n * m   # M_hat + feasible + S_bar
    fused = s_f32 + 4 * N + graphs_u8 + out \
        + (s_f32 if gumbel else 0)
    split = (
        (s_f32 if not gumbel else 2 * s_f32) + graphs_u8 + cand  # proj a
        + cand + graphs_u8 + 4 * N                 # feasibility a
        + s_f32 + n * m + cand                     # greedy projection
        + refine_iters * (2 * cand + graphs_u8)    # Ullmann sweeps
        + s_f32 + 2 * cand + graphs_u8             # re-projection b
        + cand + graphs_u8 + 4 * N                 # feasibility b
        + s_f32 + graphs_u8 + 4 * N                # fitness RECOMPUTE
        + s_f32 + 4 * N + 4 * n * m)               # top_k consensus
    return {"fused_bytes": float(fused), "split_bytes": float(split)}


def epoch_e2e_hbm_bytes(num_particles: int, n: int, m: int,
                        inner_steps: int, refine_iters: int,
                        gumbel: bool = False) -> Dict[str, float]:
    """End-to-end HBM bytes of one epoch (inner loop + epilogue), for
    the two-launch fused pipeline vs the fully split pre-fusion one."""
    loop = epoch_hbm_bytes(num_particles, n, m, inner_steps)
    tail = tail_hbm_bytes(num_particles, n, m, refine_iters,
                          gumbel=gumbel)
    return {
        "fused_bytes": loop["fused_bytes"] + tail["fused_bytes"],
        "split_bytes": loop["loose_bytes"] + tail["split_bytes"],
    }


def epoch_roofline(num_particles: int, n: int, m: int, inner_steps: int,
                   quantized: bool,
                   measured_s: Optional[float] = None) -> dict:
    """Roofline summary for one epoch; attach achieved rates if timed.

    ``mxu_utilization`` is achieved MXU FLOP/s over the v5e peak for the
    fitness dtype (f32 peak for the float path, int8 peak for the
    quantized path — see module docstring for why that is a bound).
    """
    fl = epoch_flops(num_particles, n, m, inner_steps, quantized)
    by = epoch_hbm_bytes(num_particles, n, m, inner_steps)
    peak = PEAK_INT8_OPS if quantized else PEAK_F32_FLOPS
    t_compute = fl["total_flops"] / peak
    t_mem_fused = by["fused_bytes"] / HBM_BW
    t_mem_loose = by["loose_bytes"] / HBM_BW
    row = {
        "num_particles": num_particles, "shape": [n, m],
        "inner_steps": inner_steps, "quantized": quantized,
        "mxu_flops_per_epoch": fl["mxu_flops"],
        "total_flops_per_epoch": fl["total_flops"],
        "hbm_bytes_fused": by["fused_bytes"],
        "hbm_bytes_loose": by["loose_bytes"],
        "hbm_bytes_saved_ratio": by["loose_bytes"] / max(
            by["fused_bytes"], 1.0),
        "arithmetic_intensity_fused": fl["total_flops"] / max(
            by["fused_bytes"], 1.0),
        "arithmetic_intensity_loose": fl["total_flops"] / max(
            by["loose_bytes"], 1.0),
        "v5e_bound_fused": ("compute" if t_compute >= t_mem_fused
                            else "memory"),
        "v5e_bound_loose": ("compute" if t_compute >= t_mem_loose
                            else "memory"),
        "v5e_peak_flops": peak,
    }
    if measured_s is not None:
        achieved = fl["total_flops"] / max(measured_s, 1e-12)
        row.update({
            "measured_s": measured_s,
            "achieved_flops": achieved,
            "mxu_utilization_vs_v5e": achieved / peak,
            "achieved_hbm_gbps_fused": by["fused_bytes"] / max(
                measured_s, 1e-12) / 1e9,
        })
    return row


def vmem_state_bytes(num_particles: int, n: int, m: int,
                     inner_steps: int) -> float:
    """Resident VMEM footprint of the fused epoch kernel (one problem)."""
    return (3 * 4 * num_particles * n * m          # S, V, S_local
            + 4 * num_particles                    # f_local
            + 4 * (3 * n * m + n * n + m * m)      # S_star/S_bar/mask/Q/G
            + 4 * inner_steps * num_particles * 3)  # r_all


def _measure_epoch(backend: str, num_particles: int, n: int, m: int,
                   inner_steps: int, quantized: bool,
                   repeats: int) -> float:
    """Median wall seconds of one fused epoch through the backend seam."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import get_backend

    bk = get_backend(backend)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 7)
    Q = jnp.triu(jax.random.bernoulli(
        ks[0], 0.3, (n, n)).astype(jnp.uint8), 1)
    G = jnp.triu(jax.random.bernoulli(
        ks[1], 0.4, (m, m)).astype(jnp.uint8), 1)
    mask = jax.random.bernoulli(ks[2], 0.8, (n, m)).astype(jnp.uint8)
    u = jax.random.uniform(ks[3], (num_particles, n, m)) * mask[None]
    S = u / jnp.maximum(u.sum(-1, keepdims=True), 1e-9)
    V = jax.random.normal(ks[4], (num_particles, n, m)) * 0.1
    f_local = -jax.random.uniform(ks[5], (num_particles,)) * 100
    r_all = jax.random.uniform(ks[6], (inner_steps, num_particles, 3))

    # Jit the seam call (production invokes it under pso.match's jit;
    # eager timing would measure wrapper dispatch, not the kernel).
    fused_jit = jax.jit(lambda *a: bk.epoch_fused(
        *a, omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5,
        quantized=quantized))
    inputs = (S, V, S, f_local, S[0], jnp.float32(-1e6), S.mean(0),
              mask, Q, G, r_all)

    def run():
        outs = fused_jit(*inputs)
        jax.block_until_ready(outs[2])

    run()                                  # compile
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run()
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


def build_table(num_particles: int, n: int, m: int, inner_steps: int,
                backend: Optional[str] = None, repeats: int = 10,
                measure: bool = True) -> list:
    """One roofline row per fitness dtype for the fused epoch kernel."""
    rows = []
    for quantized in (False, True):
        measured = None
        if measure:
            from repro.kernels import resolve_backend_name
            measured = _measure_epoch(
                resolve_backend_name(backend), num_particles, n, m,
                inner_steps, quantized, repeats)
        rows.append(epoch_roofline(num_particles, n, m, inner_steps,
                                   quantized, measured_s=measured))
    return rows


def main() -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=64)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--m", type=int, default=48)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--backend", type=str, default=None)
    ap.add_argument("--no-measure", action="store_true",
                    help="analytic table only, no kernel timing")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        args.particles, args.n, args.m = 8, 10, 20
        args.steps, args.repeats = 4, 3

    rows = build_table(args.particles, args.n, args.m, args.steps,
                       backend=args.backend, repeats=args.repeats,
                       measure=not args.no_measure)
    vmem = vmem_state_bytes(args.particles, args.n, args.m, args.steps)
    print(f"fused-epoch resident state: {vmem / 2**20:.2f} MiB "
          f"(VMEM budget {VMEM_BYTES / 2**20:.0f} MiB)")
    hdr = (f"{'path':>10s} {'MXU GFLOP':>10s} {'HBM KiB f/l':>14s}"
           f" {'AI f':>7s} {'bound':>8s} {'ms':>9s} {'GFLOP/s':>9s}"
           f" {'%v5e-roof':>9s}")
    print(hdr)
    for r in rows:
        path = "quantized" if r["quantized"] else "float"
        meas = (f"{1e3 * r['measured_s']:9.3f} "
                f"{r['achieved_flops'] / 1e9:9.2f} "
                f"{100 * r['mxu_utilization_vs_v5e']:8.4f}%"
                if "measured_s" in r else f"{'--':>9s} {'--':>9s} "
                f"{'--':>9s}")
        print(f"{path:>10s} {r['mxu_flops_per_epoch'] / 1e9:10.3f} "
              f"{r['hbm_bytes_fused'] / 1024:6.0f}/"
              f"{r['hbm_bytes_loose'] / 1024:7.0f} "
              f"{r['arithmetic_intensity_fused']:7.1f} "
              f"{r['v5e_bound_fused']:>8s} {meas}")
    return rows


if __name__ == "__main__":
    main()
