"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Both Mamba2's SSD and xLSTM's mLSTM are *gated linear attention* with a
scalar-per-head forget gate, so they share one chunkwise-parallel core:

    state_t = a_t · state_{t-1} + k_t v_tᵀ          (a_t = exp(log_f_t))
    out_t   = q_tᵀ · state_t

``chunked_gla`` evaluates this with O(S·L) work (L = chunk length):
intra-chunk masked attention + inter-chunk state carry via ``lax.scan`` —
the production formulation (FlashLinearAttention-style), sub-quadratic in
sequence length, which is what qualifies these archs for ``long_500k``.
``gla_step`` is the O(1)-per-token recurrent form used by decode.

mLSTM folds its input gate into k and tracks the xLSTM normalizer as an
extra value column; Mamba2 adds the D skip path and dt-scaled input.
sLSTM (scalar memory) is inherently sequential → ``lax.scan`` over time.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import common
from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# Chunkwise gated-linear-attention core
# ---------------------------------------------------------------------------

def chunked_gla(q, k, v, log_f, chunk: int, state0=None):
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); log_f: (B,S,H) (≤ 0).
    Returns (out (B,S,H,Dv), final_state (B,H,Dk,Dv))."""
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    N = S // L
    cd = q.dtype

    qc = q.reshape(B, N, L, H, Dk)
    kc = k.reshape(B, N, L, H, Dk)
    vc = v.reshape(B, N, L, H, Dv)
    fc = log_f.reshape(B, N, L, H).astype(jnp.float32)
    cum = jnp.cumsum(fc, axis=2)                       # (B,N,L,H)
    total = cum[:, :, -1]                              # (B,N,H)

    # intra-chunk masked attention with decay exp(cum_t - cum_s), s <= t
    # logits[b,n,h,t,s] = (q_t·k_s) * exp(cum_t - cum_s)
    att = jnp.einsum("bnthk,bnshk->bnhts", qc, kc)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,N,t,s,H)
    decay = jnp.moveaxis(decay, -1, 2)                      # (B,N,H,t,s)
    mask = jnp.tril(jnp.ones((L, L), bool))
    att = att * jnp.where(mask, jnp.exp(decay), 0.0).astype(cd)
    out_intra = jnp.einsum("bnhts,bnshv->bnthv", att, vc)

    # inter-chunk: carry state across chunks with a scan
    # q side decay: exp(cum_t); k side: exp(total - cum_s)
    q_dec = qc * jnp.exp(cum)[..., None].astype(cd)
    k_dec = kc * jnp.exp(total[:, :, None] - cum)[..., None].astype(cd)
    chunk_kv = jnp.einsum("bnshk,bnshv->bnhkv", k_dec, vc)  # (B,N,H,Dk,Dv)

    state_dtype = cd if state0 is None else state0.dtype
    if state0 is None:
        state0 = jnp.zeros((B, H, Dk, Dv), cd)
    state0 = state0.astype(cd)

    def scan_fn(state, inp):
        q_d, kv, tot = inp                              # per-chunk slices
        out_inter = jnp.einsum("bthk,bhkv->bthv", q_d, state)
        new_state = state * jnp.exp(tot)[:, :, None, None].astype(cd) + kv
        return new_state, out_inter

    xs = (jnp.moveaxis(q_dec, 1, 0), jnp.moveaxis(chunk_kv, 1, 0),
          jnp.moveaxis(total, 1, 0))
    final_state, out_inter = jax.lax.scan(scan_fn, state0, xs)
    out_inter = jnp.moveaxis(out_inter, 0, 1).reshape(B, N, L, H, Dv)
    out = (out_intra + out_inter).reshape(B, S, H, Dv)
    return out, final_state.astype(state_dtype)


def gla_step(state, q, k, v, log_f):
    """O(1) decode step. q,k: (B,H,Dk); v: (B,H,Dv); log_f: (B,H).
    Returns (out (B,H,Dv), new_state)."""
    a = jnp.exp(log_f.astype(jnp.float32))[..., None, None].astype(q.dtype)
    new_state = state.astype(q.dtype) * a + jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", q, new_state)
    return out, new_state.astype(state.dtype)


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv1d, kernel K. x: (B,S,C); w: (K,C); b: (C,).
    With a cache ((B,K-1,C) trailing context) returns updated cache."""
    K = w.shape[0]
    if cache is not None:
        xx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        new_cache = xx[:, -(K - 1):] if K > 1 else cache
    else:
        xx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_cache = None
    out = sum(xx[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b), new_cache


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d                    # inner width
    H = cfg.num_heads                      # SSD heads
    P = d_in // H                          # head dim
    N = s.state_dim
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    conv_ch = d_in + 2 * N                 # x + B + C get the conv
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (s.conv_dim, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32) + math.log(0.5),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": common.init_rmsnorm(d_in, dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def mamba2_block(params, cfg: ModelConfig, x, cache: Optional[dict] = None):
    """x: (B,S,d). cache: {"conv": (B,K-1,C), "state": (B,H,N,P)}."""
    s = cfg.ssm
    cd = common.dt(cfg.compute_dtype)
    B, S, d = x.shape
    d_in = s.expand * d
    H = cfg.num_heads
    P = d_in // H
    N = s.state_dim

    z_xbc_dt = jnp.einsum("bsd,dk->bsk", x.astype(cd),
                          params["in_proj"].astype(cd))
    z, xbc, dt = jnp.split(z_xbc_dt, [d_in, 2 * d_in + 2 * N], axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"].astype(cd),
                                 params["conv_b"].astype(cd), conv_cache)
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])          # (B,S,H)
    A = -jnp.exp(params["A_log"])                      # (H,) negative
    log_f = dt * A[None, None, :]                      # (B,S,H) ≤ 0

    v = xs.reshape(B, S, H, P) * dt[..., None].astype(cd)
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N)).astype(cd)
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N)).astype(cd)

    state0 = cache["state"] if cache is not None else None
    if S == 1 and cache is not None:
        out, new_state = gla_step(state0, q[:, 0], k[:, 0], v[:, 0],
                                  log_f[:, 0])
        out = out[:, None]
    else:
        out, new_state = chunked_gla(q, k, v, log_f, s.chunk, state0)
    out = out + v * params["D"][None, None, :, None].astype(cd)
    out = out.reshape(B, S, d_in)
    out = common.rmsnorm(params["norm"], out, cfg.norm_eps)
    out = out * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", out, params["out_proj"].astype(cd))
    new_cache = (None if cache is None else
                 {"conv": new_conv, "state": new_state})
    return out.astype(x.dtype), new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = cfg.num_heads
    P = d_in // H
    return {
        "conv": jnp.zeros((batch, s.conv_dim - 1, d_in + 2 * s.state_dim),
                          dtype),
        "state": jnp.zeros((batch, H, s.state_dim, P), dtype),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory) and sLSTM block (scalar memory)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = cfg.num_heads
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (s.conv_dim, d_in), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wqkv": dense_init(ks[2], (d_in, 3, H, d_in // H), dtype),
        "wif": dense_init(ks[3], (d_in, 2 * H), dtype),
        "if_bias": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                    3.0 + jnp.arange(H, dtype=jnp.float32)
                                    / max(H - 1, 1) * 3.0]),  # f-bias 3..6
        "norm": common.init_rmsnorm(d_in, dtype),
        "down_proj": dense_init(ks[4], (d_in, d), dtype),
    }


def mlstm_block(params, cfg: ModelConfig, x, cache: Optional[dict] = None):
    """xLSTM mLSTM block. cache: {"conv", "state" (B,H,Dk,Dv+1)}."""
    s = cfg.ssm
    cd = common.dt(cfg.compute_dtype)
    B, S, d = x.shape
    d_in = s.expand * d
    H = cfg.num_heads
    Dh = d_in // H

    up = jnp.einsum("bsd,dk->bsk", x.astype(cd),
                    params["up_proj"].astype(cd))
    h_in, gate = jnp.split(up, 2, axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    h_conv, new_conv = _causal_conv(h_in, params["conv_w"].astype(cd),
                                    params["conv_b"].astype(cd), conv_cache)
    qkv = jnp.einsum("bsk,kthd->bsthd", h_conv, params["wqkv"].astype(cd))
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    k = k / math.sqrt(Dh)

    if_gates = jnp.einsum("bsk,kh->bsh", h_conv,
                          params["wif"].astype(cd)).astype(jnp.float32) \
        + params["if_bias"]
    i_gate, f_gate = jnp.split(if_gates, 2, axis=-1)      # (B,S,H)
    log_f = -jax.nn.softplus(-f_gate)                     # log sigmoid(f)
    # fold exp-input-gate into k; normalizer = extra ones column in v
    k_eff = k * jnp.exp(jnp.minimum(i_gate, 8.0))[..., None].astype(cd)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)

    state0 = cache["state"] if cache is not None else None
    if S == 1 and cache is not None:
        out_aug, new_state = gla_step(state0, q[:, 0], k_eff[:, 0],
                                      v_aug[:, 0], log_f[:, 0])
        out_aug = out_aug[:, None]
    else:
        out_aug, new_state = chunked_gla(q, k_eff, v_aug, log_f, s.chunk,
                                         state0)
    out, n = out_aug[..., :Dh], out_aug[..., Dh:]
    out = out / jnp.maximum(jnp.abs(n), 1.0).astype(cd)
    out = out.reshape(B, S, d_in)
    out = common.rmsnorm(params["norm"], out, cfg.norm_eps)
    out = out * jax.nn.silu(gate)
    out = jnp.einsum("bsk,kd->bsd", out, params["down_proj"].astype(cd))
    new_cache = (None if cache is None else
                 {"conv": new_conv, "state": new_state})
    return out.astype(x.dtype), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = cfg.num_heads
    Dh = d_in // H
    return {
        "conv": jnp.zeros((batch, s.conv_dim - 1, d_in), dtype),
        "state": jnp.zeros((batch, H, Dh, Dh + 1), dtype),
    }


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    dtype = common.dt(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        # recurrent weights are per-head block-diagonal (xLSTM design)
        "w_in": dense_init(ks[0], (d, 4, H, Dh), dtype),
        "r": dense_init(ks[1], (H, Dh, 4, Dh), dtype, in_axis=1),
        "bias": jnp.zeros((4, H, Dh), jnp.float32),
        "norm": common.init_rmsnorm(d, dtype),
        "out_proj": dense_init(ks[2], (d, d), dtype),
    }


def slstm_block(params, cfg: ModelConfig, x, cache: Optional[dict] = None):
    """Sequential sLSTM (lax.scan over time). cache: {"c","n","h","m"} each
    (B,H,Dh)."""
    cd = common.dt(cfg.compute_dtype)
    B, S, d = x.shape
    H = cfg.num_heads
    Dh = d // H
    zx = jnp.einsum("bsd,dghk->bsghk", x.astype(cd),
                    params["w_in"].astype(cd))          # (B,S,4,H,Dh)

    if cache is None:
        zeros = jnp.zeros((B, H, Dh), jnp.float32)
        state0 = {"c": zeros, "n": zeros, "h": zeros,
                  "m": jnp.zeros((B, H, Dh), jnp.float32)}
    else:
        state0 = cache

    r = params["r"].astype(cd)
    bias = params["bias"]

    def step(st, zx_t):
        rec = jnp.einsum("bhk,hkgl->bghl", st["h"].astype(cd), r)
        pre = (zx_t + rec).astype(jnp.float32) + bias
        z_t = jnp.tanh(pre[:, 0])
        i_t = pre[:, 1]
        f_t = pre[:, 2]
        o_t = jax.nn.sigmoid(pre[:, 3])
        # stabilized exponential gating (xLSTM eq. 15-17)
        log_f = -jax.nn.softplus(-f_t)
        m_new = jnp.maximum(log_f + st["m"], i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(log_f + st["m"] - m_new)
        c_new = f_e * st["c"] + i_e * z_t
        n_new = f_e * st["n"] + i_e
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return ({"c": c_new, "n": n_new, "h": h_new, "m": m_new},
                h_new.astype(cd))

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(zx, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
    out = common.rmsnorm(params["norm"], out, cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", out, params["out_proj"].astype(cd))
    return out.astype(x.dtype), (state if cache is not None else None)


def init_slstm_cache(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    Dh = cfg.d_model // H
    z = jnp.zeros((batch, H, Dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}
