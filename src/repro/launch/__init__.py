# Launch entry points. NOTE: do not import dryrun here — it must own the
# first jax initialization (XLA_FLAGS device-count override).
