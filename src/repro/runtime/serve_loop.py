"""Serving step factories: prefill + decode with sharded KV caches.

``decode_step`` donates the cache buffers (in-place update on device) and
keeps them sharded per ``runtime.sharding.infer_cache_specs`` — batch over
the data axis (or sequence for batch-1 long-context), heads/latent dims
over the tensor axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.model import BuiltModel
from repro.runtime import sharding as shd


def make_prefill_step(model: BuiltModel, mesh: Optional[Mesh] = None,
                      max_len: int = 0):
    def prefill_step(params, batch):
        from repro.runtime.mesh_ctx import mesh_context
        with mesh_context(mesh):
            logits, caches = model.prefill(params, batch, max_len=max_len)
        return logits, caches
    return prefill_step


def make_decode_step(model: BuiltModel, mesh: Optional[Mesh] = None):
    def decode_step(params, batch, caches, index):
        from repro.runtime.mesh_ctx import mesh_context
        with mesh_context(mesh):
            logits, new_caches = model.decode(params, batch, caches, index)
        # greedy token for the serving loop (sampling lives client-side)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_caches
    return decode_step


def jit_decode_step(model: BuiltModel, mesh: Mesh, params, caches,
                    batch_specs):
    pspecs = shd.infer_param_specs(params, mesh)
    cspecs = shd.infer_cache_specs(caches, mesh)
    step = make_decode_step(model, mesh)
    in_sh = (shd.named(pspecs, mesh), shd.named(batch_specs, mesh),
             shd.named(cspecs, mesh), None)
    out_sh = (None, None, shd.named(cspecs, mesh))
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(2,))
