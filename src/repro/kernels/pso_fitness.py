"""Pallas TPU kernel: fused edge-preserving PSO fitness  -||Q - S G S^T||^2.

This is the matcher's compute hot-spot (two back-to-back matmuls per particle
per evaluation) and the computation the paper explicitly maps onto the
accelerator's MAC array, in both float and uint8/int32 fixed-point form
(paper §3.4).

Tiling: grid = (B particles, n/TILE_N query-row tiles). Per grid step the
kernel holds in VMEM:
  * one (TILE_N, m) row-block of this particle's S,
  * the particle's full S (n, m) for the S^T contraction,
  * the full target adjacency G (m, m),
  * the (TILE_N, n) row-block of Q,
and accumulates the block's squared residual into a (1, 1) output cell.
The row-tile loop is sequential per particle ("arbitrary"), particles are
parallel. Both matmuls hit the MXU with hardware-aligned (128-multiple)
dims — ops.py pads n and m.

VMEM budget (f32, n = m = 512): 512*512*4 * 2 (S, G) + 128*512*4 (block)
+ 128*512*4 (Q block) ≈ 2.6 MB — comfortably inside the ~16 MB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

TILE_N = 128


def _fitness_kernel(s_blk_ref, s_full_ref, q_blk_ref, g_ref, o_ref):
    """Float path. Shapes: s_blk (1, TILE_N, m), s_full (1, n, m),
    q_blk (TILE_N, n), g (m, m), o (1, 1)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s_blk = s_blk_ref[0].astype(jnp.float32)           # (TILE_N, m)
    s_full = s_full_ref[0].astype(jnp.float32)         # (n, m)
    g = g_ref[...].astype(jnp.float32)                 # (m, m)
    q = q_blk_ref[...].astype(jnp.float32)             # (TILE_N, n)

    sg = jnp.dot(s_blk, g, preferred_element_type=jnp.float32)
    # (TILE_N, n) = (TILE_N, m) @ (n, m)^T
    sgs = jax.lax.dot_general(sg, s_full,
                              dimension_numbers=(((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    r = q - sgs
    o_ref[0, 0] += -jnp.sum(r * r)


def _fitness_kernel_quantized(s_blk_ref, s_full_ref, q_blk_ref, g_ref, o_ref,
                              *, scale: int):
    """Fixed-point path: S is uint8 (≈ S*scale), Q/G are {0,1} uint8.

    First matmul uses the int8 MXU path (uint8 × uint8 → int32 accumulate);
    the second contracts the int32 partials against uint8 S (int32
    accumulate). The squared-residual reduction accumulates in f32 — the
    role of the hardware's wide accumulator tree. Residual is in units of
    1/scale², so fitness ordering matches the float kernel.
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s_blk = s_blk_ref[0].astype(jnp.int32)             # (TILE_N, m)
    s_full = s_full_ref[0].astype(jnp.int32)           # (n, m)
    g = g_ref[...].astype(jnp.int32)                   # (m, m)
    q = q_blk_ref[...].astype(jnp.int32)               # (TILE_N, n)

    sg = jnp.dot(s_blk, g, preferred_element_type=jnp.int32)
    sgs = jax.lax.dot_general(sg, s_full,
                              dimension_numbers=(((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    r = (q * (scale * scale) - sgs).astype(jnp.float32)
    o_ref[0, 0] += -jnp.sum(r * r)


def _grid_specs(B: int, n: int, m: int, s_dtype, q_dtype):
    n_tiles = pl.cdiv(n, TILE_N)
    grid = (B, n_tiles)
    in_specs = [
        pl.BlockSpec((1, TILE_N, m), lambda b, i: (b, i, 0)),   # S row-block
        pl.BlockSpec((1, n, m), lambda b, i: (b, 0, 0)),        # full S
        pl.BlockSpec((TILE_N, n), lambda b, i: (i, 0)),         # Q row-block
        pl.BlockSpec((m, m), lambda b, i: (0, 0)),              # G
    ]
    out_specs = pl.BlockSpec((1, 1), lambda b, i: (b, 0))
    return grid, in_specs, out_specs


@functools.partial(jax.jit, static_argnames=("interpret",))
def edge_fitness_pallas(S: jax.Array, Q: jax.Array, G: jax.Array,
                        interpret: bool = False) -> jax.Array:
    """S: (B, n, m) f32 row-stochastic; Q: (n, n); G: (m, m). -> (B,) f32.

    n, m must be multiples of 128 (ops.py pads); padding rows of S and
    rows/cols of Q/G must be zero, which keeps the residual exact.
    """
    B, n, m = S.shape
    grid, in_specs, out_specs = _grid_specs(B, n, m, S.dtype, Q.dtype)
    out = pl.pallas_call(
        _fitness_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(S, S, Q, G)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def edge_fitness_quantized_pallas(S_q: jax.Array, Q: jax.Array, G: jax.Array,
                                  scale: int = 255,
                                  interpret: bool = False) -> jax.Array:
    """Fixed-point fitness. S_q: (B, n, m) uint8; Q/G: {0,1}. -> (B,) f32."""
    B, n, m = S_q.shape
    grid, in_specs, out_specs = _grid_specs(B, n, m, S_q.dtype, Q.dtype)
    kernel = functools.partial(_fitness_kernel_quantized, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(S_q, S_q, Q, G)
    return out[:, 0]
