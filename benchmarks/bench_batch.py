"""Coalesced-batch matcher benchmark: burst latency, batched vs sequential.

Simulates K concurrent arrivals in one event window — warm repeat traffic
of servable requests, the scheduler's steady state — all landing in the
same shape bucket, and compares:

  * **sequential** — K warm ``MatcherService.match`` calls (K jit
    dispatches, K carry re-validations), the pre-batching hot path;
  * **coalesced** — ONE ``match_many`` launch over the same K problems
    (one jit dispatch, one batched program with per-problem early exit
    and the warm-carry fast path).

Both paths run against fully warmed caches (compile + warm-start), so the
ratio isolates the per-dispatch overhead the problem axis amortizes.
Results must match problem-for-problem (same found flags) — verified on
every run.

Problem selection: planted instances are generated from seed 100 upward
and the first K the service *serves* (finds on the cold call) form the
burst — an unserved problem is a search-quality matter (see the quant
ablation), not a dispatch-latency one. Note the honest flip side, also
reported: a problem that canNOT fast-path keeps the whole batch live for
its epochs, so mixed easy/hard bursts on a serial device can be slower
batched than sequential (`cold_batch_s` vs `cold_sequential_s` shows it).

Emits ``BENCH_batch.json`` and CSV rows on stdout. Acceptance: the warm
coalesced batch completes in < 0.5× the sequential wall time.

Usage: PYTHONPATH=src python -m benchmarks.bench_batch
           [--batch K] [--repeats N] [--smoke] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax

from repro.core import graphs, pso
from repro.core.service import MatcherService


def _planted(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="burst size K (coalesced into one launch)")
    ap.add_argument("--repeats", type=int, default=15,
                    help="timed repetitions per path (min 2)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: small swarm, batch of 4")
    ap.add_argument("--out", default="BENCH_batch.json")
    args = ap.parse_args()

    if args.smoke:
        cfg = pso.PSOConfig(num_particles=8, epochs=2, inner_steps=4)
        batch = min(args.batch, 4)
        repeats = 2
    else:
        # the simulator's production window config (SimConfig.pso_cfg)
        cfg = pso.PSOConfig(num_particles=32, epochs=2, inner_steps=8)
        batch = args.batch
        repeats = max(args.repeats, 2)
    n, m = 6, 12

    svc = MatcherService(cfg, batch_classes=(1, 2, 4, max(8, batch)))
    problems, keys, wkeys = [], [], []
    bucket = None

    # ---- warm-up: compile, pick K servable problems, seed warm carries --
    t0 = time.perf_counter()
    seed = 100
    while len(problems) < batch and seed < 100 + 20 * batch:
        q, g = _planted(seed, n, m)
        key = jax.random.PRNGKey(seed)
        r = svc.match(q, g, key=key, workload_key=f"burst/{seed}")
        if r.found:
            problems.append((q, g))
            keys.append(key)
            wkeys.append(f"burst/{seed}")
            bucket = r.bucket
        seed += 1
    cold_seq_s = time.perf_counter() - t0
    assert len(problems) == batch, "not enough servable planted problems"
    t0 = time.perf_counter()
    warm0 = svc.match_many(problems, keys=keys, workload_keys=wkeys)
    cold_batch_s = time.perf_counter() - t0
    assert all(r.bucket == bucket for r in warm0), \
        "burst must land in one shape bucket"

    # ---- timed: K sequential warm calls vs one coalesced launch ---------
    seq_lat, batch_lat = [], []
    seq_flags = batch_flags = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        rs = [svc.match(q, g, key=keys[i], workload_key=wkeys[i])
              for i, (q, g) in enumerate(problems)]
        seq_lat.append(time.perf_counter() - t0)
        seq_flags = [r.found for r in rs]

        t0 = time.perf_counter()
        rb = svc.match_many(problems, keys=keys, workload_keys=wkeys)
        batch_lat.append(time.perf_counter() - t0)
        batch_flags = [r.found for r in rb]
        assert all(r.warm_hit and r.compile_cache_hit for r in rb)

    assert seq_flags == batch_flags, \
        f"batched results diverge: {seq_flags} vs {batch_flags}"

    seq_med = statistics.median(seq_lat)
    batch_med = statistics.median(batch_lat)
    ratio = batch_med / max(seq_med, 1e-12)
    stats = svc.stats_dict()

    result = {
        "batch_size": batch,
        "bucket": list(bucket),
        "smoke": bool(args.smoke),
        "pso_cfg": {"num_particles": cfg.num_particles,
                    "epochs": cfg.epochs,
                    "inner_steps": cfg.inner_steps},
        "cold_sequential_s": cold_seq_s,
        "cold_batch_s": cold_batch_s,
        "sequential_total_median_s": seq_med,
        "coalesced_batch_median_s": batch_med,
        "batch_over_sequential_ratio": ratio,
        "coalesced_speedup": 1.0 / max(ratio, 1e-12),
        "per_problem_found": seq_flags,
        "found_flags_match": seq_flags == batch_flags,
        "batch_occupancy": stats["batch_occupancy"],
        "carry_fastpath_hits": stats["carry_fastpath_hits"],
        "stats": stats,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("name,us_per_call,derived")
    print(f"batch_seq_{batch}_warm,{seq_med * 1e6:.1f},"
          f"{sum(seq_flags)}/{batch}_found")
    print(f"batch_coalesced_{batch}_warm,{batch_med * 1e6:.1f},"
          f"ratio={ratio:.3f}")
    print(f"batch_speedup,{0.0},x{1.0 / max(ratio, 1e-12):.2f}")
    ok = ratio < 0.5 and seq_flags == batch_flags
    print(f"batch_acceptance,{0.0},{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
