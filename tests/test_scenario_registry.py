"""Scenario registry: golden-seed byte-stability + composition tests.

The golden digests below were captured from the monolithic pre-registry
``make_*_scenario`` builders (commit before the registry refactor) over
every field of every generated TaskSpec (``float.hex()`` for times, so
the comparison is bitwise). The presets now compose through
``repro.sched.registry.build_scenario``; these tests prove the registry
path reproduces the historical output byte-for-byte — plus unit
coverage for the registry pieces, ``Scenario`` re-materialization
idempotence, and the previously untested ``make_restart_scenario``
edge cases (restart at t=0 / past the horizon / duplicated instants /
on a StreamScenario).
"""
import dataclasses
import hashlib

import pytest

from repro.accel.platform import EDGE
from repro.sched.registry import (ARRIVALS, RESTARTS, Registry,
                                  build_scenario)
from repro.sched.simulator import SimConfig, Simulator
from repro.sched.schedulers import get_scheduler
from repro.sched.tasks import (Scenario, StreamScenario,
                               make_burst_scenario,
                               make_mixed_burst_scenario,
                               make_restart_scenario, make_scenario,
                               make_streaming_scenario)


def _task_rec(t):
    return (t.name, t.workload.name, float(t.arrival).hex(), t.priority,
            float(t.deadline).hex(), t.urgent, t.task_id)


def scenario_digest(sc):
    """Bitwise digest of a scenario: name, horizon, restarts and every
    TaskSpec field, with floats serialized via ``hex()``."""
    if hasattr(sc, "tasks"):
        tasks = sc.tasks
        extra = [repr(r) for r in sc.restarts]
    else:
        tasks = list(sc.arrivals_iter())
        extra = [repr(r) for r in sc.restarts]
        extra.append(repr(sc.expected_arrivals))
    rec = [sc.name, float(sc.horizon).hex(), extra,
           [_task_rec(t) for t in tasks]]
    return hashlib.sha256(repr(rec).encode()).hexdigest()


#: (builder thunk, pre-refactor digest) — one entry per legacy builder
#: shape, defaults and knob-heavy variants both covered.
GOLDEN = {
    "poisson": (
        lambda: make_scenario("simple", rate_hz=25, horizon=0.4, seed=3),
        "adb5202bae0e1a75f3b4a3c29734107e2b0d7a9ed24831e4504e99c34c8a039b"),
    "poisson-bursty": (
        lambda: make_scenario("middle", rate_hz=30, horizon=0.3,
                              urgent_frac=0.2, deadline_slack=1.5,
                              urgent_slack=1.0, burst_size=3,
                              burst_frac=0.4, seed=7),
        "62e8d7b889b0d188e43f680ba56bacf1e0e6f00c9a870c2391281a7af4f59605"),
    "burst": (
        lambda: make_burst_scenario("simple", rate_hz=40, horizon=0.3,
                                    seed=11),
        "fba1f2e5abc4364278207efa0ef923f0cc1f89de18b1b2373ce4a180925ad9ea"),
    "mixed": (
        lambda: make_mixed_burst_scenario(rate_hz=30, horizon=0.4, seed=5),
        "0054068c57a663beb89617ceab4ee85a2fc53b2c2cb1ee4ea339f5e10114f889"),
    "mixed-churn": (
        lambda: make_mixed_burst_scenario(
            "simple", "middle", rate_hz=25, horizon=0.3, burst_size=4,
            hard_frac=0.5, burst_frac=0.6, churn_rate_hz=50.0, seed=9),
        "d2b866251a8f89b3de63dbd89edf6b03e6a07d81ea7e48da799259fe74f69dfc"),
    "restart": (
        lambda: make_restart_scenario(seed=3),
        "b907c9d804482621985762c6b7fd52c446e238cadd25700cfdb7b41f9ae6d343"),
    "restart-knobs": (
        lambda: make_restart_scenario(
            "middle", rate_hz=25, phase_horizon=0.3, burst_size=3,
            burst_frac=0.5, urgent_frac=0.2, restart_gap=2e-3, seed=13),
        "ecd09a00a6b9b2824c74ff4b162c4ea5e7d69105e512a1464f24b4d9e23f5306"),
    "streaming": (
        lambda: make_streaming_scenario("simple", rate_hz=50, horizon=0.5,
                                        seed=2),
        "6332e8244ac2c27db4cd582fef4ff9d336922f8ad75d745d304fe02d1dd20ad9"),
    "streaming-bursty": (
        lambda: make_streaming_scenario("simple", rate_hz=40, horizon=0.4,
                                        burst_size=5, burst_frac=0.3,
                                        seed=21),
        "c07308b96de64492f1f26c658798bf67f8f30276e1d3b852eb13bdb0873e29bf"),
}


@pytest.mark.parametrize("case", sorted(GOLDEN), ids=sorted(GOLDEN))
def test_golden_seed_byte_stability(case):
    build, want = GOLDEN[case]
    assert scenario_digest(build()) == want, \
        f"{case}: registry output diverged from pre-refactor bytes"


def test_explicit_spec_matches_preset_bytes():
    """A hand-written spec dict through ``build_scenario`` reproduces
    the same golden bytes as the preset — the registry path IS the
    preset path, not a parallel implementation."""
    sc = build_scenario({
        "name": "middle-burst3", "seed": 7, "horizon": 0.3,
        "streams": [{
            "arrival": {"kind": "burst", "rate_hz": 30,
                        "burst_size": 3, "burst_frac": 0.4},
            "workload": {"kind": "uniform", "complexity": "middle"},
            "urgency": {"kind": "bernoulli", "urgent_frac": 0.2},
            "deadline": {"kind": "slack", "deadline_slack": 1.5,
                         "urgent_slack": 1.0,
                         "base_exec_estimate": 5e-3},
        }],
    })
    assert scenario_digest(sc) == GOLDEN["poisson-bursty"][1]


def test_explicit_two_stream_spec_matches_mixed_churn_bytes():
    """The churn phase is just a second registered stream sharing the
    RNG — composed explicitly it must equal the legacy interleaving."""
    deadline = {"kind": "slack", "deadline_slack": 2.0,
                "urgent_slack": 1.25, "base_exec_estimate": 5e-3}
    sc = build_scenario({
        "name": "mixed-simple-middle-burst4", "seed": 9, "horizon": 0.3,
        "streams": [
            {"arrival": {"kind": "burst", "rate_hz": 25,
                         "burst_size": 4, "burst_frac": 0.6},
             "workload": {"kind": "mixed_burst", "easy": "simple",
                          "hard": "middle", "hard_frac": 0.5,
                          "burst_size": 4},
             "urgency": {"kind": "never"}, "deadline": deadline},
            {"arrival": {"kind": "poisson", "rate_hz": 50.0},
             "workload": {"kind": "uniform", "complexity": "simple"},
             "urgency": {"kind": "always"}, "deadline": deadline},
        ],
    })
    assert scenario_digest(sc) == GOLDEN["mixed-churn"][1]


def test_preset_delegation():
    sc = build_scenario({"preset": "poisson",
                         "args": {"complexity": "simple", "rate_hz": 25,
                                  "horizon": 0.4, "seed": 3}})
    assert scenario_digest(sc) == GOLDEN["poisson"][1]
    with pytest.raises(ValueError, match="unknown scenario preset"):
        build_scenario({"preset": "nope"})
    with pytest.raises(ValueError, match="alongside 'preset'"):
        build_scenario({"preset": "poisson", "horizon": 1.0})


# ---------------------------------------------------------------------------
# registry machinery
# ---------------------------------------------------------------------------

def test_registry_names_and_errors():
    assert {"poisson", "burst", "trace"} <= set(ARRIVALS.names())
    assert {"none", "at", "replay"} <= set(RESTARTS.names())
    with pytest.raises(ValueError, match="unknown arrival"):
        ARRIVALS.build({"kind": "weibull"}, None, 1.0)
    with pytest.raises(ValueError, match="needs a 'kind'"):
        ARRIVALS.build({"rate_hz": 5.0}, None, 1.0)
    reg = Registry("demo")

    @reg.register("x")
    def _x():
        return 1
    with pytest.raises(ValueError, match="duplicate"):
        reg.register("x")(lambda: 2)


def test_trace_arrival_and_named_workload():
    sc = build_scenario({
        "name": "trace", "horizon": 0.2,
        "streams": [{
            "arrival": {"kind": "trace", "times": [0.0, 0.05, 0.05, 0.5],
                        "counts": [1, 2, 1, 1]},
            "workload": {"kind": "named", "name": "mobilenetv2"},
            "deadline": {"kind": "fixed", "offset": 1.0},
        }],
    })
    # 0.5 >= horizon dropped; counts honored; no RNG consumed at all
    assert [t.arrival for t in sc.tasks] == [0.0, 0.05, 0.05, 0.05]
    assert all(t.name == "mobilenetv2" and not t.urgent
               and t.deadline == t.arrival + 1.0 for t in sc.tasks)
    with pytest.raises(ValueError, match="nondecreasing"):
        build_scenario({
            "horizon": 1.0,
            "streams": [{
                "arrival": {"kind": "trace", "times": [0.2, 0.1]},
                "workload": {"kind": "named", "name": "mobilenetv2"},
            }]})


def test_streaming_spec_is_deterministic_and_rejects_replay():
    spec = {"horizon": 0.3, "seed": 4, "stream": True,
            "streams": [{
                "arrival": {"kind": "poisson", "rate_hz": 40},
                "workload": {"kind": "uniform", "complexity": "simple"},
                "urgency": {"kind": "bernoulli", "urgent_frac": 0.3},
            }]}
    sc = build_scenario(spec)
    assert isinstance(sc, StreamScenario)
    a = [_task_rec(t) for t in sc.arrivals_iter()]
    b = [_task_rec(t) for t in sc.arrivals_iter()]
    assert a == b and a
    with pytest.raises(ValueError, match="cannot back a streaming"):
        build_scenario({**spec, "restarts": {"kind": "replay"}})


# ---------------------------------------------------------------------------
# Scenario re-materialization idempotence (the __post_init__ fix)
# ---------------------------------------------------------------------------

def test_scenario_rematerialization_is_idempotent():
    base = make_scenario("simple", rate_hz=25, horizon=0.4, seed=3)
    before = [(id(t), t.task_id) for t in base.tasks]
    # same tasks, same order: ids already match -> objects pass through
    again = Scenario(name="again", tasks=list(base.tasks),
                     horizon=base.horizon)
    assert [id(t) for t in again.tasks] == [i for i, _ in before]
    assert [(id(t), t.task_id) for t in base.tasks] == before


def test_scenario_never_renumbers_foreign_tasks():
    """Building a new scenario out of another scenario's tasks must not
    corrupt the donor's task ids (the silent-mutation regression)."""
    base = make_scenario("simple", rate_hz=25, horizon=0.4, seed=3)
    donor_ids = [t.task_id for t in base.tasks]
    early = dataclasses.replace(base.tasks[0], arrival=0.0, task_id=-1)
    merged = Scenario(name="merged", tasks=[early] + list(base.tasks),
                      horizon=base.horizon)
    n = len(base.tasks)
    assert [t.task_id for t in merged.tasks] == list(range(n + 1))
    # donor untouched: shifted tasks were renumbered on COPIES
    assert [t.task_id for t in base.tasks] == donor_ids
    assert not any(m is t for m in merged.tasks[1:]
                   for t in (base.tasks[0],))


# ---------------------------------------------------------------------------
# make_restart_scenario / restart-schedule edge cases
# ---------------------------------------------------------------------------

def _cfg(**kw):
    return SimConfig(platform=EDGE, matcher_mode="analytic", **kw)


def _trace_restart_spec(restarts, horizon=0.2, stream=False):
    return {
        "name": "restart-edge", "horizon": horizon, "seed": 0,
        "stream": stream,
        "streams": [{
            "arrival": {"kind": "trace", "times": [0.0, 0.02, 0.05]}
            if not stream else {"kind": "poisson", "rate_hz": 40},
            "workload": {"kind": "named", "name": "mobilenetv2"},
            "deadline": {"kind": "fixed", "offset": 1.0},
        }],
        "restarts": {"kind": "at", "times": restarts},
    }


def test_restart_at_time_zero_hits_fresh_scheduler():
    sc = build_scenario(_trace_restart_spec([0.0]))
    r = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(sc)
    assert r.matcher_stats["restart_count"] == 1
    assert r.finished == r.total == 3


def test_restart_past_horizon_never_fires():
    sc = build_scenario(_trace_restart_spec([10.0]))
    r = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(sc)
    assert r.matcher_stats["restart_count"] == 0
    assert r.finished == r.total == 3


def test_duplicate_restart_instants_fire_individually():
    sc = build_scenario(_trace_restart_spec([0.03, 0.03]))
    r = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(sc)
    assert r.matcher_stats["restart_count"] == 2
    # heap and legacy loops must agree on the double-kill bitwise
    sc2 = build_scenario(_trace_restart_spec([0.03, 0.03]))
    r2 = Simulator(_cfg(validate=True),
                   get_scheduler("immsched")).run_legacy(sc2)
    assert dataclasses.asdict(r) == dataclasses.asdict(r2)


def test_restarts_on_stream_scenario_match_materialized():
    stream = build_scenario(_trace_restart_spec([0.1], stream=True))
    assert isinstance(stream, StreamScenario) and stream.restarts == [0.1]
    mat = Scenario(name=stream.name,
                   tasks=list(stream.arrivals_iter()),
                   horizon=stream.horizon, restarts=list(stream.restarts))
    ra = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(stream)
    rb = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(mat)
    assert ra.matcher_stats["restart_count"] == 1
    assert dataclasses.asdict(ra) == dataclasses.asdict(rb)


def test_restart_preset_replays_phase_one_exactly():
    sc = make_restart_scenario("simple", rate_hz=30, phase_horizon=0.2,
                               seed=5)
    kill_at = sc.restarts[0]
    n = len(sc.tasks) // 2
    assert len(sc.tasks) == 2 * n
    phase1, phase2 = sc.tasks[:n], sc.tasks[n:]
    for a, b in zip(phase1, phase2):
        assert b.arrival == a.arrival + kill_at
        assert b.deadline == a.deadline + kill_at
        assert (a.name, a.workload.name, a.urgent) == \
            (b.name, b.workload.name, b.urgent)
    assert all(t.arrival < kill_at for t in phase1)
    assert all(t.arrival >= kill_at for t in phase2)
