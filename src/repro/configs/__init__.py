"""Architecture registry: ``--arch <id>`` resolution + per-arch policies.

``get_config(arch)`` returns the exact published ModelConfig;
``get_train_config(arch)`` returns the production training policy
(optimizer family, state dtype, gradient-accumulation microbatches) sized
for v5e 16 GB HBM (DESIGN.md §5); ``input_specs`` builds the input pytree
(ShapeDtypeStructs for the dry-run, concrete arrays for smoke runs).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, TRAIN_4K, ModelConfig,
                                ShapeConfig, TrainConfig, shapes_for)

_MODULES = {
    "llama3-8b": "llama3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2.5-3b": "qwen2_5_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

ARCHS: List[str] = list(_MODULES)

# production training policies per arch (memory budget: v5e 16 GB)
_TRAIN_POLICY: Dict[str, TrainConfig] = {
    "llama3-8b": TrainConfig(microbatches=4),
    "qwen1.5-110b": TrainConfig(microbatches=16, optimizer="adafactor",
                                opt_state_dtype="bfloat16"),
    "qwen1.5-0.5b": TrainConfig(microbatches=1),
    "qwen2.5-3b": TrainConfig(microbatches=2),
    "seamless-m4t-medium": TrainConfig(microbatches=1),
    "deepseek-v2-236b": TrainConfig(microbatches=16, optimizer="adafactor",
                                    opt_state_dtype="bfloat16"),
    "arctic-480b": TrainConfig(microbatches=16, optimizer="adafactor",
                               opt_state_dtype="bfloat16"),
    "xlstm-1.3b": TrainConfig(microbatches=2),
    "zamba2-7b": TrainConfig(microbatches=4),
    "qwen2-vl-7b": TrainConfig(microbatches=4),
}

# modality frontends (stubs per harness): token split for mixed inputs
VLM_PATCH_TOKENS = 1024          # of the seq_len, for family == vlm
AUDIO_FRAME_RATIO = 1.0          # encoder frames per decoder token

# parallelism profile per (arch, shape): "2d" (FSDP×TP, default) or
# "fsdp_only" (batch/params over ALL axes, no TP — wins for ≤10B-dense
# training where TP's activation all-reduces dominate; §Perf)
# NOTE: the fsdp_only experiment for ≤8B dense train cells was REFUTED by
# measurement (probe collectives ×50, compile ×5 — XLA SPMD degrades at
# 1-seq/device with 256-way param gathers; EXPERIMENTS.md §Perf iter 5).
# The mechanism stays available for future meshes; no cell uses it.
_PARALLELISM = {}


def parallelism_profile(arch: str, shape_name: str) -> str:
    return _PARALLELISM.get((arch, shape_name), "2d")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_train_config(arch: str) -> TrainConfig:
    return _TRAIN_POLICY[arch]


def arch_shapes(arch: str):
    return shapes_for(get_config(arch))


def input_specs(arch: str, shape: ShapeConfig, abstract: bool = True,
                batch_override: int = 0):
    """Input pytree for (arch × shape). ``abstract=True`` →
    ShapeDtypeStructs (dry-run: no allocation); else small concrete arrays.

    train:   full-sequence tokens + labels (+ frontend embeddings)
    prefill: full-sequence tokens (+ frontend embeddings)
    decode:  one new token (KV cache of seq_len managed by serve_step)
    """
    cfg = get_config(arch)
    B = batch_override or shape.global_batch
    S = shape.seq_len

    def make(shp, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shp, dtype)
        if dtype in (jnp.int32,):
            return jnp.zeros(shp, dtype)
        return jnp.zeros(shp, dtype)

    batch = {}
    if shape.mode == "decode":
        batch["tokens"] = make((B, 1), jnp.int32)
        if cfg.mrope:
            batch["positions3"] = make((3, B, 1), jnp.int32)
    else:
        s_text = S
        if cfg.family == "vlm":
            n_patch = min(VLM_PATCH_TOKENS, S // 4)
            s_text = S - n_patch
            batch["patches"] = make((B, n_patch, cfg.d_model), jnp.bfloat16)
        if cfg.family in ("encdec", "audio"):
            n_frames = max(int(S * AUDIO_FRAME_RATIO) // 2, 8)
            batch["frames"] = make((B, n_frames, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = make((B, s_text), jnp.int32)
        if cfg.mrope:
            batch["positions3"] = make((3, B, S), jnp.int32)
        if shape.mode == "train":
            batch["labels"] = make((B, s_text), jnp.int32)
    return batch
