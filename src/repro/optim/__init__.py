from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import warmup_cosine
from repro.optim.grad_compress import compressed_psum, CompressionState


def get_optimizer(train_cfg):
    if train_cfg.optimizer == "adamw":
        return adamw(b1=train_cfg.b1, b2=train_cfg.b2,
                     weight_decay=train_cfg.weight_decay,
                     state_dtype=train_cfg.opt_state_dtype)
    if train_cfg.optimizer == "adafactor":
        return adafactor(weight_decay=train_cfg.weight_decay,
                         state_dtype=train_cfg.opt_state_dtype)
    raise ValueError(train_cfg.optimizer)
