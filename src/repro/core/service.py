"""Online matcher service: warm-started, compile-cached subgraph matching.

``pso.match`` alone is a batch API: every new (n, m) query/target shape
triggers an XLA recompile (seconds) and every call restarts the swarm from
the cold uniform prior — the opposite of what an *online* scheduler needs
when tasks arrive unpredictably at microsecond granularity. The
``MatcherService`` turns it into a service:

  * **Shape classes** — query/target problems are bucketed to padded
    ``(n_pad, m_pad)`` classes via ``preemptible_dag.pad_problem`` (dummy
    tiles pinned to dummy PEs, semantics preserved), so repeat arrivals of
    any size within a bucket reuse one compiled executable.
  * **Bounded compile LRU** — one jit wrapper per (bucket, config), held in
    an LRU of ``cache_capacity`` entries; evicting an entry drops its
    executable. Repeat arrivals never recompile.
  * **Warm starts** — the final global-controller state
    ``(S*, f*, S̄)`` of each call is remembered under a
    (workload, platform-state) key and fed back as ``carry0`` on the next
    arrival of the same problem, so the swarm resumes from the previous
    consensus instead of the uniform prior.
  * **Early exit** — the service enables ``cfg.early_exit`` so easy
    matches stop scanning epochs once a feasible mapping clears the
    fitness bound (1 epoch instead of T on planted instances).

Statistics for all three mechanisms are exported via ``stats`` /
``stats_dict()`` and surfaced by ``sched.metrics``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pso
from repro.core.graphs import (Graph, compatibility_mask,
                               topological_relabel)
from repro.core.matcher import (MatchResult, build_distributed_match,
                                collect_result)
from repro.core.preemptible_dag import pad_problem


def _round_up(v: int, mult: int) -> int:
    mult = max(mult, 1)
    return ((v + mult - 1) // mult) * mult


def shape_bucket(n: int, m: int, n_multiple: int = 8,
                 m_multiple: int = 16) -> Tuple[int, int]:
    """Stable padded shape class for an (n, m) matching problem.

    The target bucket must leave room for the ``n_pad - n`` dummy PEs that
    ``pad_problem`` pins the dummy query tiles to.
    """
    n_pad = _round_up(max(n, 1), n_multiple)
    m_pad = _round_up(max(m, 1) + (n_pad - n), m_multiple)
    return n_pad, m_pad


@dataclasses.dataclass
class ServiceStats:
    calls: int = 0
    compile_cache_hits: int = 0      # bucket already had an executable
    compile_cache_misses: int = 0    # new bucket → jit compile
    compile_evictions: int = 0
    warm_hits: int = 0               # carry0 reused from a previous call
    warm_misses: int = 0
    warm_evictions: int = 0
    epochs_run: int = 0              # total epochs actually executed
    epochs_budgeted: int = 0         # cfg.epochs × calls
    found: int = 0

    @property
    def epochs_saved(self) -> int:
        return self.epochs_budgeted - self.epochs_run

    @property
    def compile_hit_rate(self) -> float:
        return self.compile_cache_hits / max(self.calls, 1)

    @property
    def warm_hit_rate(self) -> float:
        return self.warm_hits / max(self.calls, 1)


@dataclasses.dataclass
class ServiceMatchResult(MatchResult):
    bucket: Tuple[int, int] = (0, 0)
    compile_cache_hit: bool = False
    warm_hit: bool = False
    latency_s: float = 0.0


class MatcherService:
    """Warm-start online wrapper around Algorithm 1.

    Single-device by default; pass ``mesh`` + ``axis_names`` to run each
    bucket's executable as the collective-fused distributed matcher.
    """

    def __init__(self, cfg: Optional[pso.PSOConfig] = None, *,
                 mesh=None, axis_names: Sequence[str] = ("data",),
                 cache_capacity: int = 16, warm_capacity: int = 256,
                 warm_start: bool = True, early_exit: bool = True,
                 n_multiple: int = 8, m_multiple: int = 16):
        cfg = cfg or pso.PSOConfig()
        if early_exit and not cfg.early_exit:
            cfg = cfg.replace(early_exit=True)
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.cache_capacity = max(int(cache_capacity), 1)
        self.warm_capacity = max(int(warm_capacity), 1)
        self.warm_start = warm_start
        self.n_multiple = n_multiple
        self.m_multiple = m_multiple
        self.stats = ServiceStats()
        self._compiled: "OrderedDict[Tuple[int, int], object]" = OrderedDict()
        self._warm: "OrderedDict[Tuple, tuple]" = OrderedDict()

    # -- caches ------------------------------------------------------------

    def _executable(self, bucket: Tuple[int, int]):
        fn = self._compiled.get(bucket)
        if fn is not None:
            self._compiled.move_to_end(bucket)
            self.stats.compile_cache_hits += 1
            return fn
        self.stats.compile_cache_misses += 1
        if self.mesh is None:
            cfg = self.cfg

            def fn(key, Q, G, mask, carry0, _cfg=cfg):
                return pso._match_body(key, Q, G, mask, _cfg, carry0)

            fn = jax.jit(fn)
        else:
            fn = build_distributed_match(bucket, self.mesh, self.cfg,
                                         self.axis_names)
        self._compiled[bucket] = fn
        while len(self._compiled) > self.cache_capacity:
            self._compiled.popitem(last=False)
            self.stats.compile_evictions += 1
        return fn

    def _warm_key(self, workload_key, Qp, Gp, maskp) -> Tuple:
        """Warm starts are only valid for the *same* problem (f* values are
        not comparable across different Q/G), so the key always includes a
        content digest; ``workload_key`` additionally scopes entries to the
        caller's (workload, platform-state) naming."""
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(Qp).tobytes())
        h.update(np.ascontiguousarray(Gp).tobytes())
        h.update(np.ascontiguousarray(maskp).tobytes())
        return (workload_key, Qp.shape[0], Gp.shape[0], h.hexdigest())

    def _get_carry(self, warm_key):
        if self.warm_start and warm_key in self._warm:
            self._warm.move_to_end(warm_key)
            self.stats.warm_hits += 1
            return self._warm[warm_key], True
        self.stats.warm_misses += 1
        return None, False

    def _put_carry(self, warm_key, carry):
        if not self.warm_start:
            return
        self._warm[warm_key] = carry
        while len(self._warm) > self.warm_capacity:
            self._warm.popitem(last=False)
            self.stats.warm_evictions += 1

    # -- matching ----------------------------------------------------------

    def match(self, query: Graph, target: Graph,
              key: Optional[jax.Array] = None,
              workload_key=None) -> ServiceMatchResult:
        """Match ``query`` onto ``target`` through the service caches.

        ``workload_key`` names the (workload, platform-state) class for
        warm-start scoping — e.g. ``(task_name, free_engine_signature)``.
        Results are exactly the unpadded equivalent of a direct
        ``pso.match`` on the same problem.
        """
        t0 = time.perf_counter()
        self.stats.calls += 1
        if key is None:
            key = jax.random.PRNGKey(0)

        q, order = topological_relabel(query)
        n, m = q.n, target.n
        # stay on the host until the padded problem is final — the jit call
        # uploads Qp/Gp/maskp once; no device→host→device round trip
        mask = compatibility_mask(q, target)
        bucket = shape_bucket(n, m, self.n_multiple, self.m_multiple)
        Qp, Gp, maskp = pad_problem(q.adj, target.adj, mask, *bucket)

        hits_before = self.stats.compile_cache_hits
        fn = self._executable(bucket)
        compile_hit = self.stats.compile_cache_hits > hits_before

        warm_key = self._warm_key(workload_key, Qp, Gp, maskp)
        carry0, warm_hit = self._get_carry(warm_key)
        if carry0 is None:
            carry0 = pso.default_carry(jnp.asarray(maskp))

        if self.mesh is None:
            outs = fn(key, Qp, Gp, maskp, carry0)
        else:
            num_shards = int(np.prod([self.mesh.shape[a]
                                      for a in self.axis_names]))
            keys = jax.random.split(key, num_shards)
            outs = fn(keys, Qp, Gp, maskp, carry0)

        base = collect_result(outs, order=order, crop=(n, m))
        res = ServiceMatchResult(**{f.name: getattr(base, f.name)
                                    for f in dataclasses.fields(MatchResult)})
        self._put_carry(warm_key, res.carry)
        self.stats.epochs_run += res.epochs_run
        self.stats.epochs_budgeted += self.cfg.epochs
        if res.found:
            self.stats.found += 1
        res.bucket = bucket
        res.compile_cache_hit = compile_hit
        res.warm_hit = warm_hit
        res.latency_s = time.perf_counter() - t0
        return res

    # -- reporting ---------------------------------------------------------

    def stats_dict(self) -> Dict[str, float]:
        s = self.stats
        return {
            "calls": s.calls,
            "compile_cache_hits": s.compile_cache_hits,
            "compile_cache_misses": s.compile_cache_misses,
            "compile_hit_rate": s.compile_hit_rate,
            "warm_hits": s.warm_hits,
            "warm_misses": s.warm_misses,
            "warm_hit_rate": s.warm_hit_rate,
            "epochs_run": s.epochs_run,
            "epochs_budgeted": s.epochs_budgeted,
            "epochs_saved": s.epochs_saved,
            "found": s.found,
        }
