"""Sharding-spec inference + distributed pieces that need >1 device
(run in subprocesses with fake CPU devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(__file__))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=900, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_param_spec_rules():
    script = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as shd

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = {
        "embed": jnp.zeros((1024, 64)),
        "blocks": {"attn": {"wq": jnp.zeros((8, 64, 8, 16)),
                            "wo": jnp.zeros((8, 8, 16, 64))},
                   "ffn": {"experts": {"gate": jnp.zeros((8, 4, 64, 32))},
                           "router": jnp.zeros((8, 64, 4))}},
        "final_ln": {"scale": jnp.zeros((64,))},
    }
    specs = shd.infer_param_specs(params, mesh)
    assert specs["embed"] == P("model", "data"), specs["embed"]
    # stacked leading layer dim stays unsharded
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model", None)
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None, "data")
    assert specs["blocks"]["ffn"]["experts"]["gate"] == \\
        P(None, "model", "data", None)
    assert specs["blocks"]["ffn"]["router"] == P(None, "data", None)
    assert specs["final_ln"]["scale"] == P(None)
    print("SPEC-RULES-OK")
    """
    assert "SPEC-RULES-OK" in _run(script)


def test_divisibility_fallback():
    script = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as shd
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # kv head dim 3 not divisible by model=2 → replicated
    params = {"wk": jnp.zeros((64, 3, 16))}
    specs = shd.infer_param_specs(params, mesh)
    assert specs["wk"] == P("data", None, None), specs["wk"]
    # batch 1 cache → sequence gets the data axis (context parallel)
    cache = {"k": jnp.zeros((4, 1, 64, 8, 16))}
    cspecs = shd.infer_cache_specs(cache, mesh)
    assert cspecs["k"][1] is None and cspecs["k"][2] == "data"
    print("FALLBACK-OK")
    """
    assert "FALLBACK-OK" in _run(script)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The FSDP+TP train step must be numerically identical to the
    unsharded one."""
    script = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.models import build_model
    from repro.runtime.train_loop import (make_train_state, make_train_step,
                                          state_specs)
    from repro.runtime import sharding as shd
    import sys
    sys.path.insert(0, "tests")
    from test_smoke_archs import reduce_config

    cfg = reduce_config(get_config("llama3-8b"))
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, microbatches=2, z_loss=0.0)
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
    }
    # single device reference
    step1 = jax.jit(make_train_step(model, tcfg, mesh=None))
    s1, m1 = step1(jax.tree.map(lambda x: x, state), batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sspecs = state_specs(state, mesh)
    bspecs = shd.infer_batch_specs(batch, mesh)
    step8 = jax.jit(make_train_step(model, tcfg, mesh),
                    in_shardings=(shd.named(sspecs, mesh),
                                  shd.named(bspecs, mesh)),
                    out_shardings=(shd.named(sspecs, mesh), None))
    s8, m8 = step8(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m8["loss"]),
                               rtol=2e-4)
    w1 = np.asarray(jax.tree.leaves(s1["params"])[0])
    w8 = np.asarray(jax.tree.leaves(s8["params"])[0])
    np.testing.assert_allclose(w1, w8, atol=3e-4)
    print("SHARDED-TRAIN-OK", float(m8["loss"]))
    """
    assert "SHARDED-TRAIN-OK" in _run(script)


@pytest.mark.slow
def test_grad_compression_semantics():
    """int8 error-feedback psum ≈ exact mean, and error feedback keeps the
    cumulative bias bounded over steps."""
    script = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.optim.grad_compress import compressed_psum
    from repro.runtime.sharding import get_shard_map

    mesh = jax.make_mesh((8,), ("data",))
    D = 8
    shard_map = get_shard_map()

    @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P("data"), P("data")))
    def one_round(g, err):
        mean, new_err = compressed_psum(g[0], err[0], "data", D)
        return mean[None], new_err[None]

    key = jax.random.PRNGKey(0)
    gs = jax.random.normal(key, (D, 256))
    errs = jnp.zeros((D, 256))
    exact = gs.mean(0)
    # accumulate compressed means over rounds; error feedback must keep
    # the time-averaged estimate close to the true mean
    acc = jnp.zeros((256,))
    rounds = 8
    for _ in range(rounds):
        mean, errs = one_round(gs, errs)
        acc = acc + mean[0]
    est = acc / rounds
    err_1shot = float(jnp.abs(mean[0] - exact).max())
    err_avg = float(jnp.abs(est - exact).max())
    assert err_avg < err_1shot or err_avg < 2e-3, (err_avg, err_1shot)
    assert err_avg < 0.05
    print("COMPRESS-OK", err_1shot, err_avg)
    """
    assert "COMPRESS-OK" in _run(script)
