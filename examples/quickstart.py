"""Quickstart: IMMSched's parallel PSO-Ullmann subgraph matcher in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Plants an 8-tile workload DAG inside a 4x4 engine array and recovers a
feasible mapping with the quantized (uint8, int32-accumulate) matcher —
the computation the paper runs on the accelerator's MAC datapath.
"""
import jax
import numpy as np

from repro.core import graphs
from repro.core.matcher import IMMSchedMatcher
from repro.core.pso import PSOConfig


def main():
    key = jax.random.PRNGKey(0)
    kq, kt = jax.random.split(key)
    # a workload window: random 8-tile DAG
    query = graphs.random_dag(kq, 8, edge_prob=0.35)
    # an engine array that provably contains it
    target = graphs.embed_query_in_target(kt, query, 16)

    cfg = PSOConfig(num_particles=48, epochs=4, inner_steps=10,
                    quantized=True)
    result = IMMSchedMatcher(cfg).match(query, target)

    assert result.found, "matcher failed on a feasible instance"
    M = np.asarray(result.mapping, dtype=int)
    print("feasible mappings found:", result.feasible_count)
    print("tile -> engine:", {i: int(np.argmax(M[i])) for i in range(M.shape[0])})
    covered = M @ target.adj.astype(int) @ M.T
    print("all query edges preserved:", bool((covered >= query.adj).all()))
    print("global best fitness f* =", result.f_star)


if __name__ == "__main__":
    main()
