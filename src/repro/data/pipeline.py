"""Deterministic, sharded, resumable data pipeline.

Design rules for 1000+-node training:
  * **stateless addressing** — batch ``i`` for shard ``s`` is a pure
    function of (seed, i, s): any host can reproduce any batch, so restart
    = "set the cursor", and elastic re-sharding = "recompute your shard id"
    (no shared queue, no coordinator);
  * **skip-restore** — the cursor is part of the checkpoint;
  * the synthetic backend hashes counters through ``jax.random`` (Philox)
    — collision-free and identical across hosts; a memmap-file backend
    covers real token corpora with the same addressing contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, index: int, shard: int, num_shards: int,
              batch_size: int) -> Dict[str, np.ndarray]:
        """Per-shard slice of global batch ``index`` (tokens + LM labels)."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, index, shard]))
        toks = rng.integers(0, self.vocab_size,
                            size=(batch_size, self.seq_len + 1),
                            dtype=np.int64).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class FileLMDataset:
    """Memmap-backed token stream with the same (index, shard) addressing."""
    path: str
    vocab_size: int
    seq_len: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")

    def batch(self, index: int, shard: int, num_shards: int,
              batch_size: int) -> Dict[str, np.ndarray]:
        span = batch_size * (self.seq_len + 1)
        stride = num_shards * span
        start = (index * stride + shard * span) % max(
            len(self._data) - span, 1)
        chunk = np.asarray(self._data[start:start + span])
        chunk = chunk.reshape(batch_size, self.seq_len + 1)
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}


class DataPipeline:
    """Cursor + sharding wrapper; checkpointable."""

    def __init__(self, dataset, global_batch: int, shard: int = 0,
                 num_shards: int = 1, start_index: int = 0):
        assert global_batch % num_shards == 0
        self.dataset = dataset
        self.global_batch = global_batch
        self.shard = shard
        self.num_shards = num_shards
        self.index = start_index

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    def next(self) -> Dict[str, np.ndarray]:
        b = self.dataset.batch(self.index, self.shard, self.num_shards,
                               self.local_batch)
        self.index += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    def skip_to(self, index: int) -> None:
        self.index = index

    # -- checkpoint interface --
    def state_dict(self) -> Dict:
        return {"index": self.index, "global_batch": self.global_batch}

    def load_state_dict(self, state: Dict, *, shard: Optional[int] = None,
                        num_shards: Optional[int] = None) -> None:
        """Elastic restore: the cursor is global, so a different shard
        count just re-partitions future batches."""
        self.index = int(state["index"])
        assert state["global_batch"] == self.global_batch
        if shard is not None:
            self.shard = shard
        if num_shards is not None:
            assert self.global_batch % num_shards == 0
            self.num_shards = num_shards
