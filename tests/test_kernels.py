"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes/dtypes, plus hypothesis property tests on the oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(1, 8, 16), (2, 16, 16), (3, 40, 72), (2, 128, 128), (1, 130, 60)]


def _rand_problem(key, B, n, m):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    S = jax.random.uniform(k1, (B, n, m))
    S = S / S.sum(-1, keepdims=True)
    Q = jax.random.bernoulli(k2, 0.3, (n, n)).astype(jnp.uint8)
    Q = jnp.triu(Q, k=1)  # DAG
    G = jax.random.bernoulli(k3, 0.4, (m, m)).astype(jnp.uint8)
    G = jnp.triu(G, k=1)
    mask = jax.random.bernoulli(k4, 0.8, (n, m)).astype(jnp.uint8)
    # guarantee at least one feasible entry per row to exercise normalize
    mask = mask.at[:, 0].set(1)
    return S, Q, G, mask


@pytest.mark.parametrize("B,n,m", SHAPES)
def test_edge_fitness_matches_ref(B, n, m):
    S, Q, G, _ = _rand_problem(jax.random.PRNGKey(0), B, n, m)
    got = ops.edge_fitness(S, Q, G, backend="interpret")
    want = ops.edge_fitness(S, Q, G, backend="ref")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,n,m", SHAPES)
def test_edge_fitness_quantized_matches_ref(B, n, m):
    S, Q, G, _ = _rand_problem(jax.random.PRNGKey(1), B, n, m)
    Sq = ref.quantize_s(S)
    got = ops.edge_fitness_quantized(Sq, Q, G, backend="interpret")
    want = ops.edge_fitness_quantized(Sq, Q, G, backend="ref")
    np.testing.assert_allclose(got, np.asarray(want, dtype=np.float64),
                               rtol=1e-3)


@pytest.mark.parametrize("B,n,m", SHAPES)
def test_ullmann_refine_matches_ref(B, n, m):
    key = jax.random.PRNGKey(2)
    _, Q, G, mask = _rand_problem(key, B, n, m)
    M = jnp.broadcast_to(mask, (B, n, m)).astype(jnp.uint8)
    got = ops.ullmann_refine_step(M, Q, G, backend="interpret")
    want = ops.ullmann_refine_step(M, Q, G, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,n,m", SHAPES)
def test_pso_update_matches_ref(B, n, m):
    key = jax.random.PRNGKey(3)
    S, Q, G, mask = _rand_problem(key, B, n, m)
    ks = jax.random.split(key, 5)
    V = jax.random.normal(ks[0], (B, n, m)) * 0.1
    S_local = S
    S_star = S[0]
    S_bar = S.mean(0)
    r = jax.random.uniform(ks[1], (B, 3))
    hyper = dict(omega=0.7, c1=1.4, c2=1.4, c3=0.6, v_max=0.5)
    s_got, v_got = ops.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                                  backend="interpret", **hyper)
    s_want, v_want = ops.pso_update(S, V, S_local, S_star, S_bar, mask, r,
                                    backend="ref", **hyper)
    np.testing.assert_allclose(s_got, s_want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v_got, v_want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,m", [(8, 16), (16, 16), (40, 72), (130, 60)])
def test_greedy_project_matches_ref(n, m):
    key = jax.random.PRNGKey(4)
    S, _, _, mask = _rand_problem(key, 1, n, m)
    got = ops.greedy_project(S[0], mask, backend="interpret")
    want = ops.greedy_project(S[0], mask, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,m", [(8, 16), (40, 72), (130, 60)])
def test_masked_argmax_matches_ref(n, m):
    key = jax.random.PRNGKey(5)
    X = jax.random.normal(key, (n, m))
    mask = jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (n, m)
                                ).astype(jnp.uint8)
    vg, ig = ops.masked_argmax(X, mask, backend="interpret")
    vw, iw = ops.masked_argmax(X, mask, backend="ref")
    np.testing.assert_allclose(vg, vw, rtol=1e-6)
    assert int(ig) == int(iw)


# ------------------------- property tests (oracles) ------------------------

@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(2, 20), st.randoms())
def test_pso_update_invariants(n, m, rnd):
    """After any update: rows are stochastic, masked entries zero, S >= 0."""
    seed = rnd.randint(0, 2**31 - 1)
    key = jax.random.PRNGKey(seed)
    S, _, _, mask = _rand_problem(key, 1, n, m)
    V = jax.random.normal(key, (1, n, m))
    r = jax.random.uniform(key, (1, 3))
    s_new, _ = ops.pso_update(S, V, S, S[0], S[0], mask, r, omega=0.7,
                              c1=1.5, c2=1.5, c3=0.5, backend="ref")
    s_new = np.asarray(s_new[0])
    maskb = np.asarray(mask, dtype=bool)
    assert (s_new >= -1e-7).all()
    assert np.abs(s_new[~maskb]).max(initial=0.0) < 1e-7
    np.testing.assert_allclose(s_new.sum(-1), 1.0, atol=1e-4)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(2, 14), st.randoms())
def test_refine_never_adds_candidates(n, m, rnd):
    seed = rnd.randint(0, 2**31 - 1)
    key = jax.random.PRNGKey(seed)
    _, Q, G, mask = _rand_problem(key, 1, n, m)
    M = mask[None].astype(jnp.uint8)
    M2 = ops.ullmann_refine_step(M, Q, G, backend="ref")
    assert (np.asarray(M2) <= np.asarray(M)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.randoms())
def test_perfect_match_zero_residual(n, rnd):
    """Mapping a graph onto itself with identity S has fitness 0 when the
    target has exactly the query edges (monomorphism residual counts both
    missing and extra edges; self-map of Q onto Q is exact)."""
    seed = rnd.randint(0, 2**31 - 1)
    key = jax.random.PRNGKey(seed)
    Q = jnp.triu(jax.random.bernoulli(key, 0.4, (n, n)), 1).astype(jnp.uint8)
    S = jnp.eye(n)[None]
    f = ops.edge_fitness(S, Q, Q, backend="ref")
    np.testing.assert_allclose(f, 0.0, atol=1e-6)


def test_quantized_fitness_ordering_matches_float():
    """PSO only needs the *ordering* of fitness values: check uint8 path
    preserves ranking of clearly-separated particles."""
    key = jax.random.PRNGKey(7)
    S, Q, G, _ = _rand_problem(key, 8, 24, 32)
    f_float = np.asarray(ops.edge_fitness(S, Q, G, backend="ref"))
    Sq = ref.quantize_s(S)
    f_q = np.asarray(ops.edge_fitness_quantized(Sq, Q, G, backend="ref"),
                     dtype=np.float64)
    # compare orderings of pairs separated by > quantization noise
    order_f = np.argsort(f_float)
    f_scaled = f_q / (255.0 ** 4)  # back to float units
    for a, b in zip(order_f[:-1], order_f[1:]):
        if f_float[b] - f_float[a] > 1.0:  # > uint8 quantization noise band
            assert f_scaled[b] > f_scaled[a]
