"""Fault tolerance & elasticity for the training/serving runtime.

Three mechanisms (DESIGN.md §8):
  * **StepWatchdog** — EWMA + k·σ step-time anomaly detector; flags
    straggling hosts so the launcher can exclude them at the next
    checkpoint boundary.
  * **elastic mesh rebuild** — derive the production mesh from the *live*
    device set (largest (pods, data, model) factorization that preserves
    the model axis), restore the checkpoint with new shardings, and set
    the data-pipeline cursor; nothing in the state is tied to the old
    device count.
  * **engine re-matching** (the paper's own mechanism doubling as FT) —
    when engines/devices fail mid-run on the accelerator, drop them from
    the target graph G and re-run the IMMSched matcher to remap the
    workload subgraph onto the surviving engine DAG.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class StepWatchdog:
    alpha: float = 0.1            # EWMA smoothing
    k_sigma: float = 3.0
    warmup: int = 10
    mean: float = 0.0
    var: float = 0.0
    count: int = 0

    def observe(self, step_time: float) -> bool:
        """Returns True if this step is a straggler anomaly."""
        self.count += 1
        if self.count <= self.warmup:
            d = step_time - self.mean
            self.mean += d / self.count
            self.var += d * (step_time - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.count - 1, 1), 1e-12))
        is_straggler = step_time > self.mean + self.k_sigma * std
        d = step_time - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


def elastic_mesh_shape(num_devices: int, model_parallel: int = 16,
                       multi_pod_threshold: int = 512):
    """Largest mesh from the live device set, preserving the tensor axis.

    Returns (shape, axis_names). Drops stragglers by simply being called
    with the smaller device count — data parallel shrinks, the model axis
    (which the checkpointed layouts depend on) is preserved.
    """
    assert num_devices >= model_parallel, "cannot preserve model axis"
    usable = (num_devices // model_parallel) * model_parallel
    data = usable // model_parallel
    if usable >= multi_pod_threshold and data % 2 == 0:
        return (2, data // 2, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def surviving_engine_mask(num_engines: int,
                          failed: Sequence[int]) -> List[bool]:
    failed_set = set(failed)
    return [e not in failed_set for e in range(num_engines)]


def remap_on_failure(platform, running_workload, failed_engines,
                     matcher=None):
    """Re-match a running workload's tile window onto the surviving
    engines (the paper's subgraph matcher as the FT mechanism).

    Returns (mapping or None, surviving target graph)."""
    from repro.accel.target_graph import free_engine_graph
    from repro.core.matcher import IMMSchedMatcher
    from repro.core import preemptible_dag

    mask = surviving_engine_mask(platform.engines, failed_engines)
    target = free_engine_graph(platform, mask)
    cap = platform.engine_tile_capacity_macs()
    pdag = preemptible_dag.build_preemptible_dag(
        [(0, running_workload, 0)], tile_capacity_macs=cap,
        window_stages=4)
    q = pdag.graph
    if q.n > target.n:
        keep = np.sort(np.argsort([t.stage for t in pdag.tiles])[:target.n])
        q = type(q)(adj=q.adj[np.ix_(keep, keep)], types=q.types[keep],
                    weights=q.weights[keep])
    matcher = matcher or IMMSchedMatcher()
    res = matcher.match(q, target)
    return (res.mapping if res.found else None), target
