"""Ullmann subgraph isomorphism: vectorized pieces + the serial baseline.

The vectorized refinement/feasibility used inside the PSO loop lives in
``repro.kernels`` (ops/ref). This module adds:

  * ``serial_ullmann`` — the classic depth-first backtracking Ullmann with
    per-level refinement. This is the *IsoSched-like baseline*: it is what a
    CPU-serialized TSS scheduler runs, and its step count feeds the latency
    model of the baseline scheduler in ``repro.sched``.
  * ``count_monomorphisms`` — exhaustive oracle for tests (small graphs).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class SerialStats:
    """Work counters: the baseline cost model charges these."""
    nodes_visited: int = 0          # search-tree nodes
    refine_sweeps: int = 0          # refinement passes
    mac_ops: int = 0                # multiply-accumulate ops in refinement


def _refine_np(M: np.ndarray, Q: np.ndarray, G: np.ndarray,
               stats: Optional[SerialStats] = None) -> np.ndarray:
    """Fixpoint refinement, numpy (serial semantics, same math as ref.py)."""
    Qi = Q.astype(np.int64)
    Gi = G.astype(np.int64)
    M = M.astype(np.int64)
    n, m = M.shape
    while True:
        support_out = M @ Gi.T
        support_in = M @ Gi
        viol = Qi @ (support_out == 0) + Qi.T @ (support_in == 0)
        M2 = M * (viol == 0)
        if stats is not None:
            stats.refine_sweeps += 1
            stats.mac_ops += 2 * n * m * m + 2 * n * n * m
        if (M2 == M).all():
            return M2
        M = M2


def serial_ullmann(Q: np.ndarray, G: np.ndarray, mask: np.ndarray,
                   max_solutions: int = 1,
                   stats: Optional[SerialStats] = None
                   ) -> List[np.ndarray]:
    """Classic recursive Ullmann (directed monomorphism).

    Returns up to ``max_solutions`` assignment matrices. ``stats`` (if
    given) accumulates the serial work — the quantity IMMSched removes from
    the critical path.
    """
    n, m = mask.shape
    if stats is None:
        stats = SerialStats()
    M0 = _refine_np(mask.copy(), Q, G, stats)
    solutions: List[np.ndarray] = []
    used = np.zeros(m, dtype=bool)
    assign = np.full(n, -1, dtype=np.int64)

    # order rows by fewest candidates first (standard Ullmann ordering)
    order = np.argsort(M0.sum(axis=1))

    def backtrack(depth: int, M: np.ndarray) -> bool:
        stats.nodes_visited += 1
        if depth == n:
            sol = np.zeros((n, m), dtype=np.uint8)
            for i in range(n):
                sol[i, assign[i]] = 1
            solutions.append(sol)
            return len(solutions) >= max_solutions
        i = order[depth]
        for j in range(m):
            if M[i, j] and not used[j]:
                M2 = M.copy()
                M2[i, :] = 0
                M2[:, j] = 0
                M2[i, j] = 1
                M2 = _refine_np(M2, Q, G, stats)
                if (M2.sum(axis=1) == 0).any():
                    continue
                used[j] = True
                assign[i] = j
                if backtrack(depth + 1, M2):
                    return True
                used[j] = False
                assign[i] = -1
        return False

    if not (M0.sum(axis=1) == 0).any():
        backtrack(0, M0)
    return solutions


def count_monomorphisms(Q: np.ndarray, G: np.ndarray,
                        mask: Optional[np.ndarray] = None,
                        limit: int = 10_000) -> int:
    """Exhaustive count (test oracle, n ≤ ~8)."""
    n, m = Q.shape[0], G.shape[0]
    if mask is None:
        mask = np.ones((n, m), dtype=np.uint8)
    count = 0

    def rec(i: int, used: int, assign: List[int]) -> None:
        nonlocal count
        if count >= limit:
            return
        if i == n:
            count += 1
            return
        for j in range(m):
            if not mask[i, j] or (used >> j) & 1:
                continue
            ok = True
            for u in range(i):
                if Q[i, u] and not G[j, assign[u]]:
                    ok = False
                    break
                if Q[u, i] and not G[assign[u], j]:
                    ok = False
                    break
            if ok:
                rec(i + 1, used | (1 << j), assign + [j])

    rec(0, 0, [])
    return count
