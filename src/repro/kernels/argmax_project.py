"""Pallas TPU kernel: greedy argmax projection of a relaxed mapping S.

The paper redesigns the accelerator's tree-based accumulator with
"comparators and selectors, enabling the output of the index corresponding
to the maximum value within a vector" — precisely the primitive needed to
project the continuous S onto a discrete injective assignment M̂ (each tile
→ exactly one PE, each PE ← at most one tile).

The kernel runs the full greedy loop on-chip: grid = (n,) *sequential*
steps; S and the availability mask live in VMEM for the whole sweep (one
HBM read of S total, vs. n reads for a host-side loop). Step k:

    (i, j) = argmax over available entries of S
    M̂[i, j] = 1;  row i and column j become unavailable

Shapes up to (512, 512) f32 use ≈ 2 MB VMEM (S + avail scratch + output).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG = jnp.finfo(jnp.float32).min


def _project_kernel(s_ref, mask_ref, o_ref, avail_ref):
    k = pl.program_id(0)
    n, m = s_ref.shape

    @pl.when(k == 0)
    def _init():
        avail_ref[...] = mask_ref[...].astype(jnp.float32)
        o_ref[...] = jnp.zeros_like(o_ref)

    sv = jnp.where(avail_ref[...] > 0.0, s_ref[...].astype(jnp.float32), _NEG)
    row_max = jnp.max(sv, axis=1)                       # (n,)
    i = jnp.argmax(row_max).astype(jnp.int32)
    val = jnp.max(row_max)
    row = jax.lax.dynamic_slice_in_dim(sv, i, 1, axis=0)  # (1, m)
    j = jnp.argmax(row[0]).astype(jnp.int32)
    take = val > _NEG

    rows = jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    hit = (rows == i) & (cols == j) & take
    kill = ((rows == i) | (cols == j)) & take

    o_ref[...] = jnp.where(hit, jnp.ones_like(o_ref), o_ref[...])
    avail_ref[...] = jnp.where(kill, 0.0, avail_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def greedy_project_pallas(S: jax.Array, mask: jax.Array,
                          interpret: bool = False) -> jax.Array:
    """S: (n, m) f32; mask: (n, m) {0,1}. Returns M̂: (n, m) uint8."""
    n, m = S.shape
    out = pl.pallas_call(
        _project_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((n, m), lambda k: (0, 0)),
            pl.BlockSpec((n, m), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, m), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((n, m), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(S, mask)
    return out


def _masked_argmax_kernel(x_ref, mask_ref, val_ref, idx_ref):
    n, m = x_ref.shape
    xv = jnp.where(mask_ref[...] != 0, x_ref[...].astype(jnp.float32), _NEG)
    row_max = jnp.max(xv, axis=1)
    i = jnp.argmax(row_max).astype(jnp.int32)
    row = jax.lax.dynamic_slice_in_dim(xv, i, 1, axis=0)
    j = jnp.argmax(row[0]).astype(jnp.int32)
    val_ref[0, 0] = jnp.max(row_max)
    idx_ref[0, 0] = i * m + j


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_argmax_pallas(X: jax.Array, mask: jax.Array,
                         interpret: bool = False):
    """Single masked argmax (value, flat index) — the comparator-tree
    primitive itself, exposed for reuse and testing."""
    n, m = X.shape
    val, idx = pl.pallas_call(
        _masked_argmax_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, m), lambda k: (0, 0)),
            pl.BlockSpec((n, m), lambda k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda k: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda k: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(X, mask)
    return val[0, 0], idx[0, 0]
