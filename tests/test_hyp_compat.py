"""Self-test for the hypothesis compatibility shim.

Two paths must stay green regardless of whether `hypothesis` is
installed in the running environment:

  * the ACTIVE path — whatever ``_hyp_compat`` resolved to here (real
    hypothesis in CI, the deterministic fallback in bare containers) —
    must drive ``@given`` tests end to end;
  * the FALLBACK path — loaded explicitly with the ``hypothesis``
    import masked — must cover every strategy the scenario fuzzer uses
    (integers / booleans / floats / sampled_from / lists / just /
    composite) and reproduce draws deterministically.
"""
import importlib.util
import pathlib
import sys

import _hyp_compat

SHIM_PATH = pathlib.Path(__file__).with_name("_hyp_compat.py")


def _forced_fallback():
    """The shim module with `hypothesis` masked so the fallback loads."""
    saved = {k: sys.modules.pop(k) for k in list(sys.modules)
             if k == "hypothesis" or k.startswith("hypothesis.")}
    sys.modules["hypothesis"] = None    # forces ImportError on import
    try:
        spec = importlib.util.spec_from_file_location(
            "_hyp_compat_forced", SHIM_PATH)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    finally:
        del sys.modules["hypothesis"]
        sys.modules.update(saved)
    assert not mod.HAVE_HYPOTHESIS
    return mod


def test_have_hypothesis_flag_matches_environment():
    assert _hyp_compat.HAVE_HYPOTHESIS == \
        (importlib.util.find_spec("hypothesis") is not None)


def test_active_path_runs_examples():
    seen = []

    @_hyp_compat.settings(max_examples=5, deadline=None)
    @_hyp_compat.given(_hyp_compat.st.integers(0, 9),
                       _hyp_compat.st.sampled_from(["a", "b"]))
    def probe(n, tag):
        assert 0 <= n <= 9 and tag in ("a", "b")
        seen.append((n, tag))

    probe()
    # the fallback runs exactly max_examples; real hypothesis may dedupe
    # a couple from the small search space
    assert len(seen) >= 3


def test_fallback_strategies_cover_fuzzer_needs():
    mod = _forced_fallback()
    st = mod.st
    import random
    rnd = random.Random(0)
    for _ in range(50):
        assert 3 <= st.integers(3, 7).draw(rnd) <= 7
        assert st.booleans().draw(rnd) in (True, False)
        assert 0.25 <= st.floats(0.25, 0.75).draw(rnd) <= 0.75
        assert st.sampled_from(("x", "y")).draw(rnd) in ("x", "y")
        assert st.just(42).draw(rnd) == 42
        lst = st.lists(st.integers(0, 1), min_size=1, max_size=3).draw(rnd)
        assert 1 <= len(lst) <= 3 and set(lst) <= {0, 1}
    # a fair coin must produce both faces in 50 paired draws
    coins = {st.booleans().draw(random.Random(s)) for s in range(50)}
    assert coins == {True, False}


def test_fallback_composite_and_determinism():
    mod = _forced_fallback()
    st = mod.st

    @st.composite
    def pairs(draw, hi):
        return (draw(st.integers(0, hi)), draw(st.sampled_from("pq")))

    runs = []
    for _ in range(2):
        seen = []

        @mod.settings(max_examples=6)
        @mod.given(pairs(9))
        def probe(pair):
            n, tag = pair
            assert 0 <= n <= 9 and tag in "pq"
            seen.append(pair)

        probe()
        runs.append(seen)
    assert len(runs[0]) == 6
    # fixed per-example seeding: the two runs replay identical draws
    assert runs[0] == runs[1]


def test_fallback_given_wrapper_is_fixtureless():
    """pytest must see a zero-argument callable (strategy params must
    not be mistaken for fixtures)."""
    import inspect
    mod = _forced_fallback()

    @mod.given(mod.st.integers(0, 1))
    def probe(x):
        pass

    assert inspect.signature(probe).parameters == {}
    assert probe.__name__ == "probe"
